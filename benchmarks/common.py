"""Shared helpers for the paper-artifact benchmarks."""

from __future__ import annotations

import json
import time
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts" / "bench"


def save(name: str, payload: dict):
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    (ARTIFACTS / f"{name}.json").write_text(
        json.dumps(payload, indent=1, default=str))


def ascii_curve(values, width: int = 60, height: int = 12,
                label: str = "") -> str:
    """Tiny ASCII plot for terminal-readable benchmark output."""
    import numpy as np
    v = np.asarray(values, float)
    if len(v) == 0:
        return "(empty)"
    if len(v) > width:
        idx = np.linspace(0, len(v) - 1, width).astype(int)
        v = v[idx]
    lo, hi = float(v.min()), float(v.max())
    span = (hi - lo) or 1.0
    rows = []
    for r in range(height, 0, -1):
        thr = lo + span * (r - 0.5) / height
        rows.append("".join("█" if x >= thr else " " for x in v))
    rows.append(f"[{lo:.4g} … {hi:.4g}] {label}")
    return "\n".join(rows)


class Timer:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *a):
        self.wall_s = time.monotonic() - self.t0

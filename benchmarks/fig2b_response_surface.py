"""Paper Fig. 2b: non-linear, multi-peak performance response.

Ceph: bandwidth vs pg-number.  Here: step time vs the flash q-block size
(and vs the KV chunk), on the prefill_32k cell where attention dominates —
alignment and divisor peaks with VMEM cliffs produce the same irregular
multi-peak shape that motivates GP-BO over hill-climbers.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import ascii_curve, save
from repro.configs import get_config
from repro.core.costmodel import SINGLE_POD, estimate
from repro.core.knobs import clean_space
from repro.models.config import SHAPES_BY_NAME


def run(quick: bool = False):
    cfg = get_config("yi-6b")
    cell = SHAPES_BY_NAME["prefill_32k"]
    space, _, _ = clean_space(cfg, cell, SINGLE_POD)
    base = space.default_config()
    base.update(attention_impl="flash", flash_block_k=512)

    blocks = list(range(128, 2049, 128))
    times = []
    for b in blocks:
        c = space.project({**base, "flash_block_q": b})
        times.append(estimate(cfg, cell, SINGLE_POD, c).step_s)

    d = np.sign(np.diff(times))
    peaks = int(np.sum((d[:-1] < 0) & (d[1:] > 0)))  # local minima count
    print("step time vs flash_block_q (yi-6b prefill_32k):")
    print(ascii_curve([-t for t in times], label="−step_s (higher=better)"))
    print(f"local optima: {peaks + 1} (multi-peak: {peaks >= 1})")

    out = {"blocks": blocks, "step_s": times, "n_local_optima": peaks + 1}
    save("fig2b_response_surface", out)
    return out


if __name__ == "__main__":
    run()

"""Paper Fig. 4: dynamic vs static search boundaries.

The paper shows a static box can exclude the optimum entirely; SAPPHIRE
enlarges a boundary whenever the optimizer probes near it.  Reproduction:
tune the two flash block-size knobs starting from a deliberately narrow
initial box [128, 256] when the response surface's optimum sits at larger
blocks — only the dynamic-boundary run escapes.
"""

from __future__ import annotations

from dataclasses import replace


from benchmarks.common import save
from repro.configs import get_config
from repro.core import bo
from repro.core.costmodel import SINGLE_POD, estimate
from repro.core.knobs import clean_space
from repro.models.config import SHAPES_BY_NAME


def run(quick: bool = False):
    cfg = get_config("yi-6b")
    cell = SHAPES_BY_NAME["prefill_32k"]
    space, _, _ = clean_space(cfg, cell, SINGLE_POD)
    base = space.default_config()
    base.update(attention_impl="flash", microbatch=8)

    # narrow initial box that excludes the large-block optima
    sub = space.subset(["flash_block_q", "flash_block_k"])
    narrow = sub
    for n in ("flash_block_q", "flash_block_k"):
        narrow = narrow.with_knob(replace(narrow.knob(n), lo=128, hi=256,
                                          default=128))

    def objective(c):
        full = dict(base)
        full.update(c)
        # deliberately NOT space.project: the narrow box IS the domain
        return estimate(cfg, cell, SINGLE_POD, full).step_s

    n_iter = 10 if quick else 24
    cfg_dyn = bo.BOConfig(n_init=4, n_iter=n_iter, n_candidates=256,
                          fit_steps=60, boundary_factor=2.0, seed=0)
    cfg_sta = bo.BOConfig(n_init=4, n_iter=n_iter, n_candidates=256,
                          fit_steps=60, dynamic_boundary=False, seed=0)
    bd, vd, td, sp_d = bo.minimize(objective, narrow, cfg_dyn)
    bs, vs, ts, _ = bo.minimize(objective, narrow, cfg_sta)

    print(f"static  box: best blocks ({bs['flash_block_q']},"
          f" {bs['flash_block_k']}) step {vs:.4f}s")
    print(f"dynamic box: best blocks ({bd['flash_block_q']},"
          f" {bd['flash_block_k']}) step {vd:.4f}s  "
          f"(boundary events: {len(td.boundary_events)})")
    print(f"dynamic beats static: {vd < vs}  "
          f"final hi: {sp_d.knob('flash_block_q').hi:.0f}")
    out = {
        "static": {"best": bs, "value": vs, "trace": ts.best_values},
        "dynamic": {"best": bd, "value": vd, "trace": td.best_values,
                    "boundary_events": td.boundary_events,
                    "final_hi_q": sp_d.knob("flash_block_q").hi},
    }
    save("fig4_dynamic_boundary", out)
    return out


if __name__ == "__main__":
    run()

"""Paper Fig. 5: default vs expert-manual vs SAPPHIRE, test & product envs.

Three workloads (the paper's rand/seq/write -> our train_4k / prefill_32k /
decode_32k on yi-6b).  For each: tune on the TEST evaluator (single-pod
analytic, noisy), then re-score all three configs on the PRODUCT
environment (multi-pod analytic — the 2×16×16 fleet) — the paper's
transfer experiment.  ``--compiled`` additionally validates the train_4k
configs against the compiled dry-run evaluator (slow: one XLA compile per
config).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import save
from repro.configs import get_config
from repro.core.bo import BOConfig
from repro.core.costmodel import MULTI_POD
from repro.core.evaluators import AnalyticEvaluator
from repro.core.tuner import Sapphire, expert_manual_config
from repro.models.config import SHAPES_BY_NAME


WORKLOADS = ("train_4k", "prefill_32k", "decode_32k")


def run(quick: bool = False, arch: str = "yi-6b", compiled: bool = False):
    cfg = get_config(arch)
    out = {}
    for shape in WORKLOADS:
        s = Sapphire(
            arch=arch, shape=shape, top_k=16,
            n_rank_samples=120 if quick else 300,
            bo_config=BOConfig(n_init=8, n_iter=12 if quick else 32,
                               n_candidates=512, fit_steps=80, seed=0),
            seed=0)
        res = s.tune()
        # product env: noise-free rescoring on the multi-pod fleet
        cell = SHAPES_BY_NAME[shape]
        prod = AnalyticEvaluator(cfg, cell, MULTI_POD, noise_sigma=0.0)
        space = res.ranking.space
        default = space.project(space.default_config())
        expert = expert_manual_config(space)
        prod_scores = {
            "default": prod.true_step(default),
            "expert": prod.true_step(expert),
            "sapphire": prod.true_step(space.project(res.best_config)),
        }
        out[shape] = {
            "test": {"default": res.default_value,
                     "expert": res.expert_value,
                     "sapphire": res.best_value},
            "product": prod_scores,
            "speedup_vs_default_test": res.speedup_vs_default,
            "speedup_vs_default_product":
                prod_scores["default"] / prod_scores["sapphire"],
            "speedup_vs_expert_test": res.speedup_vs_expert,
        }
        t = out[shape]
        print(f"{shape:12s} test: d={t['test']['default']:.3f} "
              f"e={t['test']['expert']:.3f} s={t['test']['sapphire']:.3f} "
              f"({t['speedup_vs_default_test']:.2f}x) | product: "
              f"d={prod_scores['default']:.3f} s={prod_scores['sapphire']:.3f} "
              f"({t['speedup_vs_default_product']:.2f}x)")

    avg_test = np.mean([out[s]["speedup_vs_default_test"] for s in WORKLOADS])
    avg_prod = np.mean([out[s]["speedup_vs_default_product"]
                        for s in WORKLOADS])
    avg_expert = np.mean([out[s]["speedup_vs_expert_test"] for s in WORKLOADS])
    print(f"\naverage speedup vs default: test {avg_test:.2f}×, "
          f"product {avg_prod:.2f}× (paper: 2.2×)")
    print(f"average speedup vs expert manual: {avg_expert:.2f}× (paper: 1.4×)")
    out["average"] = {"test": float(avg_test), "product": float(avg_prod),
                      "vs_expert": float(avg_expert)}
    save("fig5_effectiveness", out)
    return out


if __name__ == "__main__":
    run()

import os
import sys

if __name__ == "__main__":
    # standalone: claim the production device count before jax loads
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512"
                               ).strip()

"""Paper Fig. 5, strongest form: transfer validated on the COMPILED artifact.

fig5_effectiveness rescores recommendations on the multi-pod *analytic*
evaluator; this benchmark closes the loop on the **product cluster
proper**: the default config and the SAPPHIRE recommendation are applied
to the real train step, ``jit().lower().compile()``d on the production
mesh, and scored by the compiled roofline — the paper's "recommended
settings based on the test environment work similarly well in the large
product environment" claim, measured on the artifact that would actually
run.

Needs 512 placeholder devices => must own the process.  When invoked
from ``benchmarks.run`` (jax already initialized at 1 device) it
re-executes itself in a subprocess.
"""

import json
import subprocess


def _inner(quick: bool, arch: str, shape: str):
    """Hybrid tuning, the paper-faithful design: the paper's test cluster
    is a REAL (small) deployment, not a model — so the ranking phase uses
    the cheap analytic evaluator (hundreds of probes) and the BO phase
    probes the REAL compiled artifact (each probe = one XLA compile, the
    analogue of one Rados-bench run)."""
    from benchmarks.common import save
    from repro.configs import get_config
    from repro.core import bo, ranking
    from repro.core.bo import BOConfig
    from repro.core.costmodel import SINGLE_POD
    from repro.core.evaluators import AnalyticEvaluator, CompiledEvaluator
    from repro.core.knobs import clean_space
    from repro.models.config import SHAPES_BY_NAME

    model_cfg = get_config(arch)
    cell = SHAPES_BY_NAME[shape]
    space, pins, report = clean_space(model_cfg, cell, SINGLE_POD)

    # §3.3 ranking on the analytic test model (cheap)
    an = AnalyticEvaluator(model_cfg, cell, SINGLE_POD, noise_sigma=0.025,
                           seed=0)
    rk = ranking.rank(space, an, n_samples=120 if quick else 300, seed=0,
                      stability_rounds=0 if quick else 8)
    k = 6 if quick else 8
    sub = rk.top_space(k)
    base = space.default_config()

    # §3.4 BO against the COMPILED evaluator (expensive, deterministic)
    comp_ev = CompiledEvaluator(model_cfg, cell)

    def objective(c):
        full = dict(base)
        full.update(c)
        return comp_ev(space.project(full))

    n_iter = 6 if quick else 12
    best, best_v, trace, _ = bo.minimize(
        objective, sub,
        BOConfig(n_init=4 if quick else 6, n_iter=n_iter,
                 n_candidates=256, fit_steps=60, seed=0,
                 dynamic_boundary=False))
    default_v = comp_ev(space.project(base))
    speedup = default_v / best_v
    print(f"compiled default {default_v:.3f}s -> tuned {best_v:.3f}s "
          f"({speedup:.2f}x) after {comp_ev.calls} compiles")
    print("tuned knobs:", {kk: vv for kk, vv in best.items()})
    out = {"default_step_s": default_v, "tuned_step_s": best_v,
           "compiled_speedup": speedup, "tuned": best,
           "top_knobs": rk.top(k), "n_compiles": comp_ev.calls}
    save("fig5b_compiled_transfer", out)
    return out


def run(quick: bool = False, arch: str = "yi-6b", shape: str = "train_4k"):
    import jax  # noqa — probe whether this process already owns devices
    if len(jax.devices()) == 512:
        return _inner(quick, arch, shape)
    # jax initialized without the placeholder fleet: re-exec ourselves
    cmd = [sys.executable, "-m", "benchmarks.fig5b_compiled_transfer",
           "--arch", arch, "--shape", shape] + (["--quick"] if quick else [])
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    proc = subprocess.run(cmd, env=env, cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    if proc.returncode != 0:
        raise RuntimeError("fig5b subprocess failed")
    from benchmarks.common import ARTIFACTS
    return json.loads((ARTIFACTS / "fig5b_compiled_transfer.json").read_text())


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--shape", default="train_4k")
    a = ap.parse_args()
    _inner(a.quick, a.arch, a.shape)

"""Paper Fig. 6: parameter-importance curve — the drastic drop.

~300 noisy evaluations of random configurations, Lasso-path importance,
importances sorted descending.  The claim reproduced: only a small head of
the ~330-knob clean domain carries measurable importance.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import ascii_curve, save
from repro.configs import get_config
from repro.core import ranking
from repro.core.costmodel import SINGLE_POD
from repro.core.evaluators import AnalyticEvaluator
from repro.core.knobs import clean_space
from repro.models.config import SHAPES_BY_NAME


def run(quick: bool = False, arch: str = "yi-6b", shape: str = "train_4k"):
    cfg = get_config(arch)
    cell = SHAPES_BY_NAME[shape]
    space, _, report = clean_space(cfg, cell, SINGLE_POD)
    ev = AnalyticEvaluator(cfg, cell, SINGLE_POD, noise_sigma=0.025, seed=0)
    n = 150 if quick else 300
    rk = ranking.rank(space, ev, n_samples=n, seed=0)

    imp = np.sort(rk.importance)[::-1]
    total = imp.sum() or 1.0
    head_mass = float(imp[:16].sum() / total)
    inert = {k.name for k in space.knobs if k.inert}
    n_real_top16 = sum(1 for t in rk.top(16) if t not in inert)

    print(f"clean domain: {report['clean']} knobs "
          f"({report['washed']} washed, {report['pruned']} pruned)")
    print("sorted importance (log scale of head):")
    print(ascii_curve(np.log10(imp[:64] + 1e-9), label="log10 importance"))
    print(f"top-16 carries {head_mass:.1%} of total importance "
          f"({n_real_top16}/16 are ground-truth-live knobs)")
    print("top-8:", rk.top(8))

    out = {"n_samples": n, "clean_report": report,
           "sorted_importance": imp.tolist(), "top16": rk.top(16),
           "top16_mass": head_mass, "n_real_top16": n_real_top16}
    save("fig6_ranking", out)
    return out


if __name__ == "__main__":
    run()

"""Paper Fig. 7: tuning with top-64 / 32 / 16 knobs.

The claim: restricting BO to the top-16 knobs reaches the same optimum as
top-64 in ~30 % of the optimization cost.  Cost here = evaluation count ×
(per-evaluation time + recompile/redeploy surcharge for restart-required
knob changes), mirroring the paper's wall-clock framing where every probe
costs a cluster run.
"""

from __future__ import annotations

import time


from benchmarks.common import ascii_curve, save
from repro.configs import get_config
from repro.core import bo, ranking
from repro.core.costmodel import SINGLE_POD
from repro.core.evaluators import AnalyticEvaluator
from repro.core.knobs import clean_space
from repro.models.config import SHAPES_BY_NAME


def run(quick: bool = False, arch: str = "yi-6b", shape: str = "train_4k"):
    cfg = get_config(arch)
    cell = SHAPES_BY_NAME[shape]
    space, _, _ = clean_space(cfg, cell, SINGLE_POD)
    ev = AnalyticEvaluator(cfg, cell, SINGLE_POD, noise_sigma=0.025, seed=0)
    rk = ranking.rank(space, ev, n_samples=150 if quick else 300, seed=0)
    base = space.default_config()
    n_iter = 12 if quick else 40

    results = {}
    for k in (64, 32, 16):
        sub = rk.top_space(k)

        def objective(c):
            full = dict(base)
            full.update(c)
            return ev(space.project(full))

        t0 = time.monotonic()
        best, v, trace, _ = bo.minimize(
            objective, sub,
            bo.BOConfig(n_init=8, n_iter=n_iter, n_candidates=512,
                        fit_steps=80, seed=1))
        wall = time.monotonic() - t0
        true_best = ev.true_step(space.project({**base, **best}))
        results[k] = {"best_step_s": true_best, "wall_s": wall,
                      "trace": trace.best_values}
        print(f"top-{k:2d}: best (noise-free) {true_best:.4f}s "
              f"tuner wall {wall:5.1f}s")

    # the paper's framing (Fig. 7): TIME for top-16 to reach the optimum
    # that top-64 eventually finds.  On a real cluster each evaluation is a
    # ~30 min benchmark, so "time" == evaluation count.
    target = results[64]["best_step_s"] * 1.02     # within 2 %
    def evals_to(trace, tgt):
        for i, v in enumerate(trace):
            if v <= tgt:
                return i + 1
        return len(trace)
    e16 = evals_to(results[16]["trace"], target)
    e64 = len(results[64]["trace"])
    print(f"top-16 matches the top-64 optimum after {e16} evaluations "
          f"vs {e64} for top-64 ({e16 / e64:.0%} of the tuning cost; "
          f"paper: ~30 %)")
    print(f"top-16 final optimum is "
          f"{results[64]['best_step_s'] / results[16]['best_step_s']:.2f}× "
          f"better-or-equal (≥1 means the small domain lost nothing)")
    print(ascii_curve([-v for v in results[16]["trace"]],
                      label="top-16 best-so-far (−step_s)"))
    out = {str(k): dict(r) for k, r in results.items()}
    out["evals_to_match_top64"] = {"top16": e16, "top64": e64}
    save("fig7_topk_efficiency", out)
    return results


if __name__ == "__main__":
    run()

"""Two-fidelity successive halving: analytic screen -> promoted validation.

    PYTHONPATH=src python -m benchmarks.fig8_two_fidelity [--quick] [--compiled]

The experiment the ask/tell redesign exists for: the Experiment Unit mixes
evaluators of different fidelity inside one search.

* **full-fidelity arm** — GP-BO driven by ``Controller.run`` entirely on
  the HIGH-fidelity evaluator (the product cluster: noise-free multi-pod
  analytic model by default, the real compiled dry-run with ``--compiled``);
  every evaluation pays the expensive fidelity.
* **two-fidelity arm** — ``Controller.run_successive_halving``: each round
  asks a wide candidate batch, screens it on the CHEAP test-cluster
  backend (analytic, the paper's ±2.5 % noise), and promotes only the
  top scorers to the high-fidelity backend.  The strategy is told every
  candidate (promoted ones at their high-fidelity value), so the GP still
  learns from the whole screen.

Both fidelities live behind ONE evaluation service — an
``ImmediateEvaluationService({"screen": low, "promote": high})`` (with
``--compiled``, a ``FidelityRouter`` composing the immediate analytic
screen with a worker-pooled compiled promotion) — and the schedule routes
on the request's *fidelity field*, not on a choice of evaluator object.

Acceptance: the two-fidelity arm spends <= 50 % of the full arm's
high-fidelity evaluations and lands within the evaluator's noise (±5 %)
of the full-fidelity best.
"""

from __future__ import annotations

import argparse

from benchmarks.common import save
from repro.configs import get_config
from repro.core import ranking
from repro.core.controller import Controller, EvalDB
from repro.core.costmodel import MULTI_POD, SINGLE_POD
from repro.core.evaluators import AnalyticEvaluator, CompiledEvaluator
from repro.core.knobs import clean_space
from repro.core.service import (CallableServiceAdapter, FidelityRouter,
                                ImmediateEvaluationService,
                                WorkerPoolEvaluationService)
from repro.core.strategy import BOConfig, make_strategy
from repro.models.config import SHAPES_BY_NAME


def run(quick: bool = False, arch: str = "yi-6b", shape: str = "train_4k",
        compiled: bool = False, seed: int = 0):
    cfg = get_config(arch)
    cell = SHAPES_BY_NAME[shape]
    space, _, _ = clean_space(cfg, cell, SINGLE_POD)

    low = AnalyticEvaluator(cfg, cell, SINGLE_POD, noise_sigma=0.025,
                            seed=seed)
    if compiled:
        high = CompiledEvaluator(cfg, cell)
    else:
        # product-cluster stand-in: the multi-pod analytic model, noise-free
        high = AnalyticEvaluator(cfg, cell, MULTI_POD, noise_sigma=0.0)

    # rank on the cheap fidelity (as Sapphire would), search the top-8
    rk = ranking.rank(space, AnalyticEvaluator(cfg, cell, SINGLE_POD,
                                               noise_sigma=0.025, seed=9),
                      n_samples=80 if quick else 200, seed=9)
    sub = rk.top_space(8)
    _full = space.completer()      # non-top knobs pinned at defaults

    # -- full-fidelity arm: every BO evaluation on the expensive evaluator --
    n_init, n_iter = (6, 10) if quick else (8, 24)
    full_db = EvalDB()
    full_ctrl = Controller(high, full_db, tag="high").with_prepare(_full)
    full_strat = make_strategy(
        "bo", sub, cfg=BOConfig(n_init=n_init, n_iter=n_iter,
                                n_candidates=512, fit_steps=80, seed=seed))
    full_ctrl.run(full_strat)
    best_full_sub, best_full = full_strat.best()
    n_high_full = len(full_db)

    # -- two-fidelity arm: one service, routed on the fidelity field ---------
    rounds, screen, promote = (4, 12, 2) if quick else (8, 16, 2)
    if compiled:
        # mixed execution models: immediate analytic screen + a
        # worker-pool of compiles, composed behind one service
        svc = FidelityRouter({
            "screen": CallableServiceAdapter(low),
            "promote": WorkerPoolEvaluationService(high, max_workers=4)})
    else:
        svc = ImmediateEvaluationService({"screen": low, "promote": high})
    sh_db = EvalDB()
    sh_ctrl = Controller(svc, sh_db).with_prepare(_full)
    sh_strat = make_strategy(
        "bo", sub,
        cfg=BOConfig(n_init=screen, n_iter=(rounds - 1) * screen,
                     batch_size=screen, warm_start=True,
                     n_candidates=512, fit_steps=80, seed=seed))
    best_sh_cfg, best_sh, schedule = sh_ctrl.run_successive_halving(
        sh_strat, rounds=rounds, screen=screen, promote=promote)
    n_high_sh = sum(s["promoted"] for s in schedule)

    # score both recommendations noise-free on the expensive fidelity
    true_full = high.true_step(_full(best_full_sub))
    true_sh = high.true_step(_full(best_sh_cfg))   # best promoted sub-config
    rel = true_sh / true_full - 1.0
    frac = n_high_sh / max(n_high_full, 1)

    print(f"\n=== two-fidelity successive halving ({arch} × {shape}, "
          f"high={'compiled' if compiled else 'multi-pod analytic'}) ===")
    print(f"  full fidelity : best {true_full:.4f}s  "
          f"high-fid evals {n_high_full}")
    print(f"  two-fidelity  : best {true_sh:.4f}s  "
          f"high-fid evals {n_high_sh}  "
          f"(+{sum(s['screened'] for s in schedule)} cheap screens)")
    print(f"  high-fid cost : {100 * frac:.0f}% of full "
          f"({'PASS' if frac <= 0.5 else 'ABOVE'} the 50% target)")
    print(f"  best delta    : {100 * rel:+.2f}% "
          f"({'within' if abs(rel) <= 0.05 else 'OUTSIDE'} ±5% noise)")

    payload = {
        "arch": arch, "shape": shape, "seed": seed, "compiled": compiled,
        "best_full": true_full, "best_sh": true_sh, "rel_delta": rel,
        "high_evals_full": n_high_full, "high_evals_sh": n_high_sh,
        "high_frac": frac,
        "screens": sum(s["screened"] for s in schedule),
        "schedule": [{"round": s["round"], "screened": s["screened"],
                      "promoted": s["promoted"]} for s in schedule],
    }
    save("fig8_two_fidelity", payload)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--compiled", action="store_true",
                    help="use the real compiled dry-run as the high "
                         "fidelity (slow: one XLA compile per promotion)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    run(quick=args.quick, arch=args.arch, shape=args.shape,
        compiled=args.compiled, seed=args.seed)


if __name__ == "__main__":
    main()

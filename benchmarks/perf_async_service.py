"""Async vs synchronous experiment loop against a latency-bound service.

    PYTHONPATH=src python -m benchmarks.perf_async_service [--tiny]

The experiment the service API exists for: on a real test cluster each
benchmark takes seconds-to-minutes of *wall* time, and the run's critical
path is evaluation latency, not optimizer math.  Both arms drive the SAME
GP-BO strategy budget through the SAME worker-pool service over a
latency-simulating evaluator (the analytic cost model plus a deterministic
per-config sleep, heterogeneous across configs — real benchmarks do not
all take equally long):

* **sync arm**  — ``Controller.run``: a barrier per round; every round
  waits for the *slowest* config in its batch, and the GP refit runs with
  the cluster idle;
* **async arm** — ``Controller.run_async``: keeps ``max_in_flight`` probes
  in the pool, tells the strategy completions as they stream back out of
  order, and refits while work is still in flight — stragglers never idle
  the workers and the refit never idles the cluster.

Acceptance target: >= 1.5x wall-clock at the SAME evaluation budget and
seed, with the async best-found within the evaluator's noise (±5 %) of the
sync one.
"""

from __future__ import annotations

import argparse
import hashlib
import time

from benchmarks.common import Timer, save


class LatencyEvaluator:
    """Analytic evaluator wrapped with a deterministic per-config sleep:
    latency is drawn from [lo, hi) by config hash, so both arms pay the
    same latency for the same config and the comparison is pure loop
    structure.  Thread-safe: the underlying analytic scoring runs under a
    lock (per-call noise indexing stays sequential regardless of worker
    interleaving); the sleep — the part that models the cluster — runs
    outside it."""

    def __init__(self, analytic, lo: float, hi: float):
        import threading

        self.analytic = analytic
        self.lo, self.hi = lo, hi
        self._lock = threading.Lock()

    def latency(self, cfg) -> float:
        key = repr(sorted((k, str(v)) for k, v in cfg.items()))
        h = int.from_bytes(hashlib.blake2s(key.encode()).digest()[:4],
                           "little")
        return self.lo + (self.hi - self.lo) * (h / 2**32)

    def __call__(self, cfg) -> float:
        time.sleep(self.latency(cfg))
        with self._lock:
            return float(self.analytic(cfg))

    def true_step(self, cfg) -> float:
        return self.analytic.true_step(cfg)


def _make(args, seed_salt: int = 0):
    from repro.configs import get_config
    from repro.core.controller import Controller, EvalDB
    from repro.core.costmodel import SINGLE_POD
    from repro.core.evaluators import AnalyticEvaluator
    from repro.core.knobs import clean_space
    from repro.core.service import WorkerPoolEvaluationService
    from repro.models.config import SHAPES_BY_NAME

    cfg = get_config(args.arch)
    cell = SHAPES_BY_NAME[args.shape]
    space, _, _ = clean_space(cfg, cell, SINGLE_POD)
    analytic = AnalyticEvaluator(cfg, cell, SINGLE_POD, noise_sigma=0.025,
                                 seed=args.seed + seed_salt)
    lat = LatencyEvaluator(analytic, args.lat_lo, args.lat_hi)
    svc = WorkerPoolEvaluationService(lat, max_workers=args.workers)
    return space, lat, svc, Controller(svc, EvalDB())


def _strategy(args, space):
    from repro.core.strategy import BOConfig, make_strategy
    return make_strategy(
        "bo", space,
        cfg=BOConfig(n_init=args.n_init, n_iter=args.n_iter,
                     batch_size=args.batch, warm_start=True,
                     n_candidates=args.n_candidates,
                     fit_steps=args.fit_steps, seed=args.seed))


def run_sync(args):
    space, lat, svc, ctrl = _make(args)
    strat = _strategy(args, space)
    with svc, Timer() as t:
        ctrl.with_tag("sync").run(strat)
    best_c, _ = strat.best()
    return lat.true_step(best_c), len(strat.trace.values), t.wall_s


def run_async(args):
    space, lat, svc, ctrl = _make(args)
    strat = _strategy(args, space)
    min_ask = max(args.workers // 2, 1)
    with svc, Timer() as t:
        # min_ask amortizes each GP refit over ~half a pool of
        # completions; the extra in-flight depth keeps a short submission
        # queue behind the workers, so every worker stays busy *through*
        # the refit — the refit overlaps evaluation instead of gating it
        ctrl.with_tag("async").run_async(
            strat, batch_size=args.batch,
            max_in_flight=args.workers + min_ask, min_ask=min_ask)
    best_c, _ = strat.best()
    return lat.true_step(best_c), len(strat.trace.values), t.wall_s


def warm_jit_caches(args, space):
    """Pre-compile every jit entry both arms hit — the GP fit scan (cold
    and warm-started step counts), the posterior/EI build over the
    candidate pool, and the noise draw at every wave width the async loop
    can produce — so the timings compare loop structure, not which arm
    paid XLA compile time first."""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import evaluators, gp
    from repro.core.strategy import BOConfig

    rng = np.random.default_rng(0)
    d = len(space)
    pad_to = gp._bucket(args.n_init + args.n_iter)
    cfg = BOConfig(fit_steps=args.fit_steps)
    warm_steps = (cfg.fit_steps_warm if cfg.fit_steps_warm is not None
                  else max(cfg.fit_steps // 3, 20))
    x = rng.random((4, d)).astype(np.float32)
    y = rng.random(4)
    state = None
    for steps in sorted({args.fit_steps, warm_steps}):
        state = gp.fit(x, y, steps=steps, pad_to=pad_to)
    n_cand = args.n_candidates + 256 + 5 * d     # pool + local + sweeps
    gp.expected_improvement(state, rng.random((n_cand, d)).astype(np.float32),
                            0.0)
    for m in set(range(1, max(args.workers, args.batch, args.n_init) + 1)):
        evaluators._lognoise(jnp.zeros((m, 2), jnp.uint32), 0.025)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--n-init", type=int, default=8)
    ap.add_argument("--n-iter", type=int, default=72)
    ap.add_argument("--n-candidates", type=int, default=512)
    ap.add_argument("--fit-steps", type=int, default=60)
    ap.add_argument("--lat-lo", type=float, default=0.15,
                    help="fastest simulated benchmark, seconds")
    ap.add_argument("--lat-hi", type=float, default=1.0,
                    help="slowest simulated benchmark, seconds")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke budgets: exercises submit/poll/tell "
                         "streaming end to end in well under a minute; the "
                         "1.5x target is only meaningful at full budgets")
    args = ap.parse_args(argv)
    if args.tiny:
        args.n_init, args.n_iter = 4, 8
        args.batch, args.workers = 4, 4
        args.n_candidates, args.fit_steps = 64, 20
        args.lat_lo, args.lat_hi = 0.02, 0.1

    budget = args.n_init + args.n_iter
    from repro.configs import get_config
    from repro.core.costmodel import SINGLE_POD
    from repro.core.knobs import clean_space
    from repro.models.config import SHAPES_BY_NAME
    space, _, _ = clean_space(get_config(args.arch),
                              SHAPES_BY_NAME[args.shape], SINGLE_POD)
    t0 = time.monotonic()
    warm_jit_caches(args, space)
    print(f"jit warm-up: {time.monotonic() - t0:.1f}s (shared by both arms)")

    best_s, n_s, wall_s = run_sync(args)
    best_a, n_a, wall_a = run_async(args)
    assert n_s == n_a == budget, (n_s, n_a, budget)

    speedup = wall_s / wall_a
    rel = best_a / best_s - 1.0
    print(f"\n=== async evaluation service ({args.arch} × {args.shape}, "
          f"budget {budget} evals, {args.workers} workers, "
          f"latency {args.lat_lo:.2f}-{args.lat_hi:.2f}s) ===")
    print(f"  sync  (Controller.run)      : wall {wall_s:6.2f}s  "
          f"best {best_s:.4f}s")
    print(f"  async (Controller.run_async): wall {wall_a:6.2f}s  "
          f"best {best_a:.4f}s")
    print(f"\n  wall-clock speedup : {speedup:.2f}x "
          f"({'PASS' if speedup >= 1.5 else 'BELOW'} the 1.5x target)")
    verdict = ("within ±5% noise" if abs(rel) <= 0.05 else
               "better than sync" if rel < 0 else "OUTSIDE ±5% noise")
    print(f"  best-found delta   : {100 * rel:+.2f}% ({verdict})")

    payload = {
        "arch": args.arch, "shape": args.shape, "seed": args.seed,
        "budget_evals": budget, "workers": args.workers,
        "latency_s": [args.lat_lo, args.lat_hi],
        "wall_s_sync": wall_s, "wall_s_async": wall_a, "speedup": speedup,
        "best_sync": best_s, "best_async": best_a, "rel_best_delta": rel,
    }
    save("perf_async_service", payload)
    return payload


def run(quick: bool = False):
    """benchmarks.run entry point."""
    main(["--tiny"] if quick else [])


if __name__ == "__main__":
    main()

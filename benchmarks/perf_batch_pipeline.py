"""Batched vs sequential evaluation pipeline: Sapphire.tune() wall-clock.

    PYTHONPATH=src python -m benchmarks.perf_batch_pipeline \
        [--arch yi-6b] [--shape train_4k] [--batch 8] [--seed 3]

Runs the full tuner twice at the SAME evaluation budget and seed:

  * sequential — ``batch_size=1``: one config per Experiment-Unit call,
    one GP refit per BO evaluation (the paper's loop);
  * batched    — ``batch_size=q``: ranking scored in vmapped chunks,
    constant-liar q-EI probes per GP refit, warm-started hyperparameters,
    whole batches appended to the EvalDB.

Because the ranking values are bit-identical between the two runs (the
noise keys are indexed per evaluation, not per call pattern), both arms
search the same top-K subspace from the same initial design — the only
difference is how the budget is spent.  jit compilation is warmed up
before timing (both arms share every compiled shape: the padded GP size
is pinned from the budget), so the numbers compare steady-state pipeline
cost, not XLA compile time.

Acceptance target: >= 3x wall-clock speedup with the batched best-found
step time within the evaluator's noise (±5 %) of the sequential one.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import Timer, save


def warm_jit_caches(args, fit_steps, kernel: str = "matern52"):
    """Pre-compile every jit entry both arms will hit: the GP fit scan
    (each steps value), the posterior build, acquisition over the
    candidate pool, and the ranking Lasso path (its shapes come from the
    real clean space, so rank on throwaway values — no evaluations)."""
    from repro.configs import get_config
    from repro.core import gp, knobs as knobmod, ranking
    from repro.core.costmodel import SINGLE_POD
    from repro.core.sampling import latin_hypercube
    from repro.models.config import SHAPES_BY_NAME

    rng = np.random.default_rng(0)
    d = args.top_k
    pad_to = gp._bucket(args.n_init + args.n_iter)
    n_cand = args.n_candidates + 256 + 5 * d     # pool + local + sweeps
    x = rng.random((4, d)).astype(np.float32)
    y = rng.random(4)
    state = None
    for steps in sorted(set(fit_steps)):
        state = gp.fit(x, y, kernel, steps=steps, pad_to=pad_to)
    xq = rng.random((n_cand, d)).astype(np.float32)
    gp.expected_improvement(state, xq, 0.0, kernel)

    space, _, _ = knobmod.clean_space(get_config(args.arch),
                                      SHAPES_BY_NAME[args.shape], SINGLE_POD)
    samples = latin_hypercube(space, args.rank_samples, seed=0)
    ranking.rank(space, None, samples=samples,
                 values=rng.random(len(samples)).tolist())

    # noise-draw shapes: rank chunks, the q-batch, init batch, singletons
    import jax.numpy as jnp
    from repro.core import evaluators
    shapes = {1, args.batch, args.n_init, min(64, args.rank_samples)}
    if args.rank_samples % 64:
        shapes.add(args.rank_samples % 64)
    for m in shapes:
        evaluators._lognoise(jnp.zeros((m, 2), jnp.uint32), 0.025)


def run_arm(args, batch_size: int):
    from repro.core.bo import BOConfig
    from repro.core.tuner import Sapphire
    # batch_size=1 is the classic pipeline: a full fit_steps GP refit
    # before every single evaluation (what the pre-batch code did);
    # the q-batch arm warm-starts hyperparameters across rounds.
    bo_cfg = BOConfig(n_init=args.n_init, n_iter=args.n_iter,
                      n_candidates=args.n_candidates, fit_steps=args.fit_steps,
                      warm_start=batch_size > 1, seed=args.seed)
    s = Sapphire(arch=args.arch, shape=args.shape, top_k=args.top_k,
                 n_rank_samples=args.rank_samples, batch_size=batch_size,
                 bo_config=bo_cfg, seed=args.seed)
    with Timer() as t:
        res = s.tune()
    return res, t.wall_s


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--top-k", type=int, default=16)
    ap.add_argument("--rank-samples", type=int, default=300)
    ap.add_argument("--n-init", type=int, default=8)
    ap.add_argument("--n-iter", type=int, default=48)
    ap.add_argument("--n-candidates", type=int, default=2048)
    ap.add_argument("--fit-steps", type=int, default=150)
    ap.add_argument("--no-warmup", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke budgets: exercises the whole batched "
                         "pipeline in ~a minute; the 3x wall-clock target "
                         "is only meaningful at full budgets")
    args = ap.parse_args(argv)
    if args.tiny:
        args.rank_samples = 40
        args.n_init = 4
        args.n_iter = 8
        args.batch = 4
        args.n_candidates = 128
        args.fit_steps = 30

    if not args.no_warmup:
        from repro.core.bo import BOConfig
        warm = BOConfig(fit_steps=args.fit_steps)
        warm_steps = (warm.fit_steps_warm if warm.fit_steps_warm is not None
                      else max(warm.fit_steps // 3, 20))
        t0 = time.monotonic()
        warm_jit_caches(args, (args.fit_steps, warm_steps))
        print(f"jit warm-up: {time.monotonic() - t0:.1f}s (shared by both arms)")

    res_b, wall_b = run_arm(args, args.batch)
    res_s, wall_s = run_arm(args, 1)

    speedup = wall_s / wall_b
    rel_best = res_b.best_value / res_s.best_value - 1.0
    # tuning budget (rank + BO); the default/expert report probes are extra
    budget = args.rank_samples + args.n_init + args.n_iter

    print(f"\n=== batched evaluation pipeline ({args.arch} × {args.shape}, "
          f"budget {budget} evals, seed {args.seed}) ===")
    for name, res, wall in (("sequential (q=1)", res_s, wall_s),
                            (f"batched   (q={args.batch})", res_b, wall_b)):
        print(f"  {name:18s} wall {wall:7.2f}s  best {res.best_value:.4f}s"
              f"  evals {res.n_evaluations}"
              f"  speedup_vs_default {res.speedup_vs_default:.2f}x")
    print(f"\n  wall-clock speedup : {speedup:.2f}x "
          f"({'PASS' if speedup >= 3.0 else 'BELOW'} the 3x target)")
    verdict = ("within ±5% noise" if abs(rel_best) <= 0.05 else
               "better than sequential" if rel_best < 0 else
               "OUTSIDE ±5% noise")
    print(f"  best-found delta   : {100 * rel_best:+.2f}% ({verdict})")

    payload = {
        "arch": args.arch, "shape": args.shape, "seed": args.seed,
        "batch": args.batch, "budget_evals": budget,
        "wall_s_sequential": wall_s, "wall_s_batched": wall_b,
        "speedup": speedup,
        "best_sequential": res_s.best_value, "best_batched": res_b.best_value,
        "rel_best_delta": rel_best,
        "evals_sequential": res_s.n_evaluations,
        "evals_batched": res_b.n_evaluations,
        "boundary_events_sequential": len(res_s.trace.boundary_events),
        "boundary_events_batched": len(res_b.trace.boundary_events),
    }
    save("perf_batch_pipeline", payload)
    return payload


def run(quick: bool = False):
    """benchmarks.run entry point."""
    argv = ["--rank-samples", "120", "--n-iter", "24"] if quick else []
    main(argv)


if __name__ == "__main__":
    main()

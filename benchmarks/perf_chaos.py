"""Chaos gate: seeded fault injection over the worker-pool service.

    PYTHONPATH=src python -m benchmarks.perf_chaos [--tiny]

Real benchmark clusters flake: workers die mid-probe, benchmarks hang,
transient I/O errors surface as failed measurements.  The resilience
layer (``RetryPolicy`` + ``ResilientService``) is supposed to make the
tuning loop *indifferent* to transient faults — same search trajectory,
same best-found config, bounded extra wall-clock — and the seeded chaos
harness (``FaultPlan`` + ``FaultInjectingService``) is how we prove it
without a flaky cluster: every fault is a deterministic function of
(plan seed, request seed, occurrence), so a chaotic run is exactly
replayable.

Both arms run the identical BO probe schedule against the analytic
evaluator behind a real ``WorkerPoolEvaluationService``; the chaotic arm
injects a **20 % transient-fault rate** (plus worker deaths) between the
controller and the workers.  Three hard gates, asserted in ``--tiny``
(CI) too:

* **bit-identity** — on a single-worker barrier cadence the chaotic
  trace equals the fault-free trace *bit for bit* at equal seeds
  (injected faults never touch the backend, retries reuse the original
  measurement seed, ``n_evaluations`` never inflates);
* **convergence** — on the multi-worker pool the chaotic arm's
  best-found true step time matches the fault-free arm within
  ``QUALITY_TOL`` (noise tolerance);
* **wall-clock** — the chaotic arm finishes within ``WALL_GATE`` ×
  the fault-free arm (retried transients cost dispatch overhead, not
  repeated benchmark runs).
"""

from __future__ import annotations

import argparse
import sys
import threading
import time

import numpy as np

from benchmarks.common import save
from repro.configs import get_config
from repro.core.controller import Controller, EvalDB
from repro.core.costmodel import SINGLE_POD
from repro.core.evaluators import AnalyticEvaluator
from repro.core.faults import FaultInjectingService, FaultPlan
from repro.core.knobs import clean_space
from repro.core.resilience import RetryPolicy
from repro.core.service import WorkerPoolEvaluationService
from repro.core.strategy import BOConfig, make_strategy

NOISE_SIGMA = 0.02       # multiplicative measurement noise (paper: 2.5 %)
TRANSIENT_RATE = 0.2     # the ISSUE's 20 % injected transient-fault rate
DEATH_RATE = 0.05        # plus occasional worker deaths
WALL_GATE = 1.3          # chaotic wall-clock <= 1.3x fault-free
QUALITY_TOL = 1.05       # chaotic best-found true step within 5 % of clean
LATENCY_S = 0.02         # per-probe benchmark latency (makes wall real)


class SeededBench:
    """Seed-deterministic noisy benchmark over the analytic evaluator:
    the measured value depends only on (config, request.seed), so a
    retried probe reproducing the original seed reproduces the original
    measurement — the property the bit-identity gate rests on."""

    wants_request = True

    def __init__(self, model_cfg, cell, latency_s: float = 0.0):
        self.ev = AnalyticEvaluator(model_cfg, cell, noise_sigma=0.0)
        self.latency_s = latency_s
        self.calls = 0
        self._lock = threading.Lock()

    def __call__(self, cfg, request=None):
        if self.latency_s:
            time.sleep(self.latency_s)
        with self._lock:
            self.calls += 1
        seed = 0 if request is None or request.seed is None \
            else request.seed
        rng = np.random.default_rng(seed)
        return self.ev.true_step(cfg) * (
            1.0 + NOISE_SIGMA * rng.standard_normal())

    def true_step(self, cfg):
        return self.ev.true_step(cfg)


def _arm(space, model_cfg, cell, plan, probes, seed, workers,
         latency_s=LATENCY_S):
    """One tuning run behind a worker pool, optionally under a chaos
    plan.  Returns (trace values, best true step, wall seconds, stats)."""
    bench = SeededBench(model_cfg, cell, latency_s=latency_s)
    inner = WorkerPoolEvaluationService(bench, max_workers=workers)
    svc = inner if plan is None else FaultInjectingService(inner, plan)
    ctrl = Controller(svc, EvalDB(), tag="chaos", seed=seed,
                      resilience=RetryPolicy(max_attempts=8,
                                             backoff_s=0.0))
    n_init = max(probes // 2, 6)
    strat = make_strategy("bo", space, budget=probes, seed=seed,
                          cfg=BOConfig(n_init=n_init,
                                       n_iter=probes - n_init,
                                       fit_steps=30))
    width = 4
    t0 = time.monotonic()
    # barrier cadence: whole waves in, whole waves told — the replayable
    # schedule (and on one worker, a fully deterministic one)
    trace = ctrl.run_async(strat, batch_size=width, max_in_flight=width,
                           min_ask=width)
    wall = time.monotonic() - t0
    best_cfg, _ = trace.best
    resilient = ctrl.service                    # ResilientService
    stats = {"backend_calls": bench.calls,
             "retries": getattr(resilient, "retries", 0),
             "exhausted": getattr(resilient, "exhausted", 0),
             "injected": dict(getattr(svc, "injected", {})),
             "n_evaluations": len(trace.values)}
    try:
        return list(trace.values), bench.true_step(best_cfg), wall, stats
    finally:
        svc.close()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="one seed, smaller probe budget (CI smoke; all "
                         "three chaos gates are asserted here too)")
    args = ap.parse_args(argv)

    probes = 16 if args.tiny else 24
    seeds = (0,) if args.tiny else (0, 1, 2)

    model_cfg = get_config("yi-6b")
    cell = None
    from repro.models.config import SHAPES_BY_NAME
    cell = SHAPES_BY_NAME["train_4k"]
    space, _, _ = clean_space(model_cfg, cell, SINGLE_POD)

    plan = FaultPlan(transient_rate=TRANSIENT_RATE, death_rate=DEATH_RATE,
                     seed=11)

    rows = []
    for seed in seeds:
        # -- bit-identity: single worker, deterministic barrier ---------
        clean_tr, _, _, _ = _arm(space, model_cfg, cell, None, probes,
                                 seed, workers=1, latency_s=0.0)
        chaos_tr, _, _, cs = _arm(space, model_cfg, cell, plan, probes,
                                  seed, workers=1, latency_s=0.0)
        bit_identical = clean_tr == chaos_tr
        # -- convergence + wall-clock: the real multi-worker pool -------
        _, f_best, f_wall, f_stats = _arm(space, model_cfg, cell, None,
                                          probes, seed, workers=4)
        _, c_best, c_wall, c_stats = _arm(space, model_cfg, cell, plan,
                                          probes, seed, workers=4)
        ratio = c_wall / f_wall
        quality = c_best / f_best
        rows.append({"seed": seed, "probes": probes,
                     "bit_identical": bit_identical,
                     "clean_best": f_best, "chaos_best": c_best,
                     "quality_ratio": quality,
                     "clean_wall_s": f_wall, "chaos_wall_s": c_wall,
                     "wall_ratio": ratio,
                     "injected": c_stats["injected"],
                     "retries": c_stats["retries"],
                     "backend_calls": c_stats["backend_calls"]})
        print(f"seed {seed}: bit-identical={bit_identical} | clean best "
              f"{f_best:.4f}s vs chaos {c_best:.4f}s "
              f"(x{quality:.3f}) | wall x{ratio:.2f} | injected "
              f"{c_stats['injected']} retries {c_stats['retries']}",
              flush=True)

        # the chaos machinery actually fired, and the budget held
        assert sum(cs["injected"].values()) > 0, "no faults injected"
        assert cs["retries"] > 0, "no retries exercised"
        assert cs["n_evaluations"] == probes, (
            f"retries inflated n_evaluations: {cs['n_evaluations']} "
            f"!= {probes}")
        # injected faults never touch the backend: chaotic backend
        # effort equals the probe count exactly (successful attempts)
        assert cs["backend_calls"] == probes

    worst_quality = max(r["quality_ratio"] for r in rows)
    worst_wall = max(r["wall_ratio"] for r in rows)
    all_bit = all(r["bit_identical"] for r in rows)
    print(f"\nbit-identity {all_bit}, worst quality ratio "
          f"{worst_quality:.4f} (gate <= {QUALITY_TOL}), worst wall "
          f"ratio {worst_wall:.2f} (gate <= {WALL_GATE})")

    save("perf_chaos", {
        "transient_rate": TRANSIENT_RATE, "death_rate": DEATH_RATE,
        "noise_sigma": NOISE_SIGMA, "wall_gate": WALL_GATE,
        "quality_tol": QUALITY_TOL, "bit_identical": all_bit,
        "worst_quality_ratio": worst_quality,
        "worst_wall_ratio": worst_wall, "runs": rows})

    assert all_bit, (
        "chaotic trace diverged from the fault-free trace at equal "
        "seeds — retries are not replaying the original measurements")
    assert worst_quality <= QUALITY_TOL, (
        f"chaotic best-found is {worst_quality:.4f}x the fault-free "
        f"arm's (gate: <= {QUALITY_TOL})")
    assert worst_wall <= WALL_GATE, (
        f"chaotic wall-clock is {worst_wall:.2f}x the fault-free arm's "
        f"(gate: <= {WALL_GATE})")
    print(f"gates passed: {TRANSIENT_RATE:.0%} transient faults cost "
          f"x{worst_wall:.2f} wall-clock and changed nothing else")
    return 0


def run(quick: bool = False):
    """benchmarks.run entry point."""
    main(["--tiny"] if quick else [])


if __name__ == "__main__":
    sys.exit(main())

"""Device-resident q-EI batch selection vs the legacy per-pick rebuild.

    PYTHONPATH=src python -m benchmarks.perf_gp_ask [--tiny]

The proposer is the tuner's own hot path: every BO round re-fits a GP and
selects a constant-liar q-EI batch, and past a few hundred evaluations
the *proposer* — not the cluster — bottlenecks the experiment loop.  Two
arms, two claims:

* **select** — the legacy ``strategy._select_batch`` loop (q acquisition
  jit dispatches, q host argmax round trips, q full O(n³) ``condition``
  Cholesky rebuilds) against the device-resident ``gp.select_batch``
  (ONE compiled ``lax.scan``: EI scoring, masked argmax, O(n²)
  incremental-Cholesky fantasy appends).  Both arms pick from the same
  pool under the same posterior and must agree pick for pick.
  Acceptance: >= 3x wall-clock at n >= 128, q = 8.

* **submission** — ``Controller.run_async`` with ``BOConfig.refit_async``:
  the marginal-likelihood refit runs on a background executor over a
  trace snapshot, so the ask-side submission latency (measured by the
  controller's ``on_ask`` hook) is independent of ``fit_steps`` — the
  cluster never waits for Adam.
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import Timer, save
from repro.core import gp
from repro.core.controller import Controller, EvalDB
from repro.core.space import Knob, Space
from repro.core.strategy import BOConfig, BOStrategy, _select_batch


def _problem(n: int, d: int, m_cand: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    x = rng.random((n, d))
    y = (np.sin(3 * x[:, 0]) + (x[:, 1] - 0.4) ** 2
         + 0.05 * rng.normal(size=n))
    cand = rng.random((m_cand, d))
    return x, y, cand


def bench_select(n: int, d: int, q: int, m_cand: int, repeats: int,
                 fantasy: str = "liar") -> dict:
    x, y, cand = _problem(n, d, m_cand)
    pad_to = gp._bucket(n + q)
    st = gp.fit(x, y, steps=60, pad_to=pad_to)
    cfg = BOConfig(fantasy=fantasy)
    best_y = float(np.min(y))
    c32 = cand.astype(np.float32)
    y_raw = np.zeros(int(st.x.shape[0]), np.float32)
    y_raw[:n] = y

    def legacy():
        return _select_batch(st, cand, best_y, q, cfg, x, y, pad_to)

    def device():
        return np.asarray(gp.select_batch(st, c32, y_raw, n, best_y, q,
                                          fantasy=fantasy))

    picks_l = legacy()                       # warm both jit caches before
    idx = device()                           # timing anything
    same = np.array_equal(np.stack(picks_l),
                          np.stack([cand[int(i)] for i in idx]))

    def best_block(fn):
        # best-of-blocks: robust to CPU-contention spikes on shared boxes
        best = float("inf")
        for _ in range(4):
            with Timer() as t:
                for _ in range(repeats):
                    fn()
            best = min(best, t.wall_s / repeats)
        return best

    t_l = best_block(legacy)
    t_d = best_block(device)
    speedup = t_l / max(t_d, 1e-12)
    print(f"  n={n} q={q} pool={len(cand)} fantasy={fantasy}: "
          f"legacy {t_l * 1e3:7.2f} ms/batch, "
          f"device {t_d * 1e3:7.2f} ms/batch  "
          f"-> {speedup:.1f}x  (same picks: {same})")
    return {"n": n, "q": q, "pool": len(cand), "fantasy": fantasy,
            "legacy_ms": t_l * 1e3, "device_ms": t_d * 1e3,
            "speedup": speedup, "same_picks": bool(same)}


def _tuning_space(d: int) -> Space:
    return Space(tuple(Knob(f"x{i}", "float", 0.5, lo=0.0, hi=1.0)
                       for i in range(d)))


def bench_overlap(fit_steps: int, n_init: int, n_iter: int, q: int,
                  n_candidates: int, latency: float, refit_async: bool,
                  d: int = 6, label: bool = True) -> dict:
    """run_async wall-clock against a latency-bound worker pool, sync-fit
    vs refit_async at heavy ``fit_steps``.

    The sync arm pays ``fit + evaluate`` per round — the cluster idles
    for every Adam refit.  With ``refit_async`` the refit runs on the
    background executor *while the wave is in flight* (kicked after the
    selection's device work, so on one shared XLA device it queues behind
    this round's selection, not in front of the next), collapsing the
    round to ~max(fit, evaluate).  Per-ask submission latencies from the
    ``on_ask`` hook ride along; the strict no-blocking property is pinned
    by the monkeypatched-delay test in tests/test_strategy.py (a real fit
    on the same XLA device still *contends* for it even off-thread)."""
    import time

    from repro.core.service import WorkerPoolEvaluationService

    space = _tuning_space(d)

    def objective(c):
        time.sleep(latency)
        u = np.array([c[f"x{i}"] for i in range(d)])
        return float(np.sum((u - 0.3) ** 2))

    cfg = BOConfig(n_init=n_init, n_iter=n_iter, batch_size=q,
                   n_candidates=n_candidates, fit_steps=fit_steps,
                   refit_async=refit_async)
    strat = BOStrategy(space, cfg)
    lat: list = []
    with WorkerPoolEvaluationService(objective, max_workers=q) as svc:
        with Timer() as t:
            Controller(svc, EvalDB()).run_async(
                strat, max_in_flight=q, min_ask=q,
                on_ask=lambda k, s: lat.append(s))
    strat.close()
    steady = sorted(lat)[:max(len(lat) - 2, 1)]
    med = float(np.median(steady))
    if label:
        arm = "refit_async" if refit_async else "sync-fit   "
        print(f"  {arm} fit_steps={fit_steps:4d}: wall {t.wall_s:6.2f} s, "
              f"median steady-state ask {med * 1e3:7.2f} ms "
              f"({len(lat)} asks)")
    return {"fit_steps": fit_steps, "refit_async": refit_async,
            "wall_s": t.wall_s, "median_ask_s": med, "asks": len(lat)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke budgets; no speedup assertion")
    args = ap.parse_args(argv)

    if args.tiny:
        n, d, q, m_cand, repeats = 48, 4, 4, 256, 5
        fit_steps = 60
        sub = dict(n_init=8, n_iter=16, q=4, n_candidates=64,
                   latency=0.05)
    else:
        n, d, q, m_cand, repeats = 256, 8, 8, 2048, 10
        fit_steps = 1000
        sub = dict(n_init=16, n_iter=32, q=8, n_candidates=512,
                   latency=0.25)

    print("== q-EI batch selection: per-pick rebuild vs single-jit scan")
    select = [bench_select(n, d, q, m_cand, repeats, fantasy=f)
              for f in ("liar", "believer")]

    print("== run_async round overlap: sync fit vs background refit "
          f"(fit_steps={fit_steps})")
    # warmup run compiles the fit/selection programs at these exact
    # shapes (pad_to is pinned by n_init+n_iter) so neither timed arm
    # pays compilation
    bench_overlap(fit_steps, refit_async=False, label=False,
                  **{**sub, "latency": 0.0})
    overlap = [bench_overlap(fit_steps, refit_async=r, **sub)
               for r in (False, True)]
    sync_wall, async_wall = overlap[0]["wall_s"], overlap[1]["wall_s"]
    print(f"  background refit: {sync_wall:.2f} s -> {async_wall:.2f} s "
          f"({sync_wall / async_wall:.2f}x) at equal budget")

    save("perf_gp_ask", {"select": select, "overlap": overlap,
                         "overlap_speedup": sync_wall / async_wall})

    for r in select:
        assert r["same_picks"], "device picks diverged from the rebuild loop"
    if not args.tiny:
        worst = min(r["speedup"] for r in select)
        assert worst >= 3.0, f"select_batch speedup {worst:.2f}x < 3x target"
        # the refit is off the submission path: rounds cost
        # ~max(fit, evaluate) instead of fit + evaluate
        assert async_wall < sync_wall * 0.85, (
            f"refit_async wall {async_wall:.2f} s not below sync "
            f"{sync_wall:.2f} s")
    return 0


def run(quick: bool = False):
    """Entry for benchmarks.run."""
    main(["--tiny"] if quick else [])


if __name__ == "__main__":
    raise SystemExit(main())

"""§Perf hillclimbing harness: hypothesis -> knobs -> re-lower -> terms.

    PYTHONPATH=src python -m benchmarks.perf_iter --arch yi-6b \
        --shape train_4k --tag H1_bf16_reduce --knob tp_reduce_dtype=bfloat16

Compiles the cell with the baseline defaults + given knob overrides,
extracts the roofline terms, prints the before/after against the recorded
baseline artifact and appends the iteration record to
artifacts/perf/<arch>.<shape>.jsonl (the §Perf log in EXPERIMENTS.md is
generated from these records).
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
PERF = ROOT / "artifacts" / "perf"


def run_cell(arch, shape, knobs, multi_pod=False):
    from repro.configs import get_config
    from repro.launch.dryrun import compile_cell
    from repro.models.config import SHAPES_BY_NAME
    return compile_cell(get_config(arch), SHAPES_BY_NAME[shape], knobs,
                        multi_pod=multi_pod)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--knob", action="append", default=[])
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    from repro.launch.train import parse_knobs
    knobs = parse_knobs(args.knob)
    rec = run_cell(args.arch, args.shape, knobs, args.multi_pod)
    r = rec["roofline"]

    PERF.mkdir(parents=True, exist_ok=True)
    log = PERF / f"{args.arch}.{args.shape}.jsonl"
    entry = {"tag": args.tag, "hypothesis": args.hypothesis, "knobs": knobs,
             "compute_s": r["compute_s"], "memory_s": r["memory_s"],
             "collective_s": r["collective_s"], "step_s": r["step_s"],
             "dominant": r["dominant"],
             "useful_flops_ratio": rec["useful_flops_ratio"],
             "compile_s": rec["compile_s"]}
    with log.open("a") as f:
        f.write(json.dumps(entry) + "\n")

    prev = None
    lines = log.read_text().splitlines()
    if len(lines) >= 2:
        prev = json.loads(lines[-2])
    print(f"{args.tag}: step={r['step_s']:.4f}s  c={r['compute_s']:.4f} "
          f"m={r['memory_s']:.4f} x={r['collective_s']:.4f} "
          f"dom={r['dominant']} useful={rec['useful_flops_ratio']:.2f}")
    if prev:
        d = prev["step_s"] / r["step_s"]
        print(f"   vs prev [{prev['tag']}] step {prev['step_s']:.4f}s "
              f"-> {d:.2f}x {'improvement' if d > 1 else 'REGRESSION'}")
    return entry


if __name__ == "__main__":
    main()

"""Sharded candidate scoring + the kernel-autotune dogfood loop.

    PYTHONPATH=src python -m benchmarks.perf_multi_device [--tiny]

Two arms, two claims — both asserted even under ``--tiny`` (this is the
CI gate for PR 6):

* **scoring** — ``gp.select_batch_sharded`` splits the q-EI candidate
  pool row-wise over ``jax.devices()``; per-pick cross-device traffic is
  one masked all-reduce argmax plus three O(m + d) psum gathers, so the
  pool grows with the device count at ~constant wall-clock.  Device
  count is forced via ``XLA_FLAGS=--xla_force_host_platform_device_count``
  which must be set *before* jax imports, so each arm runs in a
  subprocess (re-invoking this module with ``--worker``);
  ``--xla_cpu_multi_thread_eigen=false`` stops single-device XLA from
  eating every core, which would mask device scaling on a CPU host.
  Acceptance: >= 1.6x scored-candidates/sec at 2 devices vs 1, and at an
  equal pool the sharded picks are bit-identical to ``select_batch``.
  Forced host devices are *threads sharing the machine's cores*, so the
  throughput gate only means something when the host actually grants
  >= 2 cores (the compiled program is verifiably parallel either way:
  num_partitions=2, per-shard [Ml] tensors, all-reduces only over
  scalars and [m]/[d] rows).  On a single-core host (CPU affinity, CI
  sandboxes) the ratio is reported but the gate is vacuous — the pick
  identity assertion, which is what correctness needs, always runs.

* **autotune** — the dogfood loop: :func:`repro.kernels.tune_kernel`
  tunes the gp_gram Pallas kernel's tiling through BO +
  ``Controller.run_async``, seeded with the shipped default.  The bench
  shape (n=136, d=8) sits off the 128 ladder, so the hand-picked square
  128 tile pads 136 -> 256 and runs a wasteful 2x2 grid; rectangular
  tiles under the same VMEM budget cover the rows in one stripe (~1.9x
  on this host).  Acceptance: the tuned config re-measured head-to-head
  is no slower than the hand-picked default (small tolerance for timer
  noise) — the tuner must at minimum *find* the default it was seeded
  with, and in practice beats it.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import subprocess
import sys
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _usable_cores() -> int:
    """Cores this process may actually run on (affinity-aware)."""
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:                   # non-Linux
        return os.cpu_count() or 1

# ---------------------------------------------------------------------------
# scoring arm: subprocess worker (device count is fixed at jax import)
# ---------------------------------------------------------------------------


def _worker(args) -> dict:
    """Time select_batch (1 device) / select_batch_sharded (N devices) on a
    pool of ``--pool`` candidates *per device*.  Runs inside the subprocess
    with XLA_FLAGS already applied; prints one ``RESULT {json}`` line."""
    import numpy as np

    import jax
    from repro.core import gp

    nd = jax.local_device_count()
    n, d, q = args.n, args.d, args.q
    rng = np.random.default_rng(0)
    x = rng.random((n, d))
    y = (np.sin(3 * x[:, 0]) + (x[:, 1] - 0.4) ** 2
         + 0.05 * rng.normal(size=n))
    pad_to = gp._bucket(n + q)
    st = gp.fit(x, y, steps=60, pad_to=pad_to)
    best_y = float(np.min(y))
    y_raw = np.zeros(int(st.x.shape[0]), np.float32)
    y_raw[:n] = y

    M = args.pool * nd                       # pool grows with device count
    cand = rng.random((M, d)).astype(np.float32)

    if nd == 1:
        fn = lambda: gp.select_batch(st, cand, y_raw, n, best_y, q)  # noqa
    else:
        fn = lambda: gp.select_batch_sharded(st, cand, y_raw, n,     # noqa
                                             best_y, q)
    idx = np.asarray(fn())                   # compile before timing

    same = True
    if nd > 1:
        # equal-pool identity: sharded picks == single-device picks, bit
        # for bit (the collective argmax has the same first-occurrence
        # tie-break as jnp.argmax)
        base = np.asarray(gp.select_batch(st, cand, y_raw, n, best_y, q))
        same = bool(np.array_equal(base, idx))

    best = math.inf                          # best-of-blocks: contention-
    for _ in range(4):                       # robust on shared CI boxes
        t0 = time.monotonic()
        for _ in range(args.repeats):
            np.asarray(fn())
        best = min(best, (time.monotonic() - t0) / args.repeats)

    print("RESULT " + json.dumps(
        {"devices": nd, "pool": M, "select_s": best,
         "cand_per_s": M / best, "same_picks": same}), flush=True)
    return 0


def _spawn_worker(nd: int, n: int, d: int, q: int, pool: int,
                  repeats: int) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={nd} "
                        "--xla_cpu_multi_thread_eigen=false")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(REPO / "src"), str(REPO),
                    env.get("PYTHONPATH", "")) if p)
    cmd = [sys.executable, "-m", "benchmarks.perf_multi_device", "--worker",
           "--n", str(n), "--d", str(d), "--q", str(q),
           "--pool", str(pool), "--repeats", str(repeats)]
    out = subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                         text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"worker nd={nd} failed:\n{out.stdout}"
                           f"\n{out.stderr}")
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"worker nd={nd} printed no RESULT line:"
                       f"\n{out.stdout}\n{out.stderr}")


def bench_scoring(n: int, d: int, q: int, pool: int, repeats: int,
                  devices: int = 2) -> dict:
    # the >= 1.6x throughput gate is vacuous without real parallelism;
    # PERF_REQUIRE_CORES (set by CI) turns that silent skip into a loud
    # failure so a mis-provisioned runner can't fake a pass — checked
    # before the workers spend minutes measuring
    required = int(os.environ.get("PERF_REQUIRE_CORES", "0"))
    if _usable_cores() < required:
        raise RuntimeError(
            f"PERF_REQUIRE_CORES={required} but the host grants only "
            f"{_usable_cores()} core(s): the multi-device throughput gate "
            "would pass vacuously — run on a multi-core machine")
    one = _spawn_worker(1, n, d, q, pool, repeats)
    many = _spawn_worker(devices, n, d, q, pool, repeats)
    ratio = many["cand_per_s"] / one["cand_per_s"]
    cores = _usable_cores()
    print(f"  1 device : pool {one['pool']:6d}  "
          f"{one['select_s'] * 1e3:8.2f} ms/batch  "
          f"{one['cand_per_s']:10.0f} cand/s")
    print(f"  {many['devices']} devices: pool {many['pool']:6d}  "
          f"{many['select_s'] * 1e3:8.2f} ms/batch  "
          f"{many['cand_per_s']:10.0f} cand/s  "
          f"-> {ratio:.2f}x throughput "
          f"(equal-pool picks identical: {many['same_picks']})")
    if cores < devices:
        print(f"  [host grants {cores} core(s) for {devices} forced "
              "devices: throughput gate not enforceable here]")
    return {"one": one, "many": many, "throughput_ratio": ratio,
            "cores": cores,
            "same_picks": bool(many["same_picks"])}


# ---------------------------------------------------------------------------
# autotune arm: the dogfood loop, in-process
# ---------------------------------------------------------------------------


def bench_autotune(budget: int, repeats: int, head_repeats: int) -> dict:
    from repro.kernels.autotune import KernelEvaluator, tune_kernel

    out = tune_kernel("gp_gram", budget=budget, batch_size=2, seed=0,
                      repeats=repeats, warmup=1, fit_steps=60)
    print(f"  tuned   {out['best_config']}  "
          f"{out['best_value']:.3f} ms (search estimate)")
    print(f"  default {out['default_config']}  "
          f"{out['default_value']:.3f} ms (search estimate)")

    # head-to-head re-measure: same evaluator, same process, back to back
    # — the search-time estimates above were taken minutes apart
    ev = KernelEvaluator("gp_gram", repeats=head_repeats, warmup=2)
    tuned_ms = ev(out["best_config"])
    default_ms = ev(out["default_config"])
    speedup = default_ms / max(tuned_ms, 1e-12)
    print(f"  head-to-head: default {default_ms:.3f} ms, "
          f"tuned {tuned_ms:.3f} ms  -> {speedup:.2f}x")
    n_fail = sum(1 for r in out["db"].records if not r.ok)
    return {"best_config": out["best_config"],
            "default_config": out["default_config"],
            "tuned_ms": tuned_ms, "default_ms": default_ms,
            "speedup": speedup, "evals": len(out["trace"].values),
            "failed": n_fail}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke budgets (assertions stay on)")
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--d", type=int, default=4)
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--pool", type=int, default=4096)
    ap.add_argument("--repeats", type=int, default=5)
    args = ap.parse_args(argv)

    if args.worker:
        return _worker(args)

    from benchmarks.common import save

    if args.tiny:
        # n=96 keeps the per-shard solve (O(T²·Ml)) well above the
        # per-call dispatch + collective overhead, so the 2-device ratio
        # measures compute scaling, not fixed-cost amortization
        n, d, q, pool, repeats = 96, 4, 4, 4096, 5
        budget, tune_reps, head_reps = 12, 3, 8
    else:
        n, d, q, pool, repeats = 128, 8, 8, 8192, 8
        budget, tune_reps, head_reps = 24, 5, 12

    print("== sharded candidate scoring: 1 vs 2 forced host devices")
    scoring = bench_scoring(n, d, q, pool, repeats)

    print("== kernel-autotune dogfood: BO over gp_gram tiling")
    autotune = bench_autotune(budget, tune_reps, head_reps)

    save("perf_multi_device", {"scoring": scoring, "autotune": autotune})

    assert scoring["same_picks"], (
        "sharded picks diverged from select_batch at equal pool")
    if scoring["cores"] >= 2:
        assert scoring["throughput_ratio"] >= 1.6, (
            f"sharded scoring throughput {scoring['throughput_ratio']:.2f}x "
            "< 1.6x at 2 devices")
    assert autotune["tuned_ms"] <= autotune["default_ms"] * 1.15, (
        f"tuned config {autotune['tuned_ms']:.3f} ms slower than the "
        f"hand-picked default {autotune['default_ms']:.3f} ms")
    return 0


def run(quick: bool = False):
    """Entry for benchmarks.run."""
    main(["--tiny"] if quick else [])


if __name__ == "__main__":
    raise SystemExit(main())

"""Adaptive vs fixed-k replication on the noisy analytic evaluator.

    PYTHONPATH=src python -m benchmarks.perf_replication [--tiny]

The paper's Experiment Unit averages a *fixed* number of benchmark runs
per configuration — the averaging dilemma: too few repeats and the tuner
chases noise, too many and the measurement budget evaporates.  The
replication layer's adaptive policy (racing) spends repeats only where
they decide a ranking: every probe starts at 2 repeats, and only configs
whose ±z·sd credible interval still straddles the incumbent best are
re-measured (up to ``2k`` total), through the same ``run_async``
in-flight machinery.

Both arms run the identical BO probe schedule (same controller seed,
same strategy seed — the seed-wired request streams make the comparison
deterministic) against an analytic evaluator with σ = 0.15 multiplicative
noise (6× the paper's measured 2.5 %, so replication visibly matters):

* **fixed-k**  — every probe measured k times (the paper's policy);
* **adaptive** — initial 2, increment 1, cap 2k, z = 1.

Headline assertion (the CI gate, enforced in ``--tiny`` too): adaptive
replication reaches the fixed-k arm's best-found *true* objective (noise-
free step time of the best measured config) at **≤ 75 % of fixed-k's
total measurement budget** (``evaluator.calls``), and never at a worse
best-found value than fixed-k + 2 % tolerance.
"""

from __future__ import annotations

import argparse
import sys

from benchmarks.common import save
from repro.configs import get_config
from repro.core.controller import Controller, EvalDB
from repro.core.costmodel import SINGLE_POD
from repro.core.evaluators import AnalyticEvaluator
from repro.core.knobs import clean_space
from repro.core.replication import ReplicationPolicy
from repro.core.strategy import BOConfig, make_strategy
from repro.models.config import SHAPES_BY_NAME

NOISE_SIGMA = 0.15       # multiplicative benchmark noise (6x paper's 2.5 %)
FIXED_K = 4              # the paper-style fixed repeat count
BUDGET_GATE = 0.75       # adaptive must spend <= this fraction of fixed-k
QUALITY_TOL = 1.02       # ... at a best-found no worse than fixed-k + 2 %


def _arm(space, model_cfg, cell, policy, probes: int, seed: int):
    """One tuning run: BO probe schedule under the given replication
    policy.  Returns (total measurements, best-found true step time,
    per-probe repeat counts)."""
    ev = AnalyticEvaluator(model_cfg, cell, noise_sigma=NOISE_SIGMA)
    ctrl = Controller(ev, EvalDB(), tag="replication", seed=seed,
                      replication=policy)
    n_init = max(probes // 2, 6)
    strat = make_strategy("bo", space, budget=probes, seed=seed,
                          cfg=BOConfig(n_init=n_init,
                                       n_iter=probes - n_init,
                                       fit_steps=40))
    trace = ctrl.run_async(strat)
    best_cfg, _ = trace.best
    repeats = [r.repeats for r in ctrl.db.records]
    return ev.calls, ev.true_step(best_cfg), repeats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="one seed, smaller probe budget (CI smoke; the "
                         "budget/quality gates are asserted here too)")
    args = ap.parse_args(argv)

    probes = 16 if args.tiny else 24
    seeds = (0,) if args.tiny else (0, 1, 2)

    model_cfg = get_config("yi-6b")
    cell = SHAPES_BY_NAME["train_4k"]
    space, _, _ = clean_space(model_cfg, cell, SINGLE_POD)

    fixed_pol = ReplicationPolicy(n_repeats=FIXED_K)
    adapt_pol = ReplicationPolicy(n_repeats=2, adaptive=True,
                                  max_repeats=2 * FIXED_K, z=1.0)

    rows = []
    for seed in seeds:
        f_calls, f_best, f_rep = _arm(space, model_cfg, cell, fixed_pol,
                                      probes, seed)
        a_calls, a_best, a_rep = _arm(space, model_cfg, cell, adapt_pol,
                                      probes, seed)
        ratio = a_calls / f_calls
        rows.append({"seed": seed, "probes": probes,
                     "fixed_calls": f_calls, "fixed_best": f_best,
                     "adaptive_calls": a_calls, "adaptive_best": a_best,
                     "budget_ratio": ratio,
                     "adaptive_repeats": a_rep})
        print(f"seed {seed}: fixed-k={FIXED_K} {f_calls} measurements, "
              f"best true step {f_best:.4f}s | adaptive {a_calls} "
              f"measurements, best {a_best:.4f}s | "
              f"budget ratio {ratio:.2f}", flush=True)

    mean_ratio = sum(r["budget_ratio"] for r in rows) / len(rows)
    worst_quality = max(r["adaptive_best"] / r["fixed_best"] for r in rows)
    print(f"\nmean budget ratio {mean_ratio:.2f} "
          f"(gate <= {BUDGET_GATE}), worst best-found ratio "
          f"{worst_quality:.4f} (gate <= {QUALITY_TOL})")

    save("perf_replication", {
        "noise_sigma": NOISE_SIGMA, "fixed_k": FIXED_K,
        "mean_budget_ratio": mean_ratio,
        "worst_quality_ratio": worst_quality, "runs": rows})

    # the headline claims — deterministic under the seed-wired request
    # streams, so these are hard gates, not flaky statistics
    assert mean_ratio <= BUDGET_GATE, (
        f"adaptive replication spent {mean_ratio:.2f} of the fixed-k "
        f"measurement budget (gate: <= {BUDGET_GATE})")
    assert worst_quality <= QUALITY_TOL, (
        f"adaptive best-found is {worst_quality:.4f}x fixed-k's "
        f"(gate: <= {QUALITY_TOL})")
    print("gates passed: adaptive matches fixed-k best-found at "
          f"{mean_ratio:.0%} of its measurement budget")
    return 0


def run(quick: bool = False):
    """benchmarks.run entry point."""
    main(["--tiny"] if quick else [])


if __name__ == "__main__":
    sys.exit(main())

"""Cross-workload transfer: leave-one-out priors over the config zoo.

    PYTHONPATH=src python -m benchmarks.perf_transfer [--tiny]

fig5b validates transfer across *environments* (test cluster -> product
cluster, one workload); this benchmark generalizes it across
*workloads*: the dense-model family at train_4k shares one search-space
signature, so every architecture's tuning log is evidence for the next
one.  Leave-one-workload-out over the family:

1. rank once on a donor-only architecture (never a fold target, so the
   shared top-k subspace leaks nothing into the holdouts);
2. tune every architecture from scratch with plain BO — each run is both
   that fold's baseline and every *other* fold's corpus;
3. per fold, rebuild the corpus without the target and tune it again
   with :class:`~repro.transfer.TransferBOStrategy` (multi-task GP
   prior, corpus-best design seeds, decaying pseudo-observations).

The transferred arm runs the same budget but an *exploitation* BOConfig:
a 3-point design (the corpus seed already covers the coarse exploration
a from-scratch LHS buys) and a tighter incumbent ball
(``local_sigma=0.02``), because a warm start's job is to refine the
transferred basin — including re-triggering dynamic boundary expansion
when the family's optimum sits at a shared edge, which is exactly how
the mistral fold's optimum is reached.

Headline gates (asserted, ``--tiny`` included — the CI smoke):

* **speedup** — every fold's transferred run reaches the from-scratch
  run's final best-found quality (ratio >= 0.99) within <= 60 % of the
  evaluation budget;
* **no-corpus identity** — ``TransferBOStrategy`` with an empty corpus
  is trace-identical to plain ``BOStrategy`` at equal seed: the transfer
  machinery costs nothing when there is nothing to transfer.

Objectives are noise-free (``noise_sigma=0``): the identity gate is
about the strategy's draws, and the speedup gate should measure the
prior, not the luck of the noise stream.
"""

from __future__ import annotations

import argparse
import sys
import time
from dataclasses import replace

import numpy as np

from benchmarks.common import save
from repro.configs import get_smoke_config
from repro.core import ranking
from repro.core.controller import EvalRecord
from repro.core.costmodel import SINGLE_POD
from repro.core.evaluators import AnalyticEvaluator
from repro.core.knobs import clean_space
from repro.core.strategy import BOConfig, BOStrategy, make_strategy
from repro.models.config import SHAPES_BY_NAME
from repro.transfer import (TransferBOStrategy, TransferCorpus,
                            build_corpus, space_signature)

SHAPE = "train_4k"
RANK_ARCH = "qwen1.5-4b"              # donor only: never a fold target
QUALITY_RATIO = 0.99                  # scratch_best / transfer_best gate
EVAL_FRACTION = 0.60                  # ... within this share of budget


def _folds(tiny: bool):
    return (("yi-6b", "codeqwen1.5-7b") if tiny
            else ("yi-6b", "codeqwen1.5-7b", "mistral-nemo-12b"))


def _budget(tiny: bool) -> int:
    return 8 if tiny else 16


def _bo_cfg(tiny: bool) -> BOConfig:
    return (BOConfig(n_init=4, n_iter=4, n_candidates=128, fit_steps=10,
                     seed=7)
            if tiny else
            BOConfig(n_init=6, n_iter=10, n_candidates=256, fit_steps=40,
                     seed=7))


def _transfer_cfg(tiny: bool) -> BOConfig:
    """The warm-started arm's exploitation config: tiny design, tight
    incumbent ball — the corpus seeds replace the LHS exploration."""
    budget = _budget(tiny)
    return replace(_bo_cfg(tiny), n_init=3, n_iter=budget - 3,
                   local_sigma=0.02)


def _workload(arch: str):
    """(full space, deterministic evaluator, base config) of one arch."""
    cfg = get_smoke_config(arch)
    cell = SHAPES_BY_NAME[SHAPE]
    space, _, _ = clean_space(cfg, cell, SINGLE_POD)
    ev = AnalyticEvaluator(cfg, cell, SINGLE_POD, noise_sigma=0.0, seed=0)
    return space, ev, space.default_config()


def _objective(space, ev, base):
    def f(c):
        full = dict(base)
        full.update(c)
        return float(ev(space.project(full)))
    return f


def _drive(strategy, f):
    while not strategy.finished:
        cfgs = strategy.ask()
        if not cfgs:
            break
        strategy.tell(cfgs, [f(c) for c in cfgs])
    return strategy.trace


def _evals_to(best_values, target):
    for i, v in enumerate(best_values):
        if v <= target:
            return i + 1
    return None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: tiny budgets, same gates")
    args = ap.parse_args(argv)
    tiny = args.tiny
    folds, budget = _folds(tiny), _budget(tiny)
    cfg, tcfg = _bo_cfg(tiny), _transfer_cfg(tiny)
    archs = (RANK_ARCH,) + folds
    k = 6 if tiny else 8

    # ---- shared subspace, ranked on the donor-only arch -------------------
    t0 = time.monotonic()
    workloads = {a: _workload(a) for a in archs}
    rank_space, rank_ev, _ = workloads[RANK_ARCH]
    sig = space_signature(rank_space)
    for a in archs:
        assert space_signature(workloads[a][0]) == sig, \
            f"{a} is not transfer-compatible with {RANK_ARCH}"
    rk = ranking.rank(rank_space, rank_ev,
                      n_samples=100 if tiny else 300, seed=0,
                      stability_rounds=0 if tiny else 8)
    sub = rk.top_space(k)
    rank_wall = time.monotonic() - t0

    # ---- from-scratch BO per arch: baseline AND everyone else's corpus ----
    scratch = {}
    records = []
    t0 = time.monotonic()
    for a in archs:
        space, ev, base = workloads[a]
        f = _objective(space, ev, base)
        strat = make_strategy("bo", sub, budget=budget, cfg=cfg)
        trace = _drive(strat, f)
        scratch[a] = trace
        records += [EvalRecord(dict(c), float(v), 0.0, "scratch", a)
                    for c, v in zip(trace.configs, trace.values)]
    scratch_wall = time.monotonic() - t0

    # ---- no-corpus identity gate ------------------------------------------
    space, ev, base = workloads[folds[0]]
    f = _objective(space, ev, base)
    plain = _drive(BOStrategy(sub, tcfg), f)
    for label, corpus in (("corpus=None", None),
                          ("empty corpus", TransferCorpus(sub, []))):
        twin = _drive(TransferBOStrategy(sub, tcfg, corpus=corpus), f)
        assert twin.configs == plain.configs \
            and np.allclose(twin.values, plain.values), \
            f"TransferBOStrategy({label}) diverged from plain BOStrategy"

    # ---- leave-one-out transfer -------------------------------------------
    max_evals = int(EVAL_FRACTION * budget)
    fold_out = {}
    t0 = time.monotonic()
    for target in folds:
        corpus = build_corpus(sub, [records], exclude=(target,))
        assert corpus.n_tasks == len(archs) - 1
        space, ev, base = workloads[target]
        f = _objective(space, ev, base)
        strat = make_strategy("transfer_bo", sub, budget=budget, cfg=tcfg,
                              corpus=corpus,
                              corpus_fit_steps=20 if tiny else 100)
        trace = _drive(strat, f)
        scratch_best = min(scratch[target].values)
        matched = _evals_to(trace.best_values,
                            scratch_best / QUALITY_RATIO)
        fold_out[target] = {
            "scratch_best": scratch_best,
            "transfer_best": min(trace.values),
            "evals_to_match": matched,
            "transfer_best_values": list(trace.best_values),
            "scratch_best_values": list(scratch[target].best_values),
        }
    transfer_wall = time.monotonic() - t0

    # ---- gates ------------------------------------------------------------
    print(f"perf_transfer ({'tiny' if tiny else 'full'}): "
          f"{len(folds)} leave-one-out folds over {len(archs)} archs @ "
          f"{SHAPE}, budget {budget}, top-{k} subspace "
          f"(ranked on {RANK_ARCH} in {rank_wall:.1f}s)")
    for target, r in fold_out.items():
        m = r["evals_to_match"]
        ratio = r["scratch_best"] / r["transfer_best"]
        status = (f"matched at eval {m}/{budget}" if m is not None
                  else "NEVER matched")
        print(f"  {target:18s} scratch {r['scratch_best']:.4f} "
              f"transfer {r['transfer_best']:.4f} "
              f"(ratio {ratio:.3f}) {status} (gate <= {max_evals})")
        assert m is not None and m <= max_evals, \
            (f"{target}: transferred run needed "
             f"{m if m is not None else '>' + str(budget)} evals to reach "
             f"{QUALITY_RATIO:.0%} of scratch quality; gate is "
             f"{max_evals} (60% of {budget})")
    print(f"  no-corpus identity   : TransferBOStrategy == BOStrategy "
          "at equal seed  PASS")
    print(f"  scratch wall {scratch_wall:.1f}s, transfer wall "
          f"{transfer_wall:.1f}s")

    save("perf_transfer", {
        "tiny": tiny, "shape": SHAPE, "rank_arch": RANK_ARCH,
        "folds": list(folds), "budget": budget, "top_k": k,
        "quality_ratio": QUALITY_RATIO, "eval_fraction": EVAL_FRACTION,
        "max_evals_gate": max_evals,
        "per_fold": fold_out,
        "gates": {"all_folds_matched": True, "no_corpus_identity": True},
        "rank_wall_s": rank_wall, "scratch_wall_s": scratch_wall,
        "transfer_wall_s": transfer_wall,
    })
    return 0


def run(quick: bool = False):
    """benchmarks.run entrypoint."""
    main(["--tiny"] if quick else [])


if __name__ == "__main__":
    sys.exit(main())

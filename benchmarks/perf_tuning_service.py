"""Tuning-as-a-service throughput + cross-session cache effectiveness.

    PYTHONPATH=src python -m benchmarks.perf_tuning_service [--tiny]

Scenario (BestConfig's shared-deployment payoff, measured): one in-process
:class:`~repro.service.server.TuningServer` hosts two analytic workloads;
8 synthetic clients (threads) each create a session and have the server
drive it to the same per-session budget.  Clients sharing a workload use
the same recipe (strategy, seed) — the "recommended run" a service hands
every user of a popular workload — so their probe streams coincide and
the cross-session cache turns 8 runs' worth of traffic into 2 runs'
worth of evaluations.

Headline gates (asserted, ``--tiny`` included — the CI smoke):

* cross-session cache hit rate >= 40 % over all requests;
* total evaluator calls STRICTLY fewer than 8 independent local
  ``Controller.run_async`` runs at equal per-session budget would make
  (measured against a real local run, not assumed);
* a single server-side session's trace is bit-identical to the local
  ``run_async`` with the same seed (values, configs and running best) —
  shared cached probes are indistinguishable from private evaluations.

Also reported: sessions/sec across the concurrent clients and the
daemon's own stats snapshot.
"""

from __future__ import annotations

import argparse
import sys
import threading
import time

from benchmarks.common import save
from repro.core.controller import Controller, EvalDB
from repro.core.service import ImmediateEvaluationService
from repro.core.strategy import BOConfig, make_strategy
from repro.service import TuningServer, default_catalog

WORKLOADS = ("yi-6b:train_4k", "qwen1.5-4b:train_4k")
N_CLIENTS = 8
HIT_RATE_GATE = 0.40


def _bo_cfg(tiny: bool) -> dict:
    return ({"n_init": 3, "n_iter": 3, "fit_steps": 10}
            if tiny else {"n_init": 6, "n_iter": 10, "fit_steps": 40})


def _budget(tiny: bool) -> int:
    return 6 if tiny else 16


def _local_run(workload: str, budget: int, seed: int, cfg: dict):
    """One independent client tuning alone: the baseline each of the 8
    concurrent clients would pay without the shared daemon."""
    spec = default_catalog()[workload]
    space, _ = spec.materialize()
    backend = spec.build()[1]            # fresh evaluator, fresh counter
    strat = make_strategy("bo", space, budget=budget, seed=seed,
                          cfg=BOConfig(**cfg))
    ctrl = Controller(ImmediateEvaluationService(backend), db=EvalDB(),
                      tag="bo", workload=workload, seed=seed)
    trace = ctrl.run_async(strat, budget=budget, max_in_flight=1,
                           min_ask=1)
    return trace, backend.calls


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: tiny budgets, same gates")
    args = ap.parse_args(argv)
    tiny = args.tiny
    budget, cfg, seed = _budget(tiny), _bo_cfg(tiny), 5

    # ---- baseline: one independent local run per workload ----------------
    local = {}
    t0 = time.monotonic()
    for wl in WORKLOADS:
        local[wl] = _local_run(wl, budget, seed, cfg)
    local_wall = time.monotonic() - t0
    calls_per_session = {wl: calls for wl, (_, calls) in local.items()}
    assert all(c == budget for c in calls_per_session.values()), \
        calls_per_session
    independent_calls = N_CLIENTS * budget     # 8 clients tuning alone

    # ---- the shared daemon: 8 concurrent clients, 2 workloads ------------
    srv = TuningServer({wl: default_catalog()[wl] for wl in WORKLOADS},
                       max_workers=4)
    sessions, errors = [], []
    lock = threading.Lock()

    def client(i: int):
        wl = WORKLOADS[i % len(WORKLOADS)]
        try:
            s = srv.create_session(wl, budget=budget, seed=seed,
                                   strategy_kwargs={"cfg": cfg})
            with lock:
                sessions.append(s)
            s.run()
        except Exception as e:               # pragma: no cover
            errors.append(e)

    t0 = time.monotonic()
    threads = [threading.Thread(target=client, args=(i,))
               for i in range(N_CLIENTS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.monotonic() - t0
    assert not errors, errors
    assert len(sessions) == N_CLIENTS

    cache = srv.pool.cache.snapshot()
    server_calls = sum(srv.pool.inner.backends[wl].calls
                       for wl in WORKLOADS)
    sessions_per_sec = N_CLIENTS / wall

    # ---- gates ------------------------------------------------------------
    hit_rate = cache["hit_rate"]
    assert hit_rate >= HIT_RATE_GATE, \
        f"cache hit rate {hit_rate:.1%} < {HIT_RATE_GATE:.0%}"
    assert server_calls < independent_calls, \
        (f"shared pool made {server_calls} evaluator calls; "
         f"{N_CLIENTS} independent runs make {independent_calls}")
    # bit-identity: any one server session vs its workload's local run
    for s in sessions:
        lt, _ = local[s.workload]
        st = s.strategy.trace
        assert st.values == lt.values, \
            f"{s.session_id}: server trace diverged from local run"
        assert st.configs == lt.configs
        assert st.best_values == lt.best_values
    srv.close()

    print(f"perf_tuning_service ({'tiny' if tiny else 'full'}): "
          f"{N_CLIENTS} clients x budget {budget} on {len(WORKLOADS)} "
          "workloads")
    print(f"  independent baseline : {independent_calls} evaluator calls "
          f"({local_wall:.2f}s for {len(WORKLOADS)} sessions)")
    print(f"  shared daemon        : {server_calls} evaluator calls, "
          f"{wall:.2f}s, {sessions_per_sec:.2f} sessions/s")
    print(f"  cache                : {cache['hits']}/{cache['requests']} "
          f"hits ({hit_rate:.1%}; {cache['hits_inflight']} in-flight), "
          f"gate >= {HIT_RATE_GATE:.0%}  PASS")
    print(f"  evaluator calls      : {server_calls} < {independent_calls}"
          "  PASS")
    print("  trace bit-identity   : all "
          f"{N_CLIENTS} sessions == local run_async  PASS")

    save("perf_tuning_service", {
        "tiny": tiny, "clients": N_CLIENTS, "budget": budget,
        "workloads": list(WORKLOADS),
        "independent_calls": independent_calls,
        "server_calls": server_calls,
        "cache": cache,
        "sessions_per_sec": sessions_per_sec,
        "wall_s": wall, "local_wall_s": local_wall,
        "gates": {"hit_rate": hit_rate,
                  "hit_rate_gate": HIT_RATE_GATE,
                  "calls_strictly_fewer": server_calls < independent_calls,
                  "trace_bit_identical": True},
    })
    return 0


def run(quick: bool = False):
    """benchmarks.run entrypoint."""
    main(["--tiny"] if quick else [])


if __name__ == "__main__":
    sys.exit(main())

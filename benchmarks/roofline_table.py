"""§Roofline: per (arch × shape × mesh) table from the dry-run artifacts.

Reads artifacts/dryrun/*.json (produced by ``python -m repro.launch.dryrun
--all --both-meshes``) and renders the three-term roofline table with
dominant-bottleneck classification and the MODEL_FLOPS/HLO_FLOPs "useful
compute" ratio.  Also emits artifacts/bench/roofline_table.md, which
EXPERIMENTS.md §Roofline embeds.
"""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import ARTIFACTS, save

DRYRUN = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def load(mesh: str = "16x16"):
    rows = []
    for f in sorted(DRYRUN.glob(f"*.{mesh}.json")):
        d = json.loads(f.read_text())
        if d.get("skipped"):
            continue
        rows.append(d)
    return rows


def render(rows, title):
    lines = [f"### {title}", "",
             "| arch | shape | compute_s | memory_s | collective_s | "
             "dominant | useful FLOPs | HBM GB/dev |",
             "|---|---|---|---|---|---|---|---|"]
    for d in rows:
        r = d["roofline"]
        u = d.get("useful_flops_ratio")
        mem = d["memory"].get("temp_size_gb", 0) \
            + d["memory"].get("argument_size_gb", 0)
        lines.append(
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {u if u is None else f'{u:.2f}'} | "
            f"{mem:.1f} |")
    return "\n".join(lines)


def run(quick: bool = False):
    single = load("16x16")
    multi = load("2x16x16")
    if not single:
        print("no dry-run artifacts: run `python -m repro.launch.dryrun "
              "--all --both-meshes` first")
        return {}
    md = render(single, "single-pod 16×16 (256 chips) — baseline") + "\n\n" \
        + render(multi, "multi-pod 2×16×16 (512 chips)")
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    (ARTIFACTS / "roofline_table.md").write_text(md + "\n")
    print(md)
    doms = {}
    for d in single:
        doms[d["roofline"]["dominant"]] = doms.get(
            d["roofline"]["dominant"], 0) + 1
    print(f"\nsingle-pod dominant-term histogram: {doms} "
          f"({len(single)} cells)")
    save("roofline_summary", {"single_cells": len(single),
                              "multi_cells": len(multi),
                              "dominant_hist": doms})
    return {"single": len(single), "multi": len(multi), "dominant": doms}


if __name__ == "__main__":
    run()

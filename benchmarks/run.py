"""Run every paper-artifact benchmark:  python -m benchmarks.run [--quick]

One module per paper table/figure (DESIGN.md §7):
  fig2b  multi-peak response surface
  fig4   dynamic vs static boundaries
  fig6   Lasso importance curve
  table2 top-16 knob table
  fig7   top-64/32/16 tuning efficiency
  fig5   default vs expert vs SAPPHIRE (+ product-env transfer)
  sec34  BO vs SA vs GA vs random (all via Controller.run)
  fig8   two-fidelity successive halving (analytic screen -> promotion)
  roofline  §Roofline table from the dry-run artifacts
  perf_batch  batched vs sequential evaluation pipeline wall-clock
  perf_async  async vs synchronous experiment loop on a latency-bound service
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (fig2b_response_surface, fig4_dynamic_boundary,
                        fig5_effectiveness, fig5b_compiled_transfer,
                        fig6_ranking, fig7_topk_efficiency,
                        fig8_two_fidelity, perf_async_service,
                        perf_batch_pipeline, roofline_table,
                        sec34_optimizers, table2_top16)

MODULES = [
    ("fig2b_response_surface", fig2b_response_surface),
    ("fig6_ranking", fig6_ranking),
    ("table2_top16", table2_top16),
    ("fig4_dynamic_boundary", fig4_dynamic_boundary),
    ("fig7_topk_efficiency", fig7_topk_efficiency),
    ("sec34_optimizers", sec34_optimizers),
    ("fig5_effectiveness", fig5_effectiveness),
    ("fig5b_compiled_transfer", fig5b_compiled_transfer),
    ("fig8_two_fidelity", fig8_two_fidelity),
    ("roofline_table", roofline_table),
    ("perf_batch_pipeline", perf_batch_pipeline),
    ("perf_async_service", perf_async_service),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sample/iteration budgets")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None
    failures = []
    for name, mod in MODULES:
        if only and name not in only:
            continue
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}", flush=True)
        t0 = time.monotonic()
        try:
            mod.run(quick=args.quick)
            print(f"-- {name} done in {time.monotonic() - t0:.1f}s",
                  flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    print(f"\n{'=' * 72}")
    if failures:
        print(f"FAILED: {failures}")
        return 1
    print("all benchmarks completed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Run every paper-artifact benchmark:  python -m benchmarks.run [--quick]

One module per paper table/figure (DESIGN.md §7):
  fig2b  multi-peak response surface
  fig4   dynamic vs static boundaries
  fig6   Lasso importance curve
  table2 top-16 knob table
  fig7   top-64/32/16 tuning efficiency
  fig5   default vs expert vs SAPPHIRE (+ product-env transfer)
  sec34  BO vs SA vs GA vs random (all via Controller.run)
  fig8   two-fidelity successive halving (analytic screen -> promotion)
  roofline  §Roofline table from the dry-run artifacts
  perf_batch  batched vs sequential evaluation pipeline wall-clock
  perf_async  async vs synchronous experiment loop on a latency-bound service
  perf_gp_ask device-resident q-EI selection + background GP refit
  perf_multi_device  sharded candidate scoring + kernel-autotune dogfood
  perf_replication  adaptive vs fixed-k replicated measurements budget
  perf_tuning_service  concurrent sessions sharing one evaluation pool
  perf_transfer  leave-one-workload-out meta-learned priors over the zoo
  perf_chaos  seeded fault injection: resilient tuning under 20 % faults

``--json [PATH]`` writes per-benchmark wall-clock timings and statuses to
an artifacts JSON (default artifacts/bench/run_timings.json) so the perf
trajectory is machine-readable across PRs.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (fig2b_response_surface, fig4_dynamic_boundary,
                        fig5_effectiveness, fig5b_compiled_transfer,
                        fig6_ranking, fig7_topk_efficiency,
                        fig8_two_fidelity, perf_async_service,
                        perf_batch_pipeline, perf_chaos, perf_gp_ask,
                        perf_multi_device, perf_replication, perf_transfer,
                        perf_tuning_service, roofline_table,
                        sec34_optimizers, table2_top16)

MODULES = [
    ("fig2b_response_surface", fig2b_response_surface),
    ("fig6_ranking", fig6_ranking),
    ("table2_top16", table2_top16),
    ("fig4_dynamic_boundary", fig4_dynamic_boundary),
    ("fig7_topk_efficiency", fig7_topk_efficiency),
    ("sec34_optimizers", sec34_optimizers),
    ("fig5_effectiveness", fig5_effectiveness),
    ("fig5b_compiled_transfer", fig5b_compiled_transfer),
    ("fig8_two_fidelity", fig8_two_fidelity),
    ("roofline_table", roofline_table),
    ("perf_batch_pipeline", perf_batch_pipeline),
    ("perf_async_service", perf_async_service),
    ("perf_gp_ask", perf_gp_ask),
    ("perf_multi_device", perf_multi_device),
    ("perf_replication", perf_replication),
    ("perf_tuning_service", perf_tuning_service),
    ("perf_transfer", perf_transfer),
    ("perf_chaos", perf_chaos),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sample/iteration budgets")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--json", nargs="?", default=None,
                    const="artifacts/bench/run_timings.json", metavar="PATH",
                    help="write per-benchmark wall-clock timings to an "
                         "artifacts JSON (machine-readable perf trajectory)")
    args = ap.parse_args(argv)

    only = set(args.only.split(",")) if args.only else None
    failures = []
    timings = []
    for name, mod in MODULES:
        if only and name not in only:
            continue
        print(f"\n{'=' * 72}\n== {name}\n{'=' * 72}", flush=True)
        t0 = time.monotonic()
        try:
            mod.run(quick=args.quick)
            wall = time.monotonic() - t0
            timings.append({"name": name, "wall_s": wall, "status": "ok"})
            print(f"-- {name} done in {wall:.1f}s", flush=True)
        except Exception:
            timings.append({"name": name,
                            "wall_s": time.monotonic() - t0,
                            "status": "failed"})
            failures.append(name)
            traceback.print_exc()
    if args.json:
        import json
        from pathlib import Path

        path = Path(args.json)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(
            {"quick": args.quick, "benchmarks": timings,
             "total_wall_s": sum(t["wall_s"] for t in timings)}, indent=1))
        print(f"-- timings written to {path}")
    print(f"\n{'=' * 72}")
    if failures:
        print(f"FAILED: {failures}")
        return 1
    print("all benchmarks completed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Paper §3.4: BO vs SA vs GA vs random under noise, equal budgets.

Run on the rugged prefill_32k surface (flash-block peaks + categorical
impl selection) with the paper's 2.5 % evaluation noise; scored by the
NOISE-FREE value of each method's believed-best config — noise-robustness
is exactly what separates GP-BO here (a noisy lucky probe fools methods
that trust single observations).

Every method is a registry :class:`SearchStrategy` driven through the one
``Controller.run`` experiment loop — the comparison exercises the exact
ask/tell plumbing ``Sapphire.tune()`` uses, and the per-method evaluation
logs land in one tagged EvalDB.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import save
from repro.configs import get_config
from repro.core import ranking
from repro.core.controller import Controller, EvalDB
from repro.core.costmodel import SINGLE_POD
from repro.core.evaluators import AnalyticEvaluator
from repro.core.knobs import clean_space
from repro.core.strategy import BOConfig, GAConfig, SAConfig, make_strategy
from repro.models.config import SHAPES_BY_NAME

METHODS = ("bo", "random", "sa", "ga")


def run(quick: bool = False, arch: str = "yi-6b", shape: str = "prefill_32k"):
    cfg = get_config(arch)
    cell = SHAPES_BY_NAME[shape]
    space, _, _ = clean_space(cfg, cell, SINGLE_POD)
    seeds = (0,) if quick else (0, 1, 2)
    budget = 24 if quick else 48

    # rank once (shared across methods, as SAPPHIRE would)
    ev0 = AnalyticEvaluator(cfg, cell, SINGLE_POD, noise_sigma=0.025, seed=9)
    rk = ranking.rank(space, ev0, n_samples=120 if quick else 300, seed=9)
    sub = rk.top_space(16)
    base = space.default_config()
    _full = space.completer()      # non-top knobs pinned at defaults

    results = {m: [] for m in METHODS}
    for seed in seeds:
        ev = AnalyticEvaluator(cfg, cell, SINGLE_POD, noise_sigma=0.025,
                               seed=seed)
        db = EvalDB()
        ctrl = Controller(ev, db).with_prepare(_full)
        for method in METHODS:
            kwargs = {"seed": seed, "budget": budget}
            if method == "bo":
                kwargs = {"cfg": BOConfig(n_init=8, n_iter=budget - 8,
                                          n_candidates=512, fit_steps=80,
                                          seed=seed)}
            elif method == "sa":
                kwargs["cfg"] = SAConfig(seed=seed)
            elif method == "ga":
                kwargs["cfg"] = GAConfig(seed=seed)
            strat = make_strategy(method, sub, **kwargs)
            ctrl.with_tag(method).run(strat)
            best_sub, _ = strat.best()
            results[method].append(ev.true_step(_full(best_sub)))
        # every method's experiments share the one tagged DB
        assert {r.tag for r in db.records} == set(METHODS)

    summary = {}
    default_t = AnalyticEvaluator(cfg, cell, SINGLE_POD, noise_sigma=0.0) \
        .true_step(space.project(base))
    print(f"default (noise-free): {default_t:.4f}s   budget={budget} evals"
          f"  (all methods via Controller.run)")
    for m, vals in results.items():
        mean = float(np.mean(vals))
        summary[m] = {"mean_step_s": mean, "runs": vals,
                      "speedup": default_t / mean}
        print(f"{m:7s} best-found {mean:.4f}s  ({default_t / mean:.2f}× "
              f"vs default)")
    best = min(summary, key=lambda m: summary[m]["mean_step_s"])
    print(f"winner: {best}")
    save("sec34_optimizers", {"summary": summary, "budget": budget,
                              "default_step_s": default_t})
    return summary


if __name__ == "__main__":
    run()

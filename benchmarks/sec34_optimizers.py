"""Paper §3.4: BO vs SA vs GA vs random under noise, equal budgets.

Run on the rugged prefill_32k surface (flash-block peaks + categorical
impl selection) with the paper's 2.5 % evaluation noise; scored by the
NOISE-FREE value of each method's believed-best config — noise-robustness
is exactly what separates GP-BO here (a noisy lucky probe fools methods
that trust single observations).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import save
from repro.configs import get_config
from repro.core import bo, optimizers as opt, ranking
from repro.core.costmodel import SINGLE_POD
from repro.core.evaluators import AnalyticEvaluator
from repro.core.knobs import clean_space
from repro.models.config import SHAPES_BY_NAME


def run(quick: bool = False, arch: str = "yi-6b", shape: str = "prefill_32k"):
    cfg = get_config(arch)
    cell = SHAPES_BY_NAME[shape]
    space, _, _ = clean_space(cfg, cell, SINGLE_POD)
    seeds = (0,) if quick else (0, 1, 2)
    budget = 24 if quick else 48

    # rank once (shared across methods, as SAPPHIRE would)
    ev0 = AnalyticEvaluator(cfg, cell, SINGLE_POD, noise_sigma=0.025, seed=9)
    rk = ranking.rank(space, ev0, n_samples=120 if quick else 300, seed=9)
    sub = rk.top_space(16)
    base = space.default_config()

    results = {m: [] for m in ("bo", "random", "sa", "ga")}
    for seed in seeds:
        ev = AnalyticEvaluator(cfg, cell, SINGLE_POD, noise_sigma=0.025,
                               seed=seed)

        def objective(c):
            full = dict(base)
            full.update(c)
            return ev(space.project(full))

        def truth(c):
            full = dict(base)
            full.update(c)
            return ev.true_step(space.project(full))

        b, _, _, _ = bo.minimize(objective, sub,
                                 bo.BOConfig(n_init=8, n_iter=budget - 8,
                                             n_candidates=512, fit_steps=80,
                                             seed=seed))
        results["bo"].append(truth(b))
        r, _, _ = opt.random_search(objective, sub, budget, seed=seed)
        results["random"].append(truth(r))
        s, _, _ = opt.simulated_annealing(objective, sub, budget,
                                          opt.SAConfig(seed=seed))
        results["sa"].append(truth(s))
        g, _, _ = opt.genetic_algorithm(objective, sub, budget,
                                        opt.GAConfig(seed=seed))
        results["ga"].append(truth(g))

    summary = {}
    default_t = AnalyticEvaluator(cfg, cell, SINGLE_POD, noise_sigma=0.0) \
        .true_step(space.project(base))
    print(f"default (noise-free): {default_t:.4f}s   budget={budget} evals")
    for m, vals in results.items():
        mean = float(np.mean(vals))
        summary[m] = {"mean_step_s": mean, "runs": vals,
                      "speedup": default_t / mean}
        print(f"{m:7s} best-found {mean:.4f}s  ({default_t / mean:.2f}× "
              f"vs default)")
    best = min(summary, key=lambda m: summary[m]["mean_step_s"])
    print(f"winner: {best}")
    save("sec34_optimizers", {"summary": summary, "budget": budget,
                              "default_step_s": default_t})
    return summary


if __name__ == "__main__":
    run()

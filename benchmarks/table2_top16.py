"""Paper Table 2: the top-16 knobs with type / default / range."""

from __future__ import annotations

from benchmarks.common import save
from repro.configs import get_config
from repro.core import ranking
from repro.core.costmodel import SINGLE_POD
from repro.core.evaluators import AnalyticEvaluator
from repro.core.knobs import clean_space
from repro.models.config import SHAPES_BY_NAME


def run(quick: bool = False, arch: str = "yi-6b", shape: str = "train_4k"):
    cfg = get_config(arch)
    cell = SHAPES_BY_NAME[shape]
    space, _, _ = clean_space(cfg, cell, SINGLE_POD)
    ev = AnalyticEvaluator(cfg, cell, SINGLE_POD, noise_sigma=0.025, seed=0)
    rk = ranking.rank(space, ev, n_samples=150 if quick else 300, seed=0,
                      stability_rounds=0 if quick else 8)
    rows = rk.table(16)
    hdr = f"{'knob':28s} {'type':12s} {'default':>10s} {'range':24s} {'imp':>8s}"
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['knob']:28s} {r['type']:12s} {str(r['default']):>10s} "
              f"{r['range']:24s} {r['importance']:8.4f}")
    save("table2_top16", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    run()

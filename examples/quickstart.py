"""Quickstart: the three things this framework does, in two minutes.

    PYTHONPATH=src python examples/quickstart.py

1. build any assigned architecture from its config and run a train step;
2. serve a few batched requests through the continuous-batching engine;
3. let SAPPHIRE recommend a configuration for a production cell
   (tiny budgets here — see examples/tune_sapphire.py for the real run).
"""

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.bo import BOConfig
from repro.core.tuner import Sapphire
from repro.models.model import Model
from repro.runconfig import RunConfig
from repro.serve.engine import Engine
from repro.train.data import batch_at
from repro.train.train_loop import init_state, make_train_step

# ---- 1. one train step on a reduced yi-6b ---------------------------------
cfg = get_smoke_config("yi-6b")
model = Model(cfg)
rc = RunConfig(microbatch=2)            # grad accumulation knob
state = init_state(model, jax.random.key(0), rc)
step = jax.jit(make_train_step(model, rc, lr_schedule=lambda s: 1e-3))
batch = batch_at(0, 0, global_batch=8, seq_len=64, vocab_size=cfg.vocab_size)
state, metrics = step(state, batch)
print(f"[train] loss={float(metrics['loss']):.4f} "
      f"grad_norm={float(metrics['grad_norm']):.3f}")

# ---- 2. batched serving -----------------------------------------------------
params = model.init(jax.random.key(0))
engine = Engine(model, params, RunConfig(), slots=4, s_max=64)
for n in (5, 9, 3):
    engine.submit(np.arange(1, 1 + n) % cfg.vocab_size, max_new_tokens=6)
done = engine.run()
print(f"[serve] {len(done)} requests in {engine.step_count} engine steps; "
      f"first output: {done[0].out_tokens}")

# ---- 3. SAPPHIRE recommendation (tiny budget demo) ---------------------------
result = Sapphire(
    arch="yi-6b", shape="train_4k", top_k=8, n_rank_samples=80,
    bo_config=BOConfig(n_init=6, n_iter=10, n_candidates=256, fit_steps=60),
).tune()
print(f"[tune]  {result.speedup_vs_default:.2f}x vs default config; "
      f"top knobs: {result.ranking.top(4)}")

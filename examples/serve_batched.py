"""Batched serving with continuous batching + KV-cache knobs.

    PYTHONPATH=src python examples/serve_batched.py [--arch qwen1.5-4b]

Submits a Poisson-ish stream of requests with mixed prompt lengths,
serves them through the slot-recycling engine, and reports utilization —
then repeats with the int8-KV knob to show the cache-budget effect
(double the admissible slots under the same HBM fraction).
"""

import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import Model
from repro.runconfig import RunConfig
from repro.serve.engine import Engine
from repro.serve.kvcache import CachePlan


def drive(model, params, rc, n_requests=16, slots=4, s_max=96, seed=0):
    eng = Engine(model, params, rc, slots=slots, s_max=s_max)
    rng = np.random.default_rng(seed)
    for _ in range(n_requests):
        plen = int(rng.integers(3, 24))
        eng.submit(rng.integers(1, model.cfg.vocab_size, plen),
                   max_new_tokens=int(rng.integers(4, 12)))
    done = eng.run()
    toks = sum(len(r.out_tokens) for r in done)
    return done, toks, eng.step_count


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.key(0))

    for kv_dtype in ("bfloat16", "int8"):
        rc = RunConfig(kv_cache_dtype=kv_dtype)
        plan = CachePlan.build(model.cfg, rc, hbm_bytes=16e9, kv_frac=0.3)
        done, toks, steps = drive(model, params, rc)
        print(f"kv={kv_dtype:9s} served {len(done)} reqs / {toks} tokens in "
              f"{steps} steps; cache admits batch "
              f"{plan.max_batch(32768)} @32k on a v5e chip")


if __name__ == "__main__":
    main()

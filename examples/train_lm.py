"""End-to-end training: a ~100M-parameter yi-family LM, few hundred steps.

    PYTHONPATH=src python examples/train_lm.py                # ~25M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --full-100m    # ~100M params

Exercises the real stack end to end: RunConfig knobs -> sharded train step
(grad accumulation + remat) -> stateless data stream -> checkpointing with
auto-resume -> straggler watchdog.  Kill it mid-run and rerun with
--resume: it continues bit-exact from the last checkpoint.
"""

import argparse
import time

import jax

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.runconfig import RunConfig
from repro.train import elastic
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticDataset
from repro.train.optimizer import cosine_schedule
from repro.train.train_loop import init_state, make_train_step


def model_config(full_100m: bool):
    base = get_config("yi-6b")
    if full_100m:
        # ~103M params: 12 x (d=768, ff=2048), 32k vocab
        return base.scaled(n_layers=12, d_model=768, n_heads=12,
                           n_kv_heads=4, d_ff=2048, vocab_size=32000,
                           head_dim=64)
    # ~25M params: CPU-friendly default
    return base.scaled(n_layers=8, d_model=384, n_heads=6, n_kv_heads=2,
                       d_ff=1024, vocab_size=16384, head_dim=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = model_config(args.full_100m)
    model = Model(cfg)
    print(f"model: {cfg.n_layers}L d={cfg.d_model} "
          f"params={cfg.param_count() / 1e6:.1f}M")

    rc = RunConfig(microbatch=max(args.global_batch // 2, 1),
                   remat_policy="block", learning_rate=3e-4)
    cm = CheckpointManager(args.ckpt_dir, keep_last=2)
    watchdog = elastic.StepWatchdog()

    with make_host_mesh():
        state = init_state(model, jax.random.key(0), rc)
        start = 0
        if args.resume and cm.latest_step() is not None:
            state, start = cm.restore(state)
            print(f"resumed from step {start}")
        step_fn = jax.jit(make_train_step(
            model, rc,
            lr_schedule=cosine_schedule(rc.learning_rate, warmup=20,
                                        total=args.steps)))
        data = SyntheticDataset(0, args.global_batch, args.seq_len,
                                cfg.vocab_size, start_step=start)
        t0 = time.monotonic()
        for i in range(start, args.steps):
            state, mets = step_fn(state, next(data))
            watchdog.observe(0, time.monotonic() - t0)
            t0 = time.monotonic()
            if (i + 1) % 20 == 0 or i == start:
                toks = args.global_batch * args.seq_len
                print(f"step {i + 1:4d}  loss {float(mets['loss']):.4f}  "
                      f"lr {float(mets['lr']):.2e}  "
                      f"{toks / max(time.monotonic() - t0, 1e-9) / 1e3:.0f}"
                      f"k tok/s")
            if (i + 1) % 100 == 0:
                cm.save(i + 1, state, blocking=False)
        cm.save(args.steps, state)
    print(f"done; checkpoints in {cm.root} (steps {cm.steps()})")


if __name__ == "__main__":
    main()

"""The paper, end to end: recommend a configuration for a production cell.

    PYTHONPATH=src python examples/tune_sapphire.py \
        [--arch yi-6b] [--shape train_4k] [--top-k 16] [--quick]

Pipeline (paper Fig. 3): raw knob space -> §3.2 constraint resolution ->
§3.3 Lasso ranking (~300 noisy test-cluster evaluations) -> §3.4 GP-BO
with dynamic boundaries over the top-K -> report vs default & expert
manual configs.  Prints the Table-2-style top-knob list and the
recommended config diff.
"""

import argparse
import json

from repro.core.strategy import BOConfig, strategy_names
from repro.core.tuner import Sapphire


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--top-k", type=int, default=16)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--batch", type=int, default=8,
                    help="configs per Experiment-Unit round (q-batch BO + "
                         "chunked ranking); 1 = the paper's sequential loop")
    ap.add_argument("--strategy", default="bo", choices=strategy_names(),
                    help="search-stage strategy from the registry")
    ap.add_argument("--async-eval", action="store_true",
                    help="drive rank/search through the overlapped "
                         "Controller.run_async loop (identical results on "
                         "the analytic test cluster; a wall-clock win on "
                         "services that stream completions out of order)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    s = Sapphire(
        arch=args.arch, shape=args.shape, top_k=args.top_k,
        multi_pod=args.multi_pod,
        n_rank_samples=120 if args.quick else 300,
        batch_size=args.batch,
        strategy=args.strategy,
        bo_config=BOConfig(n_init=8, n_iter=16 if args.quick else 48,
                           n_candidates=1024, fit_steps=100, seed=args.seed),
        seed=args.seed, async_eval=args.async_eval)
    res = s.tune()

    print("\n=== SAPPHIRE recommendation ===")
    print(json.dumps(res.summary(), indent=1, default=str))
    print("\ntop knobs (Table-2 style):")
    for r in res.ranking.table(args.top_k):
        print(f"  {r['knob']:28s} {r['type']:11s} default={r['default']!s:>8s}"
              f" range={r['range']:20s} imp={r['importance']:.4f}")
    print("\nrecommended config (non-default knobs only):")
    defaults = res.ranking.space.default_config()
    diff = {k: v for k, v in res.best_config.items()
            if defaults.get(k) != v}
    print(json.dumps(diff, indent=1, default=str))
    print(f"\nspeedup vs default: {res.speedup_vs_default:.2f}x | "
          f"vs expert manual: {res.speedup_vs_expert:.2f}x")


if __name__ == "__main__":
    main()

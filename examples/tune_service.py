"""Tuning as a service: two concurrent clients sharing one daemon.

    PYTHONPATH=src python examples/tune_service.py [--budget 12] [--seed 5]

Starts an in-process tuning daemon (the same `TuningServer` + HTTP wire
that ``python -m repro.service`` runs standalone), then drives two
concurrent client sessions against it over real HTTP:

* both tune the SAME workload with the same recipe — the daemon's
  cross-session probe cache dedupes their identical probes, so two
  clients cost roughly one client's evaluator calls;
* each still gets its own session: private namespace in the shared
  evaluation log, private strategy state, private incumbent.

Also shows the warm-restart loop: snapshot a session's strategy state
over the wire, close it, and resume a new session from that state.
"""

import argparse
import json
import threading

from repro.service import TuningClient, TuningServer, serve_background


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=12)
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--workload", default="yi-6b:train_4k")
    args = ap.parse_args()

    bo_cfg = {"n_init": 4, "n_iter": 8, "fit_steps": 20}

    tuning = TuningServer(max_workers=4)
    httpd, _ = serve_background(tuning)        # ephemeral port
    host, port = httpd.server_address[:2]
    base = f"http://{host}:{port}"
    print(f"daemon up on {base}")

    client = TuningClient(base)
    names = [w["name"] for w in client.workloads()]
    print(f"hosted workloads: {names}")

    # -- two concurrent sessions on the same workload ----------------------
    results = {}

    def tune(label):
        with client.create_session(
                args.workload, strategy="bo", budget=args.budget,
                seed=args.seed, strategy_kwargs={"cfg": bo_cfg},
                tag=label) as sess:
            out = sess.run()                   # server-side drive
            results[label] = out

    threads = [threading.Thread(target=tune, args=(f"client-{i}",))
               for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    for label, out in sorted(results.items()):
        print(f"{label}: best {out['best_value']:.4f} after "
              f"{out['n_evaluations']} evaluations")
    stats = client.stats()
    cache = stats["pool"]["cache"]
    print(f"shared pool: {stats['pool']['backend_calls']} evaluator calls "
          f"for both clients; cache {cache['hits']}/{cache['requests']} "
          f"hits ({cache['hit_rate']:.0%})")

    # -- warm restart: state over the wire ---------------------------------
    warm_src = client.create_session(
        args.workload, strategy="bo", budget=args.budget, seed=args.seed,
        strategy_kwargs={"cfg": bo_cfg}, tag="warm-src")
    warm_src.run()
    state = json.loads(json.dumps(warm_src.state()))   # wire round-trip
    warm_src.close()

    resumed = client.create_session(
        args.workload, strategy="bo", budget=args.budget + 6,
        seed=args.seed, strategy_kwargs={"cfg": bo_cfg},
        state=state, tag="warm-resume")
    out = resumed.run()
    print(f"warm restart: resumed with {state['evals_done']} post-init "
          f"evaluations banked, best {out['best_value']:.4f} with "
          f"{out['n_evaluations']} total on record")
    resumed.close()

    httpd.shutdown()
    tuning.close()


if __name__ == "__main__":
    main()

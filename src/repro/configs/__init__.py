"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full (paper-exact) ModelConfig;
``get_smoke_config(name)`` returns the reduced same-family config used by
CPU smoke tests (small widths/layers/experts, tiny vocab).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS = [
    "xlstm_1_3b",
    "qwen2_vl_72b",
    "mistral_nemo_12b",
    "codeqwen1_5_7b",
    "yi_6b",
    "qwen1_5_4b",
    "grok_1_314b",
    "qwen2_moe_a2_7b",
    "jamba_1_5_large_398b",
    "whisper_tiny",
]

# CLI-facing ids (match the assignment spelling)
ALIASES = {
    "xlstm-1.3b": "xlstm_1_3b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "yi-6b": "yi_6b",
    "qwen1.5-4b": "qwen1_5_4b",
    "grok-1-314b": "grok_1_314b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-tiny": "whisper_tiny",
}


def canonical(name: str) -> str:
    return ALIASES.get(name, name.replace("-", "_").replace(".", "_"))


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}

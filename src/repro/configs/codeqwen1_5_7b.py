"""codeqwen1.5-7b — qwen1.5-arch dense, QKV bias [hf:Qwen/CodeQwen1.5-7B].

32L d_model=4096 32H (GQA kv=32 == MHA) d_ff=13440 vocab=92416.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab_size=92416,
    qkv_bias=True,
    act="silu",
    rope_theta=1_000_000.0,
    pattern=(LayerSpec(kind="attn", mlp="dense"),),
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256,
)

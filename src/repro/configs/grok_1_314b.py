"""grok-1-314b — MoE 8e top-2 [hf:xai-org/grok-1].

64L d_model=6144 48H (GQA kv=8) d_ff=32768, vocab=131072.
GeLU experts, attn logit soft-cap 30, embedding multiplier ~sqrt(d).
"""

import math

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    act="gelu",
    rope_theta=10_000.0,
    logit_softcap=30.0,
    embedding_multiplier=math.sqrt(6144.0),
    n_experts=8,
    n_experts_per_tok=2,
    moe_d_ff=32768,
    pattern=(LayerSpec(kind="attn", mlp="moe"),),
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    moe_d_ff=128, vocab_size=256, n_experts=4, n_experts_per_tok=2,
    embedding_multiplier=8.0,
)

# Family defaults for the 70B+ tier: factored optimizer without f32
# masters (AdamW would need ~12 bytes/param of optimizer HBM — 4.7 TB for
# grok-1), full remat, minimum microbatch.  Still "default" in SAPPHIRE's
# sense: safe, not tuned.
RUN_OVERRIDES = dict(
    optimizer="adafactor",
    master_weights_f32=False,
    remat_policy="full",
    microbatch=1,
)

"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7, MoE 16e top-2
[arXiv:2403.19887 / 2408.12570; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.
Repeating 8-layer block: attention at position 4, mamba elsewhere;
MoE MLP on every other layer (odd positions), dense on even.
Hybrid (SSM-dominant) => sub-quadratic => long_500k runs.
"""

from repro.models.config import LayerSpec, ModelConfig

_pattern = tuple(
    LayerSpec(
        kind="attn" if i == 4 else "mamba",
        mlp="moe" if i % 2 == 1 else "dense",
    )
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    act="silu",
    rope_theta=10_000.0,          # jamba attn layers use no rope in v1; 1.5
                                  # keeps attention positions implicit — we
                                  # retain rope for the attn layers (adaptation)
    n_experts=16,
    n_experts_per_tok=2,
    moe_d_ff=24576,
    ssm_state_dim=16,
    ssm_conv_width=4,
    ssm_expand=2,
    pattern=_pattern,
    sub_quadratic=True,
)

SMOKE = CONFIG.scaled(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    moe_d_ff=128, vocab_size=256, n_experts=4, n_experts_per_tok=2,
    ssm_state_dim=8,
)

# Family defaults for the 70B+ tier: factored optimizer without f32
# masters (AdamW would need ~12 bytes/param of optimizer HBM — 4.7 TB for
# grok-1), full remat, minimum microbatch.  Still "default" in SAPPHIRE's
# sense: safe, not tuned.
RUN_OVERRIDES = dict(
    optimizer="adafactor",
    master_weights_f32=False,
    remat_policy="full",
    microbatch=1,
)

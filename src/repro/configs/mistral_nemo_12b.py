"""mistral-nemo-12b — dense GQA, 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
Nemo uses head_dim=128 explicitly (q width 4096 != d_model).
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    act="silu",
    rope_theta=1_000_000.0,
    pattern=(LayerSpec(kind="attn", mlp="dense"),),
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=80, n_heads=4, n_kv_heads=2, d_ff=160,
    head_dim=16, vocab_size=256,
)

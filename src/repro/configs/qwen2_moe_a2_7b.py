"""qwen2-moe-a2.7b — 60 routed experts top-4 + 4 shared [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16 == MHA) d_ff=1408/expert vocab=151936.
Shared experts form a dense MLP of width 4*1408 = 5632.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    act="silu",
    rope_theta=1_000_000.0,
    n_experts=60,
    n_experts_per_tok=4,
    n_shared_experts=4,
    moe_d_ff=1408,
    pattern=(LayerSpec(kind="attn", mlp="moe"),),
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
    moe_d_ff=32, vocab_size=256, n_experts=8, n_experts_per_tok=2,
    n_shared_experts=2,
)

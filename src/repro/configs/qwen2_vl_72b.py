"""qwen2-vl-72b — VLM backbone, M-RoPE [arXiv:2409.12191; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
Vision frontend is a STUB per the assignment: input_specs() supplies
(t, h, w) M-RoPE position ids; the backbone is the full text transformer.
M-RoPE sections (16, 24, 24) over head_dim/2 = 64.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    act="silu",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    pattern=(LayerSpec(kind="attn", mlp="dense"),),
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, mrope_sections=(2, 3, 3),  # head_dim 16 -> d/2 = 8
)

# Family defaults for the 70B+ tier: factored optimizer without f32
# masters (AdamW would need ~12 bytes/param of optimizer HBM — 4.7 TB for
# grok-1), full remat, minimum microbatch.  Still "default" in SAPPHIRE's
# sense: safe, not tuned.
RUN_OVERRIDES = dict(
    optimizer="adafactor",
    master_weights_f32=False,
    remat_policy="full",
    microbatch=1,
)

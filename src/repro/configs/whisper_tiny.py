"""whisper-tiny — enc-dec audio backbone, conv frontend stub [arXiv:2212.04356].

4+4L d_model=384 6H d_ff=1536 vocab=51865.  LayerNorm + GeLU (not RMS/SwiGLU).
input_specs() provides precomputed 1500-frame embeddings (the conv stub).
Decode shapes exercise the decoder backbone at the assigned 32k cache sizes
(a backbone capability; the speech product caps at 448 — DESIGN.md §6).
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                 # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    act="gelu",
    norm="layernorm",
    mlp_bias=True,
    qkv_bias=True,
    tie_embeddings=True,
    is_encoder_decoder=True,
    n_encoder_layers=4,
    encoder_seq=1500,
    frontend="audio_stub",
    pattern=(LayerSpec(kind="attn", mlp="dense"),),
)

SMOKE = CONFIG.scaled(
    n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, encoder_seq=32,
)

"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517].

48L d_model=2048 4H (GQA kv=4) d_ff=0 (no separate MLP; xLSTM blocks carry
their own projections) vocab=50304.  xLSTM[7:1]: every 8th layer is sLSTM.
Linear-recurrence => sub-quadratic => long_500k runs.
"""

from repro.models.config import LayerSpec, ModelConfig

_pattern = tuple(
    LayerSpec(kind="slstm" if i == 7 else "mlstm", mlp="none")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    act="gelu",
    mlstm_expand=2.0,
    slstm_proj=4.0 / 3.0,
    pattern=_pattern,
    sub_quadratic=True,
    tie_embeddings=False,
)

SMOKE = CONFIG.scaled(
    n_layers=8, d_model=64, n_heads=2, n_kv_heads=2, vocab_size=256,
)

"""SAPPHIRE core: the paper's contribution as a composable library.

Public API:
    Space / Knob / constraints    (§3.2  — repro.core.space, .constraints)
    lasso_path / rank             (§3.3  — repro.core.lasso, .ranking)
    gp / SearchStrategy / make_strategy
                                  (§3.4  — repro.core.gp, .strategy; the
                                   ask/tell Search Unit.  bo.minimize and
                                   optimizers.* are deprecated wrappers)
    EvalRequest / EvalResult / EvaluationService
                                  (Fig 3 — repro.core.service; the
                                   Experiment Unit as an async job queue:
                                   submit/poll/gather/drain)
    Controller.run / .run_async / EvalDB
                                  (Fig 3 — repro.core.controller; the
                                   experiment loops, incl. two-fidelity
                                   successive halving)
    Sapphire(...).tune()          (Fig 3 — repro.core.tuner; rank ->
                                   search -> validate stages)
    RetryPolicy / ResilientService / CircuitBreaker / FaultPlan /
    FaultInjectingService         (repro.core.resilience, .faults; the
                                   fault-tolerant evaluation layer and
                                   the seeded chaos harness that tests it)
"""

from repro.core.faults import (FaultInjectingService,  # noqa: F401
                               FaultPlan)
from repro.core.resilience import (CircuitBreaker,  # noqa: F401
                                   ResilientService, RetryPolicy,
                                   TransientEvalError, classify_failure)
from repro.core.service import (CallableServiceAdapter,  # noqa: F401
                                EvalRequest, EvalResult, EvalTicket,
                                EvaluationService, FidelityRouter,
                                ImmediateEvaluationService,
                                WorkerPoolEvaluationService, as_service)
from repro.core.space import Config, Knob, Space  # noqa: F401
from repro.core.strategy import (SearchStrategy, Trace,  # noqa: F401
                                 make_strategy, strategy_names)
from repro.core.tuner import Sapphire, TuneResult  # noqa: F401

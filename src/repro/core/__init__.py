"""SAPPHIRE core: the paper's contribution as a composable library.

Public API:
    Space / Knob / constraints    (§3.2  — repro.core.space, .constraints)
    lasso_path / rank             (§3.3  — repro.core.lasso, .ranking)
    gp / bo.minimize              (§3.4  — repro.core.gp, .bo)
    Sapphire(...).tune()          (Fig 3 — repro.core.tuner)
"""

from repro.core.space import Config, Knob, Space  # noqa: F401
from repro.core.tuner import Sapphire, TuneResult  # noqa: F401

"""SAPPHIRE core: the paper's contribution as a composable library.

Public API:
    Space / Knob / constraints    (§3.2  — repro.core.space, .constraints)
    lasso_path / rank             (§3.3  — repro.core.lasso, .ranking)
    gp / SearchStrategy / make_strategy
                                  (§3.4  — repro.core.gp, .strategy; the
                                   ask/tell Search Unit.  bo.minimize and
                                   optimizers.* are deprecated wrappers)
    Controller.run / EvalDB       (Fig 3 — repro.core.controller; the
                                   experiment loop, incl. two-fidelity
                                   successive halving)
    Sapphire(...).tune()          (Fig 3 — repro.core.tuner; rank ->
                                   search -> validate stages)
"""

from repro.core.space import Config, Knob, Space  # noqa: F401
from repro.core.strategy import (SearchStrategy, Trace,  # noqa: F401
                                 make_strategy, strategy_names)
from repro.core.tuner import Sapphire, TuneResult  # noqa: F401

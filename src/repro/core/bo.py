"""Bayesian optimization with GP surrogate + dynamic boundaries (§3.4, Fig 4).

The Search Unit of the paper's experiment-driven loop:

  1. evaluate an initial design (LHS over the clean domain);
  2. fit the GP to all (config, metric) history — noise-tolerant;
  3. maximize Expected Improvement over candidate configs (random +
     best-point perturbations — the standard derivative-free acquisition
     maximization at these dimensionalities);
  4. if the chosen probe sits near a ``dynamic_bound`` edge, ENLARGE that
     knob's boundary (paper Fig. 4) and re-encode history;
  5. evaluate, append, repeat until the budget is exhausted.

Works on any objective ``f(config) -> float`` (lower is better).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import gp
from repro.core.sampling import latin_hypercube, lhs_unit
from repro.core.space import Config, Space


@dataclass
class BOTrace:
    configs: List[Config] = field(default_factory=list)
    values: List[float] = field(default_factory=list)
    best_values: List[float] = field(default_factory=list)   # running min
    boundary_events: List[Tuple[int, str]] = field(default_factory=list)

    @property
    def best(self) -> Tuple[Config, float]:
        i = int(np.argmin(self.values))
        return self.configs[i], self.values[i]


@dataclass
class BOConfig:
    n_init: int = 8                 # initial LHS design
    n_iter: int = 48                # BO iterations after the design
    n_candidates: int = 2048        # acquisition candidates per iteration
    n_local: int = 256              # perturbations around the incumbent
    local_sigma: float = 0.08
    kernel: str = "matern52"
    fit_steps: int = 150
    acquisition: str = "ei"         # ei | ucb
    log_objective: bool = True      # model log(y): heavy-tailed penalties
                                    # (OOM probes) otherwise flatten the GP
    dynamic_boundary: bool = True
    boundary_tol: float = 0.05
    boundary_factor: float = 2.0
    seed: int = 0


def _acq_argmax(state, cand_u, best_y, cfg: BOConfig) -> int:
    if cfg.acquisition == "ei":
        a = gp.expected_improvement(state, cand_u, best_y, cfg.kernel)
    else:
        a = gp.ucb(state, cand_u, cfg.kernel)
    return int(np.argmax(np.asarray(a)))


def minimize(f: Callable[[Config], float], space: Space,
             cfg: Optional[BOConfig] = None,
             init_configs: Optional[List[Config]] = None) -> Tuple[Config, float, BOTrace, Space]:
    """Run GP-BO.  Returns (best config, best value, trace, final space).

    The returned space reflects any dynamic-boundary enlargements — the
    recommendation report includes the final domain, as the paper's Fig. 4
    experiment does.
    """
    cfg = cfg or BOConfig()
    rng = np.random.default_rng(cfg.seed)
    trace = BOTrace()

    # -- initial design ------------------------------------------------------
    init = list(init_configs or [])
    need = max(cfg.n_init - len(init), 0)
    if need:
        init += latin_hypercube(space, need, seed=cfg.seed)
    for c in init:
        c = space.project(c)
        v = float(f(c))
        trace.configs.append(c)
        trace.values.append(v)
        trace.best_values.append(min(trace.values))

    # -- BO loop ---------------------------------------------------------------
    for it in range(cfg.n_iter):
        x = np.stack([space.to_unit(c) for c in trace.configs])
        y = np.asarray(trace.values, np.float64)
        if cfg.log_objective:
            y = np.log(np.maximum(y, 1e-12))
        state = gp.fit(x, y, cfg.kernel, steps=cfg.fit_steps)

        # candidates: global LHS + Gaussian ball + per-knob incumbent
        # mutations.  The Gaussian ball almost never crosses a bool /
        # categorical decision boundary (σ=0.08 in unit space), so EI can
        # sit in a basin forever without trying `tensor_parallel=False`;
        # the axis sweeps make every single-knob move visible.
        d = len(space)
        cand = lhs_unit(rng, cfg.n_candidates, d)
        inc = space.to_unit(trace.best[0])
        local = np.clip(inc[None] + rng.normal(0, cfg.local_sigma,
                                               (cfg.n_local, d)), 0, 1)
        sweeps = []
        for j in range(d):
            for u in (0.0, 0.25, 0.5, 0.75, 1.0):
                m = inc.copy()
                m[j] = u
                sweeps.append(m)
        cand = np.vstack([cand, local, np.asarray(sweeps)])
        best_y = float(np.min(y))
        # standardize best for the EI threshold the way gp.fit standardizes y
        j = _acq_argmax(state, cand.astype(np.float32), best_y, cfg)
        probe_u = cand[j]
        probe = space.from_unit(probe_u)

        # -- dynamic boundary (paper Fig. 4) ---------------------------------
        if cfg.dynamic_boundary:
            near = space.near_boundary(probe, cfg.boundary_tol)
            if near:
                space = space.expand_boundaries(near, cfg.boundary_factor)
                for n in near:
                    trace.boundary_events.append((it, n))

        v = float(f(probe))
        trace.configs.append(probe)
        trace.values.append(v)
        trace.best_values.append(min(trace.values))

    best_c, best_v = trace.best
    return best_c, best_v, trace, space

"""Bayesian optimization with GP surrogate + dynamic boundaries (§3.4, Fig 4).

The Search Unit of the paper's experiment-driven loop, batch-first:

  1. evaluate an initial design (LHS over the clean domain) — as one
     batch when the Experiment Unit can score configs concurrently;
  2. fit the GP to all (config, metric) history — noise-tolerant, with
     hyperparameters warm-started from the previous round;
  3. select a *q-batch* of probes by constant-liar Expected Improvement:
     pick the EI argmax over the candidate pool, fantasize its outcome at
     the incumbent best (the "lie"), recondition the posterior (fixed
     hyperparameters, one Cholesky), repeat q times — the lie zeroes EI
     around chosen probes so the batch spreads instead of stacking;
  4. if any chosen probe sits near a ``dynamic_bound`` edge, ENLARGE that
     knob's boundary (paper Fig. 4) and re-encode history;
  5. evaluate the batch, append it, repeat until the budget is exhausted.

``batch_size=1`` reduces to the classic sequential loop (one probe per GP
refit).  Works on any objective ``f(config) -> float`` (lower is better);
pass ``f_batch`` to score a whole probe batch in one call (see
``Controller.evaluate_batch``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import gp
from repro.core.sampling import lhs_unit
from repro.core.space import Config, Space


@dataclass
class BOTrace:
    configs: List[Config] = field(default_factory=list)
    values: List[float] = field(default_factory=list)
    best_values: List[float] = field(default_factory=list)   # running min
    boundary_events: List[Tuple[int, str]] = field(default_factory=list)

    @property
    def best(self) -> Tuple[Config, float]:
        i = int(np.argmin(self.values))
        return self.configs[i], self.values[i]

    def extend(self, configs: Sequence[Config], values: Sequence[float]):
        for c, v in zip(configs, values):
            self.configs.append(c)
            self.values.append(float(v))
            self.best_values.append(min(self.best_values[-1], float(v))
                                    if self.best_values else float(v))


@dataclass
class BOConfig:
    n_init: int = 8                 # initial LHS design
    n_iter: int = 48                # BO evaluations after the design
    batch_size: int = 1             # q: probes per GP refit (constant-liar
                                    # q-EI); 1 = the classic sequential loop
    n_candidates: int = 2048        # acquisition candidates per iteration
    n_local: int = 256              # perturbations around the incumbent
    local_sigma: float = 0.08
    kernel: str = "matern52"
    fit_steps: int = 150
    fit_steps_warm: Optional[int] = None   # Adam steps on warm-started
                                           # rounds (None: fit_steps // 3)
    warm_start: bool = False        # reuse GP hyperparams across rounds.
                                    # Off by default so sequential callers
                                    # keep the paper's full refit-per-eval
                                    # loop; Sapphire turns it on whenever
                                    # batching is requested
    acquisition: str = "ei"         # ei | ucb
    log_objective: bool = True      # model log(y): heavy-tailed penalties
                                    # (OOM probes) otherwise flatten the GP
    fantasy: str = "liar"           # q-batch fantasy value: "liar"
                                    # (constant liar at the incumbent best
                                    # — matches the sequential optimum
                                    # within noise on every seed tried) |
                                    # "believer" (Kriging believer —
                                    # posterior mean at the pick)
    dynamic_boundary: bool = True
    boundary_tol: float = 0.05
    boundary_factor: float = 2.0
    seed: int = 0


def _acq(state, cand_u, best_y, cfg: BOConfig) -> np.ndarray:
    if cfg.acquisition == "ei":
        a = gp.expected_improvement(state, cand_u, best_y, cfg.kernel)
    else:
        a = gp.ucb(state, cand_u, cfg.kernel)
    return np.array(a)      # writable copy (jax buffers are read-only)


def _select_batch(state, cand: np.ndarray, best_y: float, q: int,
                  cfg: BOConfig, x: np.ndarray, y: np.ndarray,
                  pad_to: Optional[int]) -> List[np.ndarray]:
    """Fantasized q-EI: argmax over the pool, fantasize the pick's
    outcome, recondition the posterior (fixed hyperparams, one Cholesky),
    repeat.  EI collapses at the fantasized probe — via the variance for
    the Kriging believer, via the mean for the constant liar — so later
    picks spread over the pool instead of stacking on the first argmax."""
    cand32 = cand.astype(np.float32)
    taken = np.zeros(len(cand), bool)
    picks: List[np.ndarray] = []
    x_aug, y_aug = x, y
    for j in range(q):
        a = _acq(state, cand32, best_y, cfg)
        a[taken] = -np.inf
        i = int(np.argmax(a))
        taken[i] = True
        picks.append(cand[i])
        if j < q - 1:
            if cfg.fantasy == "believer":
                mu, _ = gp.predict(state, cand32[i][None], cfg.kernel)
                lie = float(mu[0])
            else:
                lie = best_y
            x_aug = np.vstack([x_aug, cand[i][None]])
            y_aug = np.append(y_aug, lie)
            state = gp.condition(state.params, x_aug, y_aug, cfg.kernel,
                                 pad_to=pad_to)
    return picks


def minimize(f: Callable[[Config], float], space: Space,
             cfg: Optional[BOConfig] = None,
             init_configs: Optional[List[Config]] = None,
             f_batch: Optional[Callable[[Sequence[Config]],
                                        Sequence[float]]] = None,
             ) -> Tuple[Config, float, BOTrace, Space]:
    """Run GP-BO.  Returns (best config, best value, trace, final space).

    ``cfg.n_iter`` counts *evaluations* after the initial design, so the
    experiment budget is identical for every ``batch_size`` — a q-batch
    run spends the same budget in ~n_iter/q GP refits.

    The returned space reflects any dynamic-boundary enlargements — the
    recommendation report includes the final domain, as the paper's Fig. 4
    experiment does.
    """
    cfg = cfg or BOConfig()
    rng = np.random.default_rng(cfg.seed)
    trace = BOTrace()
    use_batch = cfg.batch_size > 1 and f_batch is not None

    # -- initial design ------------------------------------------------------
    init = list(init_configs or [])
    need = max(cfg.n_init - len(init), 0)
    if need:
        init += space.decode_batch(lhs_unit(rng, need, len(space)))
    init = space.project_batch(init)
    if use_batch:
        trace.extend(init, f_batch(init))
    else:
        trace.extend(init, [float(f(c)) for c in init])

    # fix the padded GP shape for the whole run: every jit (fit scan,
    # posterior build, EI) compiles once instead of once per size bucket
    pad_to = gp._bucket(len(trace.configs) + cfg.n_iter)

    # -- BO loop ---------------------------------------------------------------
    params = None
    evals_done = 0
    while evals_done < cfg.n_iter:
        # clamp: nonsense batch_size (<=0) degrades to sequential, and the
        # last round never overshoots the evaluation budget
        q = max(min(cfg.batch_size, cfg.n_iter - evals_done), 1)
        x = space.encode_batch(trace.configs)
        y = np.asarray(trace.values, np.float64)
        if cfg.log_objective:
            y = np.log(np.maximum(y, 1e-12))
        steps = cfg.fit_steps
        warm = None
        if cfg.warm_start and params is not None:
            warm = params
            steps = (cfg.fit_steps_warm if cfg.fit_steps_warm is not None
                     else max(cfg.fit_steps // 3, 20))
        state = gp.fit(x, y, cfg.kernel, steps=steps, params=warm,
                       pad_to=pad_to)
        params = state.params

        # candidates: global LHS + Gaussian ball + per-knob incumbent
        # mutations.  The Gaussian ball almost never crosses a bool /
        # categorical decision boundary (σ=0.08 in unit space), so EI can
        # sit in a basin forever without trying `tensor_parallel=False`;
        # the axis sweeps make every single-knob move visible.
        d = len(space)
        cand = lhs_unit(rng, cfg.n_candidates, d)
        inc = space.to_unit(trace.best[0])
        local = np.clip(inc[None] + rng.normal(0, cfg.local_sigma,
                                               (cfg.n_local, d)), 0, 1)
        sweeps = []
        for j in range(d):
            for u in (0.0, 0.25, 0.5, 0.75, 1.0):
                m = inc.copy()
                m[j] = u
                sweeps.append(m)
        cand = np.vstack([cand, local, np.asarray(sweeps)])
        best_y = float(np.min(y))
        picks = _select_batch(state, cand, best_y, q, cfg, x, y, pad_to)
        probes = space.decode_batch(np.stack(picks))

        # -- dynamic boundary (paper Fig. 4), once over the whole batch ------
        if cfg.dynamic_boundary:
            near: List[str] = []
            for probe in probes:
                for n in space.near_boundary(probe, cfg.boundary_tol):
                    if n not in near:
                        near.append(n)
            if near:
                space = space.expand_boundaries(near, cfg.boundary_factor)
                for n in near:
                    trace.boundary_events.append((evals_done, n))

        if use_batch:
            trace.extend(probes, f_batch(probes))
        else:
            trace.extend(probes, [float(f(c)) for c in probes])
        evals_done += len(probes)

    best_c, best_v = trace.best
    return best_c, best_v, trace, space

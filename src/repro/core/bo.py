"""Bayesian optimization entry point (§3.4, Fig 4) — legacy wrapper.

.. deprecated::
    The GP-BO loop now lives in :class:`repro.core.strategy.BOStrategy`
    (ask/tell — it never calls an objective) and the evaluation loop in
    :meth:`repro.core.controller.Controller.run`.  ``minimize`` survives
    as a thin synchronous driver over the strategy so existing callers,
    tests and benchmarks keep working; new code should compose a strategy
    with a Controller instead::

        ctrl = Controller(evaluator, EvalDB())
        strategy = BOStrategy(space, BOConfig(...))
        trace = ctrl.run(strategy)

``BOConfig`` and ``BOTrace`` are re-exported from ``repro.core.strategy``
(where ``BOTrace`` is now the strategy-generic ``Trace``).
"""

from __future__ import annotations

import warnings
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.space import Config, Space
from repro.core.strategy import (BOConfig, BOStrategy,  # noqa: F401
                                 Trace)

BOTrace = Trace     # legacy name


def minimize(f: Callable[[Config], float], space: Space,
             cfg: Optional[BOConfig] = None,
             init_configs: Optional[List[Config]] = None,
             f_batch: Optional[Callable[[Sequence[Config]],
                                        Sequence[float]]] = None,
             ) -> Tuple[Config, float, BOTrace, Space]:
    """Run GP-BO.  Returns (best config, best value, trace, final space).

    ``cfg.n_iter`` counts *evaluations* after the initial design, so the
    experiment budget is identical for every ``batch_size`` — a q-batch
    run spends the same budget in ~n_iter/q GP refits.

    The returned space reflects any dynamic-boundary enlargements — the
    recommendation report includes the final domain, as the paper's Fig. 4
    experiment does.

    Deprecated wrapper: drives a :class:`BOStrategy` synchronously —
    ``ask`` the next probe batch, score it through ``f`` (or ``f_batch``
    when batching is on), ``tell`` the results.
    """
    warnings.warn(
        "bo.minimize is deprecated: compose a strategy with the experiment "
        "loop instead — Controller(evaluator, EvalDB()).run(BOStrategy("
        "space, cfg)) (or Controller.run_async for the overlapped loop)",
        DeprecationWarning, stacklevel=2)
    cfg = cfg or BOConfig()
    use_batch = cfg.batch_size > 1 and f_batch is not None
    strat = BOStrategy(space, cfg, init_configs=init_configs)
    try:
        while not strat.finished:
            probes = strat.ask()
            if not probes:
                break
            if use_batch:
                values = f_batch(probes)
            else:
                values = [float(f(c)) for c in probes]
            strat.tell(probes, values)
    finally:
        # refit_async spawns a background executor (possibly pinned to a
        # spare device); legacy callers never see the strategy, so the
        # wrapper owns the join.
        strat.close()
    best_c, best_v = strat.best()
    return best_c, best_v, strat.trace, strat.space

"""Parameter-constraint resolution (paper §3.2).

Pipeline over a raw :class:`~repro.core.space.Space`:

  1. **washing**  — drop C1 unconfigurable knobs (ids, addresses, paths) —
     the paper does this by static analysis of Ceph's config source; here
     the raw space carries ``configurable=False`` tags produced by the knob
     generator (knobs.py), and washing removes them.
  2. **pruning**  — C3: given the user case (which modules are exercised by
     the target workload), pin module-selector knobs whose value is forced,
     and drop knobs belonging to modules that cannot take effect.
  3. **boundary** — C2: every surviving numeric knob must have finite
     [lo, hi]; knobs without developer-documented bounds get a default box
     around the default value and are flagged ``dynamic_bound`` so the
     optimizer may enlarge it later (paper Fig. 4).

The output is the paper's "clean and complete configurable parameter
space": no misconfigurations representable, well-defined boundaries,
C4 constraints attached for projection.
"""

from __future__ import annotations

import math
from dataclasses import replace
from typing import Dict, List, Optional, Set, Tuple

from repro.core.space import Config, Knob, Space


def wash(space: Space) -> Space:
    """C1: remove unconfigurable knobs entirely."""
    knobs = tuple(k for k in space.knobs if k.configurable)
    keep = {k.name for k in knobs}
    cons = tuple(c for c in space.constraints if all(n in keep for n in c.knobs))
    return Space(knobs, cons)


def prune(space: Space, pinned: Optional[Dict[str, object]] = None) -> Tuple[Space, Config]:
    """C3: pin module selectors and drop gated knobs that cannot activate.

    ``pinned`` maps selector-knob names to their forced value for this user
    case (e.g. ``{"optimizer": "adamw"}`` when the workload trains with
    AdamW, the way the paper pins ``osd_objectstore`` for a Bluestore
    deployment).  Returns the pruned space and the pin assignments (which
    become part of the recommended config verbatim).
    """
    pinned = dict(pinned or {})
    dropped: Set[str] = set()
    knobs: List[Knob] = []
    for k in space.knobs:
        if k.name in pinned:
            dropped.add(k.name)          # selector fixed -> not searched
            continue
        if k.gated_by is not None:
            sel, enabling = k.gated_by
            if sel in pinned and pinned[sel] not in enabling:
                dropped.add(k.name)      # module not in use -> prune
                continue
        knobs.append(k)
    keep = {k.name for k in knobs}
    cons = tuple(c for c in space.constraints if all(n in keep for n in c.knobs))
    return Space(tuple(knobs), cons), pinned


DEFAULT_SPAN = 8.0   # default box: [default/8, default*8] (log) when unbounded


def synthesize_boundaries(space: Space) -> Space:
    """C2: give every numeric knob a finite box.

    Knobs that already carry developer bounds are kept as-is.  Unbounded
    knobs get a box spanning ``DEFAULT_SPAN``× around the default and are
    flagged dynamic (the optimizer may enlarge it — the static-box failure
    mode of paper Fig. 4 is exactly what this avoids).
    """
    out = []
    for k in space.knobs:
        if k.kind not in ("int", "float"):
            out.append(k)
            continue
        if k.lo is not None and k.hi is not None and math.isfinite(k.lo) \
                and math.isfinite(k.hi):
            out.append(k)
            continue
        d = float(k.default) if float(k.default) != 0 else 1.0
        lo, hi = abs(d) / DEFAULT_SPAN, abs(d) * DEFAULT_SPAN
        if k.kind == "int":
            lo, hi = max(1, math.floor(lo)), max(2, math.ceil(hi))
        out.append(replace(k, lo=lo, hi=hi, log_scale=True, dynamic_bound=True))
    return Space(tuple(out), space.constraints)


def resolve(space: Space, pinned: Optional[Dict[str, object]] = None
            ) -> Tuple[Space, Config, Dict[str, int]]:
    """Full §3.2 pipeline: wash -> prune -> boundary synthesis.

    Returns (clean space, pinned assignments, stage report).
    """
    n0 = len(space)
    w = wash(space)
    n1 = len(w)
    p, pins = prune(w, pinned)
    n2 = len(p)
    b = synthesize_boundaries(p)
    report = {"raw": n0, "washed": n0 - n1, "pruned": n1 - n2, "clean": n2}
    return b, pins, report

"""The SAPPHIRE Controller (paper Fig. 3).

Owns the **evaluation database** (append-only JSONL, the paper's store of
"all the system measurement results") and wires the Experiment Unit
(an evaluator callable) to the Search Unit (one of the optimizers).  On a
real fleet the controller additionally injects runtime-settable knobs
without restart (``Knob.restart_required=False``) and schedules
recompile/redeploy for the rest — recorded per evaluation so the
recommendation report can state the application cost of the final config.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.evaluators import evaluate_many
from repro.core.space import Config, Space


@dataclass
class EvalRecord:
    config: Config
    value: float
    wall_s: float
    tag: str = ""


class EvalDB:
    """Append-only evaluation log; reloadable for warm-started ranking."""

    def __init__(self, path: Optional[str] = None):
        self.path = Path(path) if path else None
        self.records: List[EvalRecord] = []
        if self.path and self.path.exists():
            for line in self.path.read_text().splitlines():
                if not line.strip():
                    continue
                d = json.loads(line)
                self.records.append(EvalRecord(d["config"], d["value"],
                                               d.get("wall_s", 0.0),
                                               d.get("tag", "")))

    @staticmethod
    def _line(rec: EvalRecord) -> str:
        return json.dumps({"config": {k: _json_safe(v) for k, v
                                      in rec.config.items()},
                           "value": _json_safe(rec.value),
                           "wall_s": rec.wall_s,
                           "tag": rec.tag}) + "\n"

    def append(self, rec: EvalRecord):
        self.append_batch([rec])

    def append_batch(self, recs: Sequence[EvalRecord]):
        """Record a whole evaluation batch: one list extend, one file
        append (a batched experiment is the unit of work, and on a fleet
        the JSONL write is a remote call worth amortizing)."""
        self.records.extend(recs)
        if self.path and recs:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            with self.path.open("a") as f:
                f.writelines(self._line(r) for r in recs)

    def pairs(self, tag: Optional[str] = None) -> Tuple[List[Config], List[float]]:
        rs = [r for r in self.records if tag is None or r.tag == tag]
        return [r.config for r in rs], [r.value for r in rs]

    def __len__(self):
        return len(self.records)


def _json_safe(v):
    import numpy as np
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (np.bool_,)):
        return bool(v)
    return v


@dataclass
class Controller:
    """Experiment Unit wrapper: evaluates configs, logs to the DB."""

    evaluate: Callable[[Config], float]
    db: EvalDB = field(default_factory=EvalDB)
    tag: str = ""

    def __call__(self, cfg: Config) -> float:
        t0 = time.monotonic()
        v = float(self.evaluate(cfg))
        self.db.append(EvalRecord(dict(cfg), v, time.monotonic() - t0,
                                  self.tag))
        return v

    def evaluate_batch(self, cfgs: Sequence[Config]) -> List[float]:
        """Evaluate a whole batch (via the evaluator's ``evaluate_batch``
        when it has one) and record it as one tagged DB append.  Each
        record's ``wall_s`` is the batch wall-clock amortized per config."""
        cfgs = [dict(c) for c in cfgs]
        t0 = time.monotonic()
        vals = evaluate_many(self.evaluate, cfgs)
        wall = (time.monotonic() - t0) / max(len(cfgs), 1)
        self.db.append_batch([EvalRecord(c, v, wall, self.tag)
                              for c, v in zip(cfgs, vals)])
        return vals

    def with_tag(self, tag: str) -> "Controller":
        return Controller(self.evaluate, self.db, tag)

    def restart_cost(self, space: Space, old: Config, new: Config) -> int:
        """How many changed knobs force a restart/recompile (fleet cost)."""
        n = 0
        for k in space.knobs:
            if old.get(k.name) != new.get(k.name) and k.restart_required:
                n += 1
        return n

"""The SAPPHIRE Controller (paper Fig. 3) — the experiment loop.

Owns the **evaluation database** (append-only JSONL, the paper's store of
"all the system measurement results") and drives any ask/tell
:class:`~repro.core.strategy.SearchStrategy` against any evaluator:

    ctrl = Controller(evaluator, EvalDB("evals.jsonl"), tag="bo")
    trace = ctrl.run(make_strategy("bo", space, cfg=BOConfig(...)))

:meth:`Controller.run` is the single synchronous loop every strategy goes
through — probes are scored as whole batches (``evaluate_batch``), every
batch is one tagged DB append, and an ``on_round`` hook fires after each
round so a future async loop can overlap GP refits with in-flight batches.
:meth:`Controller.run_successive_halving` adds the two-fidelity schedule:
each round screens a wide candidate batch on this controller's cheap
evaluator and promotes only the top scorers to a high-fidelity (compiled)
validation — the strategy is told every candidate, promoted ones at their
high-fidelity value.

On a real fleet the controller additionally injects runtime-settable knobs
without restart (``Knob.restart_required=False``) and schedules
recompile/redeploy for the rest — recorded per evaluation so the
recommendation report can state the application cost of the final config.
"""

from __future__ import annotations

import json
import time
import warnings
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.evaluators import evaluate_many
from repro.core.space import Config, Space
from repro.core.strategy import SearchStrategy, Trace


@dataclass
class EvalRecord:
    config: Config
    value: float
    wall_s: float
    tag: str = ""


class EvalDB:
    """Append-only evaluation log; reloadable for warm-started ranking."""

    def __init__(self, path: Optional[str] = None):
        self.path = Path(path) if path else None
        self.records: List[EvalRecord] = []
        if self.path and self.path.exists():
            for i, line in enumerate(self.path.read_text().splitlines()):
                if not line.strip():
                    continue
                try:
                    d = json.loads(line)
                    rec = EvalRecord(
                        {k: _json_safe(v) for k, v in d["config"].items()},
                        float(d["value"]), float(d.get("wall_s", 0.0)),
                        str(d.get("tag", "")))
                except (json.JSONDecodeError, KeyError, TypeError,
                        ValueError):
                    # a crashed writer leaves a truncated trailing line;
                    # the rest of the log is still good history
                    warnings.warn(f"EvalDB: skipping corrupt line {i + 1} "
                                  f"of {self.path}")
                    continue
                self.records.append(rec)

    @staticmethod
    def _sanitize(rec: EvalRecord) -> EvalRecord:
        """Normalize numpy scalars at append time so in-memory records,
        the JSONL on disk, and reloaded records all compare equal."""
        return EvalRecord({k: _json_safe(v) for k, v in rec.config.items()},
                          float(_json_safe(rec.value)), rec.wall_s, rec.tag)

    @staticmethod
    def _line(rec: EvalRecord) -> str:
        return json.dumps({"config": rec.config,
                           "value": rec.value,
                           "wall_s": rec.wall_s,
                           "tag": rec.tag}) + "\n"

    def append(self, rec: EvalRecord):
        self.append_batch([rec])

    def append_batch(self, recs: Sequence[EvalRecord]):
        """Record a whole evaluation batch: one list extend, one file
        append (a batched experiment is the unit of work, and on a fleet
        the JSONL write is a remote call worth amortizing)."""
        recs = [self._sanitize(r) for r in recs]
        self.records.extend(recs)
        if self.path and recs:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            with self.path.open("a") as f:
                f.writelines(self._line(r) for r in recs)

    def pairs(self, tag: Optional[str] = None) -> Tuple[List[Config], List[float]]:
        rs = [r for r in self.records if tag is None or r.tag == tag]
        return [r.config for r in rs], [r.value for r in rs]

    def __len__(self):
        return len(self.records)


def _json_safe(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (np.bool_,)):
        return bool(v)
    return v


@dataclass
class Controller:
    """Experiment Unit driver: evaluates configs, logs to the DB, and runs
    the ask/tell loop for any search strategy.

    ``prepare`` (optional) maps a strategy-side config to the full config
    the evaluator runs — e.g. expanding a top-K sub-config over pinned
    defaults.  The *prepared* config is what the DB records, so the log
    always holds runnable configurations.
    """

    evaluate: Callable[[Config], float]
    db: EvalDB = field(default_factory=EvalDB)
    tag: str = ""
    prepare: Optional[Callable[[Config], Config]] = None

    def __call__(self, cfg: Config) -> float:
        cfg = self.prepare(cfg) if self.prepare else cfg
        t0 = time.monotonic()
        v = float(self.evaluate(cfg))
        self.db.append(EvalRecord(dict(cfg), v, time.monotonic() - t0,
                                  self.tag))
        return v

    def evaluate_batch(self, cfgs: Sequence[Config]) -> List[float]:
        """Evaluate a whole batch (via the evaluator's ``evaluate_batch``
        when it has one) and record it as one tagged DB append.  Each
        record's ``wall_s`` is the batch wall-clock amortized per config."""
        cfgs = [dict(c) for c in cfgs]
        if self.prepare:
            cfgs = [self.prepare(c) for c in cfgs]
        t0 = time.monotonic()
        vals = evaluate_many(self.evaluate, cfgs)
        wall = (time.monotonic() - t0) / max(len(cfgs), 1)
        self.db.append_batch([EvalRecord(c, v, wall, self.tag)
                              for c, v in zip(cfgs, vals)])
        return vals

    def with_tag(self, tag: str) -> "Controller":
        return Controller(self.evaluate, self.db, tag, self.prepare)

    def with_prepare(self, prepare: Callable[[Config], Config]) -> "Controller":
        return Controller(self.evaluate, self.db, self.tag, prepare)

    # ---- the experiment loop ------------------------------------------------

    def run(self, strategy: SearchStrategy, budget: Optional[int] = None,
            batch_size: Optional[int] = None,
            on_round: Optional[Callable[[int, List[Config], List[float]],
                                        None]] = None) -> Trace:
        """Drive ``strategy`` to completion: ask a probe batch, score it,
        tell the results, repeat until the strategy's budget is told (or
        ``budget`` evaluations have been spent here, when given).

        ``on_round(round_index, configs, values)`` fires after each tell —
        the seam where a future async controller overlaps the next GP
        refit with an in-flight Experiment-Unit batch (see ROADMAP).
        """
        spent = 0
        rnd = 0
        while not strategy.finished:
            n = batch_size
            remaining = None
            if budget is not None:
                remaining = budget - spent
                if remaining <= 0:
                    break
                if n is not None:
                    n = min(n, remaining)
            cfgs = strategy.ask(n)
            if not cfgs:
                break
            if remaining is not None and len(cfgs) > remaining:
                # cap the spend without distorting the strategy's batch
                # width: the final round is truncated, not re-asked
                cfgs = cfgs[:remaining]
            vals = self.evaluate_batch(cfgs)
            strategy.tell(cfgs, vals)
            spent += len(cfgs)
            if on_round is not None:
                on_round(rnd, cfgs, vals)
            rnd += 1
        return strategy.trace

    def run_successive_halving(
            self, strategy: SearchStrategy,
            high: Union["Controller", Callable[[Config], float]],
            rounds: int, screen: int, promote: int,
            screen_tag: str = "screen", promote_tag: str = "promote",
            on_round: Optional[Callable[[int, Dict], None]] = None,
    ) -> Tuple[Config, float, List[Dict]]:
        """Two-fidelity successive halving: per round, ask ``screen``
        candidates, score them all on *this* controller's cheap evaluator
        (the analytic test cluster), promote the ``promote`` best to the
        ``high``-fidelity evaluator (the compiled product cluster), and
        tell the strategy every candidate — promoted ones at their
        high-fidelity value, the rest at their screen value (a cheap
        multi-fidelity prior for the surrogate).

        Returns ``(best_config, best_value, schedule)`` where best is over
        *high-fidelity* measurements only and ``schedule`` records, per
        round, what was screened and what was promoted.
        """
        if isinstance(high, Controller):
            high_ctrl = high if high.tag else high.with_tag(promote_tag)
        else:
            # a bare evaluator inherits this controller's prepare hook —
            # both fidelities must score the same completed config
            high_ctrl = Controller(high, self.db, promote_tag, self.prepare)
        screen_ctrl = self.with_tag(screen_tag)
        best_c: Optional[Config] = None
        best_v = float("inf")
        schedule: List[Dict] = []
        for rnd in range(rounds):
            if strategy.finished:
                break
            cands = strategy.ask(screen)
            if not cands:
                break
            screen_vals = screen_ctrl.evaluate_batch(cands)
            order = np.argsort(screen_vals, kind="stable")
            keep = [int(i) for i in order[:max(min(promote, len(cands)), 1)]]
            promoted = [cands[i] for i in keep]
            high_vals = high_ctrl.evaluate_batch(promoted)
            vals = [float(v) for v in screen_vals]
            for i, hv in zip(keep, high_vals):
                vals[i] = float(hv)
            strategy.tell(cands, vals)
            for c, hv in zip(promoted, high_vals):
                if float(hv) < best_v:
                    best_c, best_v = dict(c), float(hv)
            entry = {"round": rnd, "screened": len(cands),
                     "promoted": len(promoted),
                     "screen_values": [float(v) for v in screen_vals],
                     "promoted_configs": [dict(c) for c in promoted],
                     "high_values": [float(v) for v in high_vals]}
            schedule.append(entry)
            if on_round is not None:
                on_round(rnd, entry)
        if best_c is None:
            raise RuntimeError("successive halving promoted nothing "
                               "(strategy returned no candidates)")
        return best_c, best_v, schedule

    def restart_cost(self, space: Space, old: Config, new: Config) -> int:
        """How many changed knobs force a restart/recompile (fleet cost)."""
        n = 0
        for k in space.knobs:
            if old.get(k.name) != new.get(k.name) and k.restart_required:
                n += 1
        return n

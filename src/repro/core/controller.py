"""The SAPPHIRE Controller (paper Fig. 3) — the experiment loop.

Owns the **evaluation database** (append-only JSONL, the paper's store of
"all the system measurement results") and drives any ask/tell
:class:`~repro.core.strategy.SearchStrategy` against any evaluator:

    ctrl = Controller(evaluator, EvalDB("evals.jsonl"), tag="bo")
    trace = ctrl.run(make_strategy("bo", space, cfg=BOConfig(...)))

:meth:`Controller.run` is the single synchronous loop every strategy goes
through — probes are scored as whole batches through the evaluation
*service* (:mod:`repro.core.service`), every batch is one tagged DB
append, and an ``on_round`` hook fires after each round.
:meth:`Controller.run_async` is the overlapped loop the ROADMAP named:
the next ``ask`` batch is submitted while prior results are still in
flight, the strategy is told partial/out-of-order completions as they
stream in, every completion wave is appended to the DB under its writer
lock, and a failed evaluation becomes an infeasible record instead of a
crashed run.  :meth:`Controller.run_successive_halving` adds the
two-fidelity schedule: each round screens a wide candidate batch at the
cheap fidelity and promotes only the top scorers to the high fidelity —
fidelity is a *request field* on the wire, not a choice of evaluator
object.

On a real fleet the controller additionally injects runtime-settable knobs
without restart (``Knob.restart_required=False``) and schedules
recompile/redeploy for the rest — recorded per evaluation so the
recommendation report can state the application cost of the final config.
"""

from __future__ import annotations

import inspect
import json
import threading
import time
import warnings
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

try:                      # POSIX advisory file lock for cross-process writers
    import fcntl
except ImportError:       # pragma: no cover - non-POSIX hosts
    fcntl = None

from repro.core.replication import AdaptiveRacer, ReplicationPolicy, \
    ReplicatingService
from repro.core.resilience import ResilientService, RetryPolicy
from repro.core.service import (DEFAULT_FIDELITY, EvalRequest, EvalResult,
                                EvaluationService, as_service, fold_seed)
from repro.core.space import Config, Space
from repro.core.strategy import SearchStrategy, Trace

# default in-flight cap: this many strategy batch widths may be pending
# before run_async stops submitting (see _batch_width)
_IN_FLIGHT_WIDTH_FACTOR = 4


def _batch_width(strategy: SearchStrategy,
                 batch_size: Optional[int]) -> int:
    """The strategy's preferred probes-per-ask, for run_async's default
    in-flight cap: the driver's explicit ``batch_size``, else the
    strategy's own width (``RandomStrategy.batch_size``,
    ``BOConfig.batch_size``, the GA population), else 1."""
    if batch_size:
        return int(batch_size)
    w = getattr(strategy, "batch_size", None)
    if w:
        return int(w)
    cfg = getattr(strategy, "cfg", None)
    if cfg is not None:
        for name in ("batch_size", "population"):
            w = getattr(cfg, name, None)
            if w:
                return int(w)
    return 1


@dataclass
class EvalRecord:
    config: Config
    value: float
    wall_s: float
    tag: str = ""
    workload: str = ""
    fidelity: str = ""
    status: str = "ok"            # "ok" | "failed" (recorded as infeasible)
    repeats: int = 1              # successful repeats pooled into `value`
    variance: float = 0.0         # variance of that pooled mean (0.0 =
                                  # single measurement / no estimate)
    ns: str = ""                  # owning namespace (tuning-service session)
                                  # behind a shared/sharded append log; ""
                                  # = unnamespaced (every legacy record)

    @property
    def ok(self) -> bool:
        return self.status == "ok"


class EvalDB:
    """Append-only evaluation log; reloadable for warm-started ranking.

    Writes are guarded by a lock and flushed per record: concurrent
    worker completions (the async controller streams appends from many
    threads' results) can neither interleave two half-written JSONL lines
    nor leave a torn line behind a crash mid-batch.  Writers that do NOT
    share this object (a second EvalDB on the same path — daemon workers,
    other processes) are serialized by a POSIX advisory file lock per
    append batch.  The corrupt-line skip on reload stays as the last
    line of defense.
    """

    def __init__(self, path: Optional[str] = None,
                 shared_path: bool = False):
        self.path = Path(path) if path else None
        # shared_path declares that OTHER writers (threads holding their
        # own EvalDB, daemon workers, other processes) may append to this
        # file concurrently: without advisory file locks that cannot be
        # made safe, so the append fails loudly instead
        self.shared_path = shared_path
        self.records: List[EvalRecord] = []
        self._lock = threading.Lock()
        if self.path and self.path.exists():
            self._heal_tail()
        if self.path and self.path.exists():
            for i, line in enumerate(self.path.read_text().splitlines()):
                if not line.strip():
                    continue
                try:
                    d = json.loads(line)
                    rec = EvalRecord(
                        {k: _json_safe(v) for k, v in d["config"].items()},
                        float("nan") if d["value"] is None
                        else float(d["value"]), float(d.get("wall_s", 0.0)),
                        str(d.get("tag", "")), str(d.get("workload", "")),
                        str(d.get("fidelity", "")),
                        str(d.get("status", "ok")),
                        int(d.get("repeats", 1)),
                        float(d.get("variance", 0.0)),
                        str(d.get("ns", "")))
                except (json.JSONDecodeError, KeyError, TypeError,
                        ValueError):
                    # a crashed writer leaves a truncated trailing line;
                    # the rest of the log is still good history
                    warnings.warn(f"EvalDB: skipping corrupt line {i + 1} "
                                  f"of {self.path}")
                    continue
                self.records.append(rec)

    def _heal_tail(self):
        """Crash-truncation self-heal: a writer killed mid-append leaves a
        partial trailing JSONL line, which used to be "corrupt, skipped
        with warning" on *every* subsequent load, forever.  On load,
        inspect the tail under the same advisory file lock appends take:
        a parseable final line merely missing its newline gets one
        appended; an unparseable fragment is moved to ``<path>.quarantine``
        (preserved for forensics, never silently discarded) and the log
        truncated back to its last complete line — so a shared log
        self-heals once instead of warning forever, and the next append
        starts on a clean line boundary instead of extending the torn
        one into a second corrupt record."""
        with self.path.open("r+b") as f:
            if fcntl is not None:
                fcntl.flock(f.fileno(), fcntl.LOCK_EX)
            data = f.read()
            if not data or data.endswith(b"\n"):
                return
            cut = data.rfind(b"\n") + 1          # 0 if no newline at all
            tail = data[cut:]
            try:
                json.loads(tail.decode("utf-8"))
                # complete record, torn newline (killed between write and
                # flush of the terminator): finish the line in place
                f.write(b"\n")
                return
            except (json.JSONDecodeError, UnicodeDecodeError):
                pass
            quarantine = self.path.with_name(self.path.name + ".quarantine")
            with quarantine.open("ab") as q:
                q.write(tail + b"\n")
            f.truncate(cut)
            warnings.warn(
                f"EvalDB: quarantined {len(tail)}-byte torn tail of "
                f"{self.path} (crashed writer) to {quarantine}")

    @staticmethod
    def _sanitize(rec: EvalRecord) -> EvalRecord:
        """Normalize numpy scalars at append time so in-memory records,
        the JSONL on disk, and reloaded records all compare equal."""
        return EvalRecord({k: _json_safe(v) for k, v in rec.config.items()},
                          float(_json_safe(rec.value)), rec.wall_s, rec.tag,
                          rec.workload, rec.fidelity, rec.status,
                          int(rec.repeats), float(rec.variance), rec.ns)

    @staticmethod
    def _line(rec: EvalRecord) -> str:
        # a non-finite value (a failed evaluation recorded before the
        # raise) serializes as null, keeping every line strict JSON
        d = {"config": rec.config,
             "value": rec.value if np.isfinite(rec.value) else None,
             "wall_s": rec.wall_s, "tag": rec.tag}
        # only write the async-era fields when informative: the common
        # synchronous line stays short and byte-stable for existing
        # tooling (the default fidelity reloads as "", meaning
        # unspecified — same as legacy lines)
        if rec.workload:
            d["workload"] = rec.workload
        if rec.fidelity and rec.fidelity != DEFAULT_FIDELITY:
            d["fidelity"] = rec.fidelity
        if rec.status != "ok":
            d["status"] = rec.status
        # replication fields only when an aggregate was recorded: legacy
        # single-measurement lines stay byte-stable, and legacy logs
        # reload with the defaults (repeats=1, variance=0.0)
        if rec.repeats != 1:
            d["repeats"] = rec.repeats
        if rec.variance:
            d["variance"] = rec.variance
        if rec.ns:
            d["ns"] = rec.ns
        return json.dumps(d) + "\n"

    def append(self, rec: EvalRecord):
        self.append_batch([rec])

    def append_batch(self, recs: Sequence[EvalRecord]):
        """Record a whole evaluation batch under the writer lock, flushing
        line by line — a batched experiment is the unit of work, and a
        crash can truncate at most the line being written.

        The in-process ``threading.Lock`` only serializes writers sharing
        THIS EvalDB object; two daemon workers (or two processes) each
        holding their own EvalDB on the same path would interleave lines
        through it.  Every append therefore additionally takes a POSIX
        advisory lock (``flock``) on the open file — an exclusive lock
        per batch, released when the file closes — so concurrent writers
        anywhere on the host serialize whole batches instead of
        interleaving half-written JSONL lines.  On hosts without
        ``fcntl`` the append fails loudly rather than risking silent
        corruption when a second writer is plausible (the tuning daemon
        sets ``shared_path=True`` on its shard logs)."""
        recs = [self._sanitize(r) for r in recs]
        if not recs:
            return
        with self._lock:
            self.records.extend(recs)
            if self.path:
                self.path.parent.mkdir(parents=True, exist_ok=True)
                if fcntl is None and getattr(self, "shared_path", False):
                    raise RuntimeError(
                        f"EvalDB({self.path}): marked as shared between "
                        "writers but this host has no fcntl advisory "
                        "locks — concurrent appends could interleave "
                        "corrupt JSONL lines")
                with self.path.open("a") as f:
                    if fcntl is not None:
                        fcntl.flock(f.fileno(), fcntl.LOCK_EX)
                    for r in recs:
                        f.write(self._line(r))
                        f.flush()

    def pairs(self, tag: Optional[str] = None,
              workload: Optional[str] = None,
              include_failed: bool = False,
              ) -> Tuple[List[Config], List[float]]:
        rs = [r for r in self.records
              if (tag is None or r.tag == tag)
              and (workload is None or r.workload == workload)
              and (include_failed or r.ok)]
        return [r.config for r in rs], [r.value for r in rs]

    def __len__(self):
        return len(self.records)


def _json_safe(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (np.bool_,)):
        return bool(v)
    return v


@dataclass
class Controller:
    """Experiment Unit driver: evaluates configs through an evaluation
    *service*, logs to the DB, and runs the ask/tell loop for any search
    strategy.

    ``evaluate`` may be anything :func:`repro.core.service.as_service`
    accepts — an :class:`~repro.core.service.EvaluationService`, an
    evaluator object, or a bare ``Callable[[Config], float]``; the
    resolved service is cached and shared across ``with_tag``/
    ``with_prepare``/``with_workload`` derivatives (one worker pool, not
    one per tag).

    ``prepare`` (optional) maps a strategy-side config to the full config
    the evaluator runs — e.g. expanding a top-K sub-config over pinned
    defaults.  The *prepared* config is what the DB records, so the log
    always holds runnable configurations.  ``workload`` names the cell
    (e.g. ``"yi-6b:train_4k"``) every request/record is stamped with.

    ``replication`` (a :class:`~repro.core.replication.ReplicationPolicy`)
    turns on replicated measurements: the resolved service is wrapped in a
    :class:`~repro.core.replication.ReplicatingService` that fans every
    probe into ``initial_repeats`` seed-derived sub-measurements and
    returns one pooled result (mean + variance-of-mean + repeat count);
    with ``adaptive=True``, :meth:`run_async` additionally re-measures —
    through the same in-flight machinery — only the probes whose credible
    interval still straddles the incumbent best.  ``seed`` pins the whole
    run's measurement streams: every request is stamped with a
    deterministic per-submission seed (``fold_seed(seed, counter)``), so
    a replayed run on a fresh controller + fresh service reproduces every
    noise draw bit for bit — even through an out-of-order worker pool.
    """

    evaluate: Union[Callable[[Config], float], EvaluationService]
    db: EvalDB = field(default_factory=EvalDB)
    tag: str = ""
    prepare: Optional[Callable[[Config], Config]] = None
    workload: str = ""
    replication: Optional[ReplicationPolicy] = None
    seed: Optional[int] = None
    resilience: Optional[RetryPolicy] = None

    @property
    def service(self) -> EvaluationService:
        svc = getattr(self, "_service", None)
        if svc is None:
            svc = as_service(self.evaluate)
            if self.resilience is not None and self.resilience.active:
                # retries live BELOW replication: each sub-repeat retries
                # independently, the Chan merge only ever sees settled
                # repeats, and a retried probe completes its one outer
                # ticket exactly once — so n_evaluations (and the budget
                # the strategy was told) never inflate under faults
                svc = ResilientService(svc, self.resilience)
            if self.replication is not None and self.replication.active:
                svc = ReplicatingService(
                    svc, n_repeats=self.replication.initial_repeats,
                    seed=self.replication.seed)
            self._service = svc
        return svc

    def _derive(self, **changes) -> "Controller":
        kw = {"evaluate": self.evaluate, "db": self.db, "tag": self.tag,
              "prepare": self.prepare, "workload": self.workload,
              "replication": self.replication, "seed": self.seed,
              "resilience": self.resilience}
        kw.update(changes)
        c = Controller(**kw)
        # resolve eagerly so every derivative shares THIS controller's
        # service (one worker pool total, not one per tag) — resolution
        # is cheap: pooled services spawn threads on first submit only
        c._service = self.service
        return c

    def with_tag(self, tag: str) -> "Controller":
        return self._derive(tag=tag)

    def with_prepare(self, prepare: Callable[[Config], Config]) -> "Controller":
        return self._derive(prepare=prepare)

    def with_workload(self, workload: str) -> "Controller":
        return self._derive(workload=workload)

    def with_replication(self, policy: ReplicationPolicy) -> "Controller":
        """Derivative with replicated measurements.  Unlike the other
        ``with_*`` helpers the service is NOT shared: the policy decides
        how the service wraps, so the derivative resolves its own (the
        underlying backend object is still the same one)."""
        kw = {"evaluate": self.evaluate, "db": self.db, "tag": self.tag,
              "prepare": self.prepare, "workload": self.workload,
              "replication": policy, "seed": self.seed,
              "resilience": self.resilience}
        return Controller(**kw)

    def with_resilience(self, policy: RetryPolicy) -> "Controller":
        """Derivative with retried evaluation.  Like
        :meth:`with_replication`, the service is NOT shared: the policy
        decides how the backend wraps, so the derivative resolves its
        own stack (same backend object underneath)."""
        kw = {"evaluate": self.evaluate, "db": self.db, "tag": self.tag,
              "prepare": self.prepare, "workload": self.workload,
              "replication": self.replication, "seed": self.seed,
              "resilience": policy}
        return Controller(**kw)

    # ---- synchronous evaluation ---------------------------------------------

    def __call__(self, cfg: Config) -> float:
        return self.evaluate_batch([cfg])[0]

    def _requests(self, cfgs: Sequence[Config],
                  fidelity: str) -> Tuple[List[Config], List[EvalRequest]]:
        cfgs = [dict(c) for c in cfgs]
        if self.prepare:
            cfgs = [self.prepare(c) for c in cfgs]
        seeds: List[Optional[int]] = [None] * len(cfgs)
        if self.seed is not None:
            # per-submission seed stream: request i of a seeded run is the
            # same measurement on every replay (fresh controller + fresh
            # service), regardless of service completion order
            base = getattr(self, "_seed_count", 0)
            self._seed_count = base + len(cfgs)
            seeds = [fold_seed(self.seed, base + i)
                     for i in range(len(cfgs))]
        return cfgs, [EvalRequest(c, fidelity, self.workload, self.tag, s)
                      for c, s in zip(cfgs, seeds)]

    def _record(self, result: EvalResult, cfg: Config, value: float,
                wall_s: Optional[float] = None) -> EvalRecord:
        return EvalRecord(cfg, value,
                          result.wall_s if wall_s is None else wall_s,
                          self.tag, self.workload, result.request.fidelity,
                          result.status, int(result.repeats),
                          float(result.variance))

    @staticmethod
    def _teller(strategy: SearchStrategy):
        """Variance-aware tell, feature-detected (the same pattern as the
        poll ``min_results`` probe): strategies whose ``tell`` accepts a
        ``variances`` argument get the per-observation measurement
        variance alongside the values; legacy two-argument strategies are
        told exactly as before."""
        try:
            wants = ("variances"
                     in inspect.signature(strategy.tell).parameters)
        except (TypeError, ValueError):
            wants = False
        if wants:
            return strategy.tell
        return lambda cfgs, vals, variances=None: strategy.tell(cfgs, vals)

    def _evaluate_sync(self, cfgs: Sequence[Config],
                       fidelity: str) -> List[EvalResult]:
        svc = self.service
        cfgs, reqs = self._requests(cfgs, fidelity)
        t0 = time.monotonic()
        results = svc.gather(svc.submit(reqs))
        wall = (time.monotonic() - t0) / max(len(cfgs), 1)
        self.db.append_batch([self._record(r, c, float(r.value), wall)
                              for c, r in zip(cfgs, results)])
        failed = [r for r in results if not r.ok]
        if failed:
            raise RuntimeError(
                f"{len(failed)}/{len(results)} evaluations failed; "
                f"first: {failed[0].error}") from failed[0].exception
        return results

    def evaluate_batch(self, cfgs: Sequence[Config],
                       fidelity: str = DEFAULT_FIDELITY) -> List[float]:
        """Submit a whole batch and block for it (the synchronous
        contract): one tagged DB append, each record's ``wall_s`` the
        batch wall-clock amortized per config.  A failed evaluation is
        recorded (status ``failed``) and then raised — synchronous callers
        treat a broken benchmark as an error; the async loop is the path
        that survives failures."""
        return [float(r.value)
                for r in self._evaluate_sync(cfgs, fidelity)]

    # ---- the experiment loop ------------------------------------------------

    def run(self, strategy: SearchStrategy, budget: Optional[int] = None,
            batch_size: Optional[int] = None,
            fidelity: str = DEFAULT_FIDELITY,
            on_round: Optional[Callable[[int, List[Config], List[float]],
                                        None]] = None) -> Trace:
        """Drive ``strategy`` to completion: ask a probe batch, score it,
        tell the results, repeat until the strategy's budget is told (or
        ``budget`` evaluations have been spent here, when given).

        ``on_round(round_index, configs, values)`` fires after each tell.
        This is the synchronous barrier loop; :meth:`run_async` is the
        overlapped one.
        """
        spent = 0
        rnd = 0
        tell = self._teller(strategy)
        while not strategy.finished:
            n = batch_size
            remaining = None
            if budget is not None:
                remaining = budget - spent
                if remaining <= 0:
                    break
                if n is not None:
                    n = min(n, remaining)
            cfgs = strategy.ask(n)
            if not cfgs:
                break
            if remaining is not None and len(cfgs) > remaining:
                # cap the spend without distorting the strategy's batch
                # width: the final round is truncated, not re-asked
                cfgs = cfgs[:remaining]
            results = self._evaluate_sync(cfgs, fidelity)
            vals = [float(r.value) for r in results]
            tell(cfgs, vals, [float(r.variance) for r in results])
            spent += len(cfgs)
            if on_round is not None:
                on_round(rnd, cfgs, vals)
            rnd += 1
        return strategy.trace

    def run_async(self, strategy: SearchStrategy,
                  budget: Optional[int] = None,
                  batch_size: Optional[int] = None,
                  max_in_flight: Optional[int] = None,
                  min_ask: int = 1,
                  fidelity: str = DEFAULT_FIDELITY,
                  failure_value: Optional[float] = None,
                  on_round: Optional[Callable[[int, List[Config],
                                               List[float]], None]] = None,
                  on_ask: Optional[Callable[[int, float], None]] = None,
                  ) -> Trace:
        """The overlapped experiment loop (ROADMAP's async follow-on).

        Keeps the evaluation service saturated: the next ``ask`` batch is
        submitted while prior probes are still in flight, and the strategy
        is ``tell``-ed each completion *wave* — partial and out of order —
        as results stream back (the seam the ask/tell protocol guarantees:
        in-flight probes already count against the strategy's budget, so
        the GP refit no longer gates probe submission).  Every wave is one
        tagged DB append under the writer lock.

        A failed evaluation does not kill the run: it is recorded with
        status ``failed`` (excluded from ``pairs()`` by default) and told
        to the strategy at a penalty value — ``failure_value`` if given,
        otherwise strictly past the worst value observed so far (a finite
        "this region is bad" signal; ``inf``/``nan`` would flatten the
        GP, and anything not clearly worse than the incumbent could make
        a broken config look attractive).  A failure landing before *any*
        success is held back and priced once the first real value fixes
        the objective's scale — a guessed absolute penalty could
        accidentally beat genuine values; only if the whole run fails is
        the fallback ``1e6`` used (no best exists to corrupt then).

        ``max_in_flight`` caps concurrent submissions.  The default
        (``None``) caps at ``4 ×`` the strategy's batch width
        (:func:`_batch_width`): a slow service can no longer absorb the
        whole remaining budget against one stale posterior — submission
        pauses until results land and the surrogate catches up.  The
        automatic cap only *gates* further asks, it never shapes an
        ask's width (an explicit ``max_in_flight`` does both, via
        ``room`` below), so on an immediate service — where results land
        before the next ask and nothing ever accumulates — traces are
        unchanged.  Pass ``max_in_flight <= 0`` for the old unbounded
        behavior;
        ``min_ask > 1`` coalesces completion waves — with probes still in
        flight, the loop waits until that many slots are free before the
        next ``ask``, so an expensive proposer (a GP refit per ask) is
        amortized over a q-batch instead of re-running for every single
        straggler (set it to about half the worker count; ``min_ask =
        max_in_flight`` degenerates to the synchronous barrier).  On
        services whose ``poll`` supports ``min_results`` (all built-in
        ones) the blocking poll coalesces too: the driver wakes once per
        min_ask-wide wave — one tell, one DB append — instead of once
        per completed probe.
        ``on_round(round_index, configs, values)`` fires per completion
        wave.  Submission yields to completed results — the loop tells
        what has landed before asking for more — so on an immediate
        (analytic) service this reproduces :meth:`run` exactly: same
        noise stream, same trace.

        ``on_ask(n_asked, wall_s)`` fires after every ``strategy.ask``
        that returned probes, with the batch width and the ask's
        wall-clock — the submission-latency probe (empty asks from a
        blocked or exhausted strategy are not latencies worth recording).
        The proposer is the only part of
        this loop that can stall submission; with a strategy that fits
        its surrogate in the background (``BOConfig.refit_async``) these
        latencies stay at evaluation-dispatch scale regardless of
        ``fit_steps``, which is exactly what the hook exists to verify
        (see ``benchmarks/perf_gp_ask.py``).
        """
        svc = self.service
        # adaptive replication: completed probes whose credible interval
        # straddles the incumbent are held back and re-measured through
        # the same service before being told (racing, not fixed-k).  A
        # strategy exposing a GP-implied measurement_variance lends the
        # racer its posterior: 2-repeat probes then race on intervals
        # pooled across configs, not 1-dof empirical variance draws
        racer = None
        if self.replication is not None and self.replication.adaptive:
            prior = (getattr(strategy, "measurement_variance", None)
                     if getattr(self.replication, "gp_prior", True) else None)
            racer = AdaptiveRacer(self.replication, svc, noise_prior=prior)
        tell = self._teller(strategy)
        auto_cap = auto_width = None
        if max_in_flight is None:
            auto_width = _batch_width(strategy, batch_size)
            auto_cap = _IN_FLIGHT_WIDTH_FACTOR * auto_width
        elif max_in_flight <= 0:
            max_in_flight = None                         # explicit unbounded
        pending: Dict[int, Tuple[Config, Config]] = {}   # uid -> (asked,
        spent = 0                                        #         prepared)
        rnd = 0
        worst = float("-inf")
        # wave-coalescing poll: services whose poll supports min_results
        # (every _ServiceBase subclass) let the driver sleep through a
        # whole min_ask-wide wave instead of waking per straggler; other
        # protocol implementations keep the one-completion wakeup
        try:
            poll_coalesces = ("min_results"
                              in inspect.signature(svc.poll).parameters)
        except (TypeError, ValueError):
            poll_coalesces = False

        def submit_more():
            nonlocal spent
            while not strategy.finished:
                if getattr(svc, "ready", 0) > 0:
                    return          # landed results first: fresher asks
                if budget is not None and spent >= budget:
                    return
                if (auto_cap is not None and pending
                        and len(pending) + auto_width > auto_cap):
                    return      # bounded staleness: the next ask-wide
                    #             wave would push in-flight past the cap
                room = None
                if max_in_flight is not None:
                    room = max_in_flight - len(pending)
                    if room <= 0:
                        return
                    if pending and room < min(
                            min_ask,
                            budget - spent if budget is not None
                            else min_ask):
                        return      # coalesce: amortize the next ask
                n = batch_size
                if budget is not None and n is not None:
                    # a budget never overrides ask(None) — the strategy's
                    # preferred batch is truncated below, exactly as in
                    # run(), so the two loops stay trace-identical
                    n = min(n, budget - spent)
                if room is not None:
                    n = room if n is None else min(n, room)
                t_ask = time.monotonic()
                asked = strategy.ask(n)
                if not asked:
                    return
                if on_ask is not None:
                    on_ask(len(asked), time.monotonic() - t_ask)
                if budget is not None and len(asked) > budget - spent:
                    # cap the spend without distorting the strategy's
                    # batch width: the final round is truncated
                    asked = asked[:budget - spent]
                asked = [dict(c) for c in asked]
                prepared, reqs = self._requests(asked, fidelity)
                for t, a, p in zip(svc.submit(reqs), asked, prepared):
                    pending[t.uid] = (a, p)
                spent += len(asked)

        deferred: List[Tuple[EvalResult, Config, Config]] = []

        def tell_wave(wave):
            nonlocal rnd
            if failure_value is not None:
                penalty = failure_value
            elif np.isfinite(worst):
                penalty = worst + max(abs(worst), 1.0)
            else:
                penalty = 1e6       # the whole run failed: scale unknowable
            asked_cfgs: List[Config] = []
            values: List[float] = []
            variances: List[float] = []
            records: List[EvalRecord] = []
            for r, asked_c, prepared_c in wave:
                v = float(r.value) if r.ok else penalty
                records.append(self._record(r, prepared_c, v))
                asked_cfgs.append(asked_c)
                values.append(v)
                variances.append(float(r.variance) if r.ok else 0.0)
            if records:
                self.db.append_batch(records)
                tell(asked_cfgs, values, variances)
                if on_round is not None:
                    on_round(rnd, asked_cfgs, values)
                rnd += 1

        while True:
            submit_more()
            if not pending and (racer is None or not racer.busy):
                if deferred:
                    # nothing in flight and nothing succeeded yet: price
                    # the held failures at the fallback so a blocked
                    # strategy is told and the run can continue
                    tell_wave(deferred)
                    deferred = []
                    continue
                break
            if poll_coalesces and min_ask > 1:
                # block for a whole wave: min_ask results (or everything
                # in flight), matching the coalesced ask cadence — but at
                # the budget tail never hold more slots than the run can
                # still submit, or the last probes idle behind the wave
                want = max(min(min_ask, len(pending)), 1)
                if budget is not None and 0 < budget - spent < want:
                    want = budget - spent
                results = svc.poll(timeout=None, min_results=want)
            else:
                results = svc.poll(timeout=None)    # first completion
            if not results:
                # the protocol: poll(None) returns empty only when nothing
                # is in flight — any pending entries left are orphaned
                # (claimed elsewhere or lost) and nothing more will come.
                # (the racer cannot be busy here: every racing group has a
                # follow-up in flight, so an empty drain settles them too)
                break
            if racer is None:
                wave = [(r, *e) for r in results
                        if (e := pending.pop(r.ticket.uid, None)) is not None]
            else:
                # route completions through the racer: first completions
                # (pending) may be held for re-measurement, follow-up
                # completions re-decide their group; only settled probes
                # enter the tell wave
                wave = []
                for r in results:
                    e = pending.pop(r.ticket.uid, None)
                    settled = (racer.offer(r.ticket.uid, r, *e)
                               if e is not None else racer.absorb(r))
                    if settled is not None:
                        wave.append(settled)
            # two passes: every ok value in the wave raises the penalty
            # floor *before* any failure is priced, so an early failure
            # can't be told a deceptively good value
            for r, _, _ in wave:
                if r.ok:
                    worst = max(worst, float(r.value))
            if failure_value is None and not np.isfinite(worst):
                # no success yet: hold every failure back until the first
                # real value fixes the objective's scale
                deferred += wave
                continue
            if deferred:
                wave = deferred + wave
                deferred = []
            tell_wave(wave)
        if deferred:
            tell_wave(deferred)     # nothing ever succeeded (or orphaned
        return strategy.trace       # tail): price at the fallback

    def run_successive_halving(
            self, strategy: SearchStrategy,
            high: Union["Controller", Callable[[Config], float], None] = None,
            rounds: int = 4, screen: int = 16, promote: int = 2,
            screen_tag: str = "screen", promote_tag: str = "promote",
            promote_z: float = 1.0,
            on_round: Optional[Callable[[int, Dict], None]] = None,
    ) -> Tuple[Config, float, List[Dict]]:
        """Two-fidelity successive halving: per round, ask ``screen``
        candidates, score them all at the cheap screen fidelity (the
        analytic test cluster), promote the ``promote`` best to the high
        fidelity (the compiled product cluster), and tell the strategy
        every candidate — promoted ones at their high-fidelity value, the
        rest at their screen value (a cheap multi-fidelity prior for the
        surrogate).

        Under replicated measurements the screen values carry an
        empirical variance of their pooled mean; promotion then ranks on
        the *variance-widened* mean ``value + promote_z·sd`` instead of
        the raw mean, so a lucky noisy draw cannot crowd a genuinely
        good config out of the promotion slots.  Unreplicated screens
        report zero variance, making the widened ranking bit-identical
        to the plain one (``promote_z`` is inert then); the strategy is
        always told the un-widened means, with their variances when it
        accepts them.

        Fidelity is a *request field*: every screen request is stamped
        ``fidelity=screen_tag`` and every promotion ``fidelity=
        promote_tag``.  With ``high=None`` both fidelities are served by
        *this* controller's service — e.g. an
        :class:`~repro.core.service.ImmediateEvaluationService` hosting
        ``{screen_tag: analytic, promote_tag: compiled}`` backends or a
        :class:`~repro.core.service.FidelityRouter` — so the schedule
        needs no second evaluator object.  Passing a ``high`` controller/
        evaluator keeps the legacy two-object form working.

        Returns ``(best_config, best_value, schedule)`` where best is over
        *high-fidelity* measurements only and ``schedule`` records, per
        round, what was screened and what was promoted.
        """
        if high is None:
            high_ctrl = self.with_tag(promote_tag)
        elif isinstance(high, Controller):
            high_ctrl = high if high.tag else high.with_tag(promote_tag)
        else:
            # a bare evaluator inherits this controller's prepare hook —
            # both fidelities must score the same completed config
            high_ctrl = Controller(high, self.db, promote_tag, self.prepare,
                                   self.workload)
        screen_ctrl = self.with_tag(screen_tag)
        tell = self._teller(strategy)
        best_c: Optional[Config] = None
        best_v = float("inf")
        schedule: List[Dict] = []
        for rnd in range(rounds):
            if strategy.finished:
                break
            cands = strategy.ask(screen)
            if not cands:
                break
            screen_res = screen_ctrl._evaluate_sync(cands,
                                                    fidelity=screen_tag)
            screen_vals = [float(r.value) for r in screen_res]
            screen_vars = [float(r.variance) for r in screen_res]
            # promotion ranks on the variance-widened mean: a 2-repeat
            # screen's ±sd uncertainty counts against it, so promotion
            # slots go to configs whose screen value is good *beyond*
            # its noise (zero-variance screens reduce to the raw mean)
            widened = [v + promote_z * float(np.sqrt(max(s, 0.0)))
                       for v, s in zip(screen_vals, screen_vars)]
            order = np.argsort(widened, kind="stable")
            keep = [int(i) for i in order[:max(min(promote, len(cands)), 1)]]
            promoted = [cands[i] for i in keep]
            high_res = high_ctrl._evaluate_sync(promoted,
                                                fidelity=promote_tag)
            high_vals = [float(r.value) for r in high_res]
            vals = list(screen_vals)
            variances = list(screen_vars)
            for i, hr in zip(keep, high_res):
                vals[i] = float(hr.value)
                variances[i] = float(hr.variance)
            tell(cands, vals, variances)
            for c, hv in zip(promoted, high_vals):
                if float(hv) < best_v:
                    best_c, best_v = dict(c), float(hv)
            entry = {"round": rnd, "screened": len(cands),
                     "promoted": len(promoted),
                     "screen_values": [float(v) for v in screen_vals],
                     "promoted_configs": [dict(c) for c in promoted],
                     "high_values": [float(v) for v in high_vals]}
            schedule.append(entry)
            if on_round is not None:
                on_round(rnd, entry)
        if best_c is None:
            raise RuntimeError("successive halving promoted nothing "
                               "(strategy returned no candidates)")
        return best_c, best_v, schedule

    def restart_cost(self, space: Space, old: Config, new: Config) -> int:
        """How many changed knobs force a restart/recompile (fleet cost)."""
        n = 0
        for k in space.knobs:
            if old.get(k.name) != new.get(k.name) and k.restart_required:
                n += 1
        return n

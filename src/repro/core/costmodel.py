"""Analytic performance model of a (arch × shape × mesh × config) cell.

This is the paper's *test cluster* (§3.1): a cheap, faithful simulator the
tuner probes hundreds of times, standing in for the expensive product
evaluation (here: the compiled dry-run; in the paper: a live Ceph bench).

The model composes per-layer FLOPs / HBM bytes / collective bytes under the
chosen RunConfig knobs into the same three roofline terms the compiled
dry-run reports (launch/roofline.py), so test-cluster and product-cluster
evaluations are directly comparable — the transfer experiment (paper
Fig. 5) depends on that.

Deliberately *non-linear and multi-peak* where real systems are
(paper Fig. 2b): kernel block-size efficiency has alignment and divisor
peaks with VMEM-pressure cliffs; microbatching trades MXU utilization
against collective exposure and HBM feasibility.

Hardware constants: TPU v5e per assignment — 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI; inter-pod DCI modeled at half ICI.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

from repro.models.config import (ATTN, MAMBA, MLP_MOE, MLSTM, SLSTM,
    ModelConfig, ShapeCell)

Config = Dict[str, object]


@dataclass(frozen=True)
class Hardware:
    peak_flops: float = 197e12          # bf16 per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per link
    dci_bw: float = 25e9                # bytes/s per pod link (inter-pod)
    hbm_bytes: float = 16e9             # v5e HBM capacity
    vmem_bytes: float = 64 * 2**20      # usable VMEM for kernel tiles


V5E = Hardware()


@dataclass(frozen=True)
class MeshShape:
    pod: int = 1
    data: int = 16
    model: int = 16

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model

    @property
    def dp(self) -> int:
        return self.pod * self.data


SINGLE_POD = MeshShape(1, 16, 16)
MULTI_POD = MeshShape(2, 16, 16)


@dataclass(frozen=True)
class CostBreakdown:
    compute_s: float
    memory_s: float
    collective_s: float
    step_s: float
    hbm_per_chip: float        # bytes
    feasible: bool
    flops: float               # total step FLOPs (all chips)
    hbm_bytes_moved: float     # total step HBM traffic (all chips)
    collective_bytes: float    # total step collective traffic (all chips)


# ---------------------------------------------------------------------------
# per-layer FLOPs / bytes
# ---------------------------------------------------------------------------

def _bytes_of(dtype: str) -> int:
    return {"bfloat16": 2, "float16": 2, "float32": 4, "int8": 1}[str(dtype)]


def matmul_flops_layer(cfg: ModelConfig, tokens: int) -> float:
    """Forward matmul FLOPs of one *pattern group* per token-batch.

    2 · (active params in the group) · tokens, using the config's analytic
    parameter counter so MoE counts routed-in experts only.
    """
    per_group_active = cfg.active_param_count() - _embedding_params(cfg)
    per_group_active /= cfg.n_groups
    return 2.0 * per_group_active * tokens


def _embedding_params(cfg: ModelConfig) -> int:
    emb = cfg.vocab_size * cfg.d_model
    return emb if cfg.tie_embeddings else 2 * emb


def attention_flops(cfg: ModelConfig, batch: int, seq: int) -> float:
    """Quadratic attention score+value FLOPs per group (fwd)."""
    per_group = sum(1 for s in cfg.pattern if s.kind == ATTN)
    window_terms = []
    for s in cfg.pattern:
        if s.kind != ATTN:
            continue
        kv_len = min(s.sliding_window or seq, seq)
        window_terms.append(kv_len)
    if not window_terms:
        return 0.0
    hd = cfg.resolved_head_dim
    f = 0.0
    for kv_len in window_terms:
        # causal halves the score matrix; QKᵀ + PV, 2 flops/MAC
        f += 2 * 2 * batch * cfg.n_heads * seq * kv_len * hd * 0.5
    return f


def scan_mixer_flops(cfg: ModelConfig, batch: int, seq: int) -> float:
    """Linear-time mixers (mamba/mlstm/slstm) state-update FLOPs per group."""
    f = 0.0
    for s in cfg.pattern:
        if s.kind == MAMBA:
            f += 2 * batch * seq * cfg.d_inner * (2 * cfg.ssm_state_dim + 8)
        elif s.kind == MLSTM:
            di = int(cfg.mlstm_expand * cfg.d_model)
            nh = max(di // max(cfg.resolved_head_dim, 1), 1)
            p = di // nh
            f += 2 * batch * seq * di * p          # C update ~ d_inner × head_dim
        elif s.kind == SLSTM:
            f += 2 * batch * seq * 8 * cfg.d_model
    return f


# ---------------------------------------------------------------------------
# knob-response curves (non-linear, multi-peak — paper Fig. 2b)
# ---------------------------------------------------------------------------

def mxu_block_efficiency(block_q: int, block_k: int, seq: int,
                         hd: int, hw: Hardware) -> float:
    """MXU utilization of a flash tile configuration ∈ (0, 1].

    Peaks where blocks are 128-aligned AND divide the (padded) sequence;
    cliffs where the working set overflows VMEM — multi-peak by design,
    matching measured TPU kernel behaviour and reproducing the paper's
    Fig. 2b response shape.
    """
    eff = 0.45
    if block_q % 128 == 0:
        eff += 0.12
    if block_k % 128 == 0:
        eff += 0.12
    if seq % max(block_q, 1) == 0:
        eff += 0.12
    if seq % max(block_k, 1) == 0:
        eff += 0.08
    # second harmonic: 512-aligned tiles keep the MXU pipeline full
    if block_q % 512 == 0:
        eff += 0.06
    if block_k % 512 == 0:
        eff += 0.04
    # VMEM working set: q,k,v,o tiles + score tile (f32)
    ws = (block_q * hd + 2 * block_k * hd + block_q * hd) * 2 \
        + block_q * block_k * 4
    if ws > hw.vmem_bytes:
        eff *= 0.25                       # spill cliff
    elif ws > 0.5 * hw.vmem_bytes:
        eff *= 0.8                        # reduced double-buffering
    # tiny blocks starve the MXU
    if block_q < 128 or block_k < 128:
        eff *= 0.5
    return min(eff, 0.98)


def microbatch_efficiency(tokens_per_chip: int) -> float:
    """Compute efficiency vs per-chip tokens per microbatch (saturating).

    MXU pipelines saturate around ≥2k tokens/chip for these widths; tiny
    microbatches (the conservative default, tuned for small machines —
    the paper's 'defaults are for commodity hardware' premise) starve it.
    Caps at 0.88: real kernels never hit paper peak.
    """
    return min(0.30 + 0.58 * min(tokens_per_chip / 2048.0, 1.0), 0.88)


def precision_factor(matmul_precision: str) -> float:
    return {"default": 1.0, "high": 2.0, "highest": 4.0}[str(matmul_precision)]


REMAT_RECOMPUTE = {"none": 0.0, "dots": 0.35, "block": 0.65, "full": 1.0}
REMAT_ACT_FRACTION = {"none": 1.0, "dots": 0.45, "block": 0.18, "full": 0.05}


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

def estimate(cfg: ModelConfig, cell: ShapeCell, mesh: MeshShape,
             knobs: Config, hw: Hardware = V5E) -> CostBreakdown:
    g = lambda k, d: knobs.get(k, d)   # noqa: E731

    tp_on = bool(g("tensor_parallel", True))
    fsdp = bool(g("fsdp_shard_params", True))
    sp_on = bool(g("sequence_parallel", False)) and tp_on
    ep_on = bool(g("expert_parallel", True)) and cfg.has_moe
    pod_in_batch = bool(g("pod_in_batch", True))
    tp = mesh.model if tp_on else 1
    dp = mesh.chips // mesh.model if pod_in_batch else mesh.data
    remat = str(g("remat_policy", "none"))
    prec = precision_factor(g("matmul_precision", "default"))
    attn_impl = str(g("attention_impl", "reference"))
    grad_dtype_bytes = _bytes_of(
        "bfloat16" if str(g("grad_allreduce_dtype", "float32")) == "bfloat16"
        else "float32")
    hier = bool(g("pod_hierarchical_allreduce", True))

    B, S = cell.global_batch, cell.seq_len
    train = cell.mode == "train"
    decode = cell.mode == "decode"

    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    pbytes = _bytes_of(g("param_dtype", "bfloat16"))

    # ---- microbatching -----------------------------------------------------
    per_replica = max(B // dp, 1)
    micro = int(g("microbatch", 0)) or per_replica
    micro = max(min(micro, per_replica), 1)
    n_micro = max(per_replica // micro, 1)

    seq_for_tokens = 1 if decode else S
    tokens_global = B * seq_for_tokens
    tokens_micro_chip = micro * seq_for_tokens // max(tp, 1)

    # ---- FLOPs ---------------------------------------------------------------
    fwd = cfg.n_groups * matmul_flops_layer(cfg, tokens_global)
    if decode:
        # decode attends 1 token against an S-long cache: linear in S
        hd = cfg.resolved_head_dim
        attn_fwd = 2 * 2 * B * cfg.n_heads * 1 * S * hd * cfg.attn_layer_count
    else:
        attn_fwd = cfg.n_groups * attention_flops(cfg, B, S)
    mix_fwd = cfg.n_groups * scan_mixer_flops(cfg, B, 1 if decode else S)
    head = 2 * tokens_global * cfg.d_model * cfg.vocab_size
    fwd_total = fwd + attn_fwd + mix_fwd + head

    if train:
        flops = fwd_total * (3.0 + REMAT_RECOMPUTE[remat])  # fwd+2×bwd+remat
    else:
        flops = fwd_total

    # ---- compute efficiency (knob-responsive) --------------------------------
    eff = microbatch_efficiency(max(tokens_micro_chip, 1))
    if cfg.has_attention and not decode:
        if attn_impl == "flash":
            eff_attn = mxu_block_efficiency(
                int(g("flash_block_q", 512)), int(g("flash_block_k", 512)),
                S, cfg.resolved_head_dim, hw)
        elif attn_impl == "chunked":
            ck = int(g("chunk_size_k", 2048))
            eff_attn = 0.55 + (0.15 if S % max(ck, 1) == 0 else 0.0)
        else:
            # reference materializes [S,S] — efficiency collapses with S
            eff_attn = max(0.5 - 0.4 * min(S / 32768.0, 1.0), 0.08)
        attn_share = attn_fwd / max(fwd_total, 1.0)
        eff = eff * (1 - attn_share) + eff_attn * attn_share
    if cfg.has_moe:
        cap = float(g("moe_capacity_factor", 1.25))
        # dropping tokens hurts quality not time; overcapacity pads compute
        flops *= (1.0 if str(g("moe_impl", "dense")) == "dense"
                  else max(cap, 1.0))
    compute_s = flops * prec / (mesh.chips * hw.peak_flops * max(eff, 0.05))

    # ---- HBM traffic ----------------------------------------------------------
    act_dtype_bytes = _bytes_of(g("activation_dtype", "bfloat16"))
    act_frac = REMAT_ACT_FRACTION[remat] if train else 1.0
    layer_io = 12 if cfg.has_attention else 8   # tensors touched per layer
    act_bytes = (tokens_global * cfg.d_model * act_dtype_bytes
                 * cfg.n_layers * layer_io * act_frac)
    # EVERY chip reads its (TP-sharded) slice of the gathered weights per
    # pass: per-chip weight traffic = N/tp, so the fleet-wide total is
    # chips·N/tp.  With tp off this is chips× the model per microbatch —
    # the term that makes naive "just turn TP off" recommendations fail
    # on the product cluster (validated against the compiled evaluator).
    weight_reads = (n_active * pbytes * (2 if train else 1) * n_micro
                    * mesh.chips / max(tp, 1))
    opt_bytes = 0.0
    if train:
        opt_mult = 12 if str(g("optimizer", "adamw")) == "adamw" else 5
        if not bool(g("master_weights_f32", True)):
            opt_mult = max(opt_mult - 4, 1)
        opt_bytes = n_params * opt_mult   # m, v, master read+write (f32)
    kv_bytes = 0.0
    if decode:
        kv_dtype = _bytes_of(g("kv_cache_dtype", "bfloat16"))
        kv_bytes = (2 * B * S * cfg.kv_dim * kv_dtype * cfg.attn_layer_count)
    hbm_moved = act_bytes + weight_reads + opt_bytes + kv_bytes
    memory_s = hbm_moved / (mesh.chips * hw.hbm_bw)

    # ---- collective traffic -----------------------------------------------------
    coll = 0.0
    slowest_bw = hw.ici_bw
    if train:
        shard_params = n_params * grad_dtype_bytes
        if fsdp:
            # ZeRO-3: all-gather params fwd+bwd per microbatch + reduce-scatter
            coll += shard_params * (2 * n_micro + 1)
        elif dp > 1:
            coll += 2 * shard_params                      # ring all-reduce
        if mesh.pod > 1 and pod_in_batch:
            pod_bytes = shard_params if not hier else shard_params / mesh.data
            coll += pod_bytes
            slowest_bw = hw.dci_bw if not hier else hw.ici_bw
    if tp_on and tp > 1:
        # 2 activation collectives per layer (attn out + mlp out); partial
        # sums reduce in f32 unless tp_reduce_dtype compresses them
        tp_red_bytes = 2 if str(g("tp_reduce_dtype", "float32")) \
            == "bfloat16" else 4
        act_coll = (tokens_global * cfg.d_model * tp_red_bytes
                    * 2 * cfg.n_layers * (3 if train else 1))
        if sp_on:
            act_coll *= 0.75    # RS+AG instead of AR; SP keeps seq sharded
        coll += act_coll
    if ep_on:
        moe_layers = sum(1 for s in cfg.pattern if s.mlp == MLP_MOE) * cfg.n_groups
        a2a = (tokens_global * cfg.d_model * act_dtype_bytes
               * 2 * moe_layers * (3 if train else 1)
               * float(g("moe_capacity_factor", 1.25)))
        coll += a2a
    chunk_kb = float(g("ici_collective_chunk_kb", 1024))
    # chunked collectives overlap poorly if tiny, congest if huge (mild, peaked)
    chunk_pen = 1.0 + 0.15 * abs(math.log2(max(chunk_kb, 1) / 1024.0)) / 4.0
    collective_s = coll * chunk_pen / (mesh.chips * slowest_bw)

    # ---- overlap: per-microbatch allreduce hides collectives behind compute ----
    if train and bool(g("allreduce_per_microbatch", False)) and n_micro > 1:
        collective_exposed = max(collective_s - compute_s * 0.6, collective_s * 0.25)
    else:
        collective_exposed = collective_s

    # ---- HBM feasibility ---------------------------------------------------------
    param_shard = mesh.chips if fsdp else (tp if tp_on else 1)
    hbm = n_params * pbytes / param_shard
    if train:
        opt_shard = mesh.chips if fsdp else dp    # ZeRO-1 at minimum
        hbm += n_params * 12 / opt_shard
        act_live = (micro * S * cfg.d_model * act_dtype_bytes
                    * cfg.n_layers * layer_io * act_frac) / max(tp, 1)
        hbm += act_live
    if decode:
        kv_dtype = _bytes_of(g("kv_cache_dtype", "bfloat16"))
        kv_shard = max(tp, 1)
        if bool(g("shard_kv_seq", False)):
            kv_shard *= mesh.data
        hbm += (2 * (B / max(dp, 1)) * S * cfg.kv_dim * kv_dtype
                * cfg.attn_layer_count) / max(kv_shard / max(tp, 1), 1)
    feasible = hbm <= hw.hbm_bytes * 0.92

    step = max(compute_s, memory_s, collective_exposed)
    # non-dominant terms still partially serialize (imperfect overlap)
    step += 0.15 * (compute_s + memory_s + collective_exposed - step)
    if not feasible:
        step *= 4.0 + 4.0 * (hbm / (hw.hbm_bytes * 0.92) - 1.0)  # soft OOM

    return CostBreakdown(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_exposed,
        step_s=step, hbm_per_chip=hbm, feasible=feasible, flops=flops,
        hbm_bytes_moved=hbm_moved, collective_bytes=coll,
    )

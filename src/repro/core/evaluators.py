"""Experiment Unit backends (paper §3.1/§3.4).

* :class:`AnalyticEvaluator` — the *test cluster*: the closed-form cost
  model corrupted with multiplicative Gaussian noise (σ = 2.5 %, the
  paper's measured benchmark deviation).  Milliseconds per call; used for
  the 300-sample ranking phase and every optimizer-comparison benchmark.
* :class:`CompiledEvaluator` — the *product cluster*: applies the config to
  the real step function, ``jit().lower().compile()`` on the production
  mesh and scores the three roofline terms extracted from the compiled
  HLO.  Deterministic, seconds per call; used to validate recommendations
  (the paper's Fig. 5 transfer) and for the §Perf hillclimbs.

Both return *step seconds* (lower is better) and log every evaluation into
the evaluation database (controller.py).

Batch protocol: every evaluator additionally exposes
``evaluate_batch(configs) -> np.ndarray`` scoring n configs at once — the
test cluster can run many benchmarks concurrently (BestConfig's
parallelized sampling rounds), so the tuner stack treats the batch as the
unit of work.  ``AnalyticEvaluator`` draws its noise with a *per-row* PRNG
key and a single vmapped draw, so a batch reproduces the noise stream of
n sequential ``__call__``s (same keys; values equal to f32 ULP);
``CompiledEvaluator`` falls back to a thread pool over the compile cache.

Service protocol: both evaluators are *backends* of the first-class
evaluation API in :mod:`repro.core.service` — the analytic evaluator's
``evaluate_batch_detailed`` gives the immediate service its values *and*
feasibility in one bit-compatible sweep, and the compiled evaluator
(``service_kind = "pool"``) runs behind a persistent worker pool that
streams completions out of order.  :func:`repro.core.service.as_service`
performs the wrapping; ``evaluate_many`` below is the legacy synchronous
shim over the same layer.
"""

from __future__ import annotations

import hashlib
import json
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import (SINGLE_POD, CostBreakdown, Hardware,
                                  MeshShape, V5E, estimate)
from repro.core.space import Config
from repro.models.config import ModelConfig, ShapeCell


def _trim_history(history: list, cap: Optional[int]):
    """Ring-buffer semantics on a plain list: keep the newest ``cap``
    records.  ``cap=None`` keeps everything (tests inspect full history);
    long async runs set a cap so streamed completions don't grow memory
    without bound."""
    if cap is not None and len(history) > cap:
        del history[:len(history) - cap]


def _stable_seed(cfg: Config, salt) -> int:
    """Noise must be i.i.d. per *evaluation*, not per config — repeated
    probes of one config see fresh noise (the paper's averaging dilemma).
    ``salt`` is the stream selector: the call-indexed int for unseeded
    evaluations, or the ``"seed:<n>"`` tag for request-seeded ones (the
    string prefix keeps the two streams disjoint — a request seed can
    never collide with a call index)."""
    s = json.dumps({k: str(v) for k, v in sorted(cfg.items())}, sort_keys=True)
    h = hashlib.blake2s(f"{s}|{salt}".encode()).digest()[:8]
    return int.from_bytes(h, "little") >> 1      # 63-bit: fits PRNGKey int64


def _noise_salt(seed: Optional[int], call_salt: int):
    """Replicated-measurement contract: a request that carries a seed
    draws noise from the seed-pinned stream — bit-reproducible for the
    same (config, seed) no matter which service, batch position or call
    count delivers it; an unseeded request keeps the legacy call-indexed
    stream (fresh i.i.d. noise per evaluation)."""
    return call_salt if seed is None else f"seed:{seed}"


def _key_data(seed: int) -> np.ndarray:
    """Raw threefry key words for a 63-bit seed.  Built host-side so a
    batch of keys is one uint32 [n, 2] transfer, not n PRNGKey dispatches
    (and, unlike ``PRNGKey``, keeps the high word under default x32)."""
    return np.array([seed >> 32, seed & 0xFFFFFFFF], np.uint32)


@jax.jit
def _lognoise(keys: jnp.ndarray, sigma) -> jnp.ndarray:
    """exp(σ·z) with one independent standard normal per row key."""
    return jnp.exp(sigma * jax.vmap(jax.random.normal)(keys))


@dataclass
class AnalyticEvaluator:
    model_cfg: ModelConfig
    cell: ShapeCell
    mesh: MeshShape = SINGLE_POD
    hw: Hardware = V5E
    noise_sigma: float = 0.025          # paper: ±2.5 % benchmark deviation
    seed: int = 0
    history_cap: Optional[int] = None   # keep-all by default (tests); async
                                        # runs cap the record ring buffer
    calls: int = 0
    history: list = field(default_factory=list)

    def breakdown(self, knobs: Config) -> CostBreakdown:
        return estimate(self.model_cfg, self.cell, self.mesh, knobs, self.hw)

    def true_step(self, knobs: Config) -> float:
        """Noise-free objective (tests / regret reporting only)."""
        return self.breakdown(knobs).step_s

    def _record(self, knobs: Config, bd: CostBreakdown, step: float):
        self.history.append({"knobs": dict(knobs), "step_s": step,
                             "true_step_s": bd.step_s,
                             "feasible": bd.feasible})
        _trim_history(self.history, self.history_cap)

    # the evaluation-service layer passes per-request seeds through the
    # batched path when this attribute is set (see service._score_batch)
    accepts_seeds = True

    def __call__(self, knobs: Config, seed: Optional[int] = None) -> float:
        bd = self.breakdown(knobs)
        self.calls += 1
        noise = 1.0
        if self.noise_sigma > 0:
            salt = _noise_salt(seed, self.seed + self.calls)
            keys = _key_data(_stable_seed(knobs, salt))
            noise = float(_lognoise(jnp.asarray(keys[None]),
                                    self.noise_sigma)[0])
        step = bd.step_s * noise
        self._record(knobs, bd, step)
        return step

    def evaluate_batch_detailed(
            self, configs: Sequence[Config],
            seeds: Optional[Sequence[Optional[int]]] = None,
    ) -> Tuple[np.ndarray, List[CostBreakdown]]:
        """Score n configs in one shot, returning the per-config cost
        breakdowns alongside the noisy step times — what the evaluation
        *service* reports as feasibility without re-running the cost
        model.  Same noise stream as n sequential ``__call__``\\ s (each
        row keeps its own eval-indexed noise key).  A per-row entry in
        ``seeds`` pins that row to the seed's noise stream instead (the
        replication contract: bit-identical for the same (config, seed)
        regardless of batch position or call count); ``None`` rows keep
        the call-indexed stream."""
        cfgs = list(configs)
        if seeds is None:
            seeds = [None] * len(cfgs)
        if not cfgs:
            return np.zeros(0, np.float64), []
        bds = [self.breakdown(c) for c in cfgs]
        base = self.calls
        self.calls += len(cfgs)
        steps = np.asarray([bd.step_s for bd in bds], np.float64)
        if self.noise_sigma > 0:
            keys = np.stack([
                _key_data(_stable_seed(
                    c, _noise_salt(s, self.seed + base + i + 1)))
                for i, (c, s) in enumerate(zip(cfgs, seeds))])
            noise = np.asarray(
                _lognoise(jnp.asarray(keys), self.noise_sigma), np.float64)
            steps = steps * noise
        for c, bd, s in zip(cfgs, bds, steps):
            self._record(c, bd, float(s))
        return steps, bds

    def evaluate_batch(self, configs: Sequence[Config]) -> np.ndarray:
        return self.evaluate_batch_detailed(configs)[0]


@dataclass
class CompiledEvaluator:
    """Scores a config by lowering+compiling the real step function.

    Lazy-imports the launch layer so ``repro.core`` stays importable in
    processes that must not touch jax device state (the dry-run sets
    XLA_FLAGS before any jax import).

    Thread-safe: the compile itself runs outside the lock (XLA releases
    the GIL, so distinct configs overlap in a worker pool), but every
    ``calls``/``history``/``_cache`` update happens under ``_lock`` so
    concurrent worker completions can't tear the bookkeeping.
    ``service_kind = "pool"`` tells :func:`repro.core.service.as_service`
    to wrap this evaluator in a persistent worker pool.
    """
    model_cfg: ModelConfig
    cell: ShapeCell
    multi_pod: bool = False
    max_workers: int = 4               # batch / worker-pool width
    history_cap: Optional[int] = None  # keep-all by default; see Analytic
    calls: int = 0
    history: list = field(default_factory=list)
    _cache: Dict[str, float] = field(default_factory=dict)
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    service_kind = "pool"

    @staticmethod
    def _key(knobs: Config) -> str:
        return json.dumps({k: str(v) for k, v in sorted(knobs.items())},
                          sort_keys=True)

    def _compile(self, knobs: Config) -> float:
        from repro.launch.dryrun import compile_cell  # lazy
        res = compile_cell(self.model_cfg, self.cell, knobs,
                           multi_pod=self.multi_pod)
        return res["roofline"]["step_s"]

    def _store(self, key: str, knobs: Config, step: float) -> float:
        """Record a finished compile; first writer wins on a duplicate
        (two workers may race to compile the same config — the score is
        deterministic, so the duplicate is dropped, not double-counted)."""
        with self._lock:
            if key not in self._cache:
                self.calls += 1
                self.history.append({"knobs": dict(knobs), "step_s": step})
                _trim_history(self.history, self.history_cap)
                self._cache[key] = step
            return self._cache[key]

    def __call__(self, knobs: Config) -> float:
        key = self._key(knobs)
        with self._lock:
            if key in self._cache:
                return self._cache[key]
        step = self._compile(knobs)      # slow path: outside the lock
        return self._store(key, knobs, step)

    def true_step(self, knobs: Config) -> float:
        """Noise-free objective — the compile path is deterministic, so
        this is ``__call__`` (cache-served on repeats).  Exists so both
        fidelities expose the same validation interface (the two-fidelity
        successive-halving demo scores final configs through it)."""
        return self(knobs)

    def evaluate_batch(self, configs: Sequence[Config]) -> np.ndarray:
        """Thread-pooled fallback: the compile path releases the GIL inside
        XLA, so distinct configs lower concurrently.  Cache hits and
        duplicate configs within the batch compile once."""
        from concurrent.futures import ThreadPoolExecutor

        cfgs = list(configs)
        keys = [self._key(c) for c in cfgs]
        with self._lock:
            missing: Dict[str, Config] = {}
            for k, c in zip(keys, cfgs):
                if k not in self._cache and k not in missing:
                    missing[k] = c
        if missing:
            order = list(missing)
            workers = min(self.max_workers, len(order))
            if workers > 1:
                with ThreadPoolExecutor(workers) as ex:
                    steps = list(ex.map(self._compile,
                                        (missing[k] for k in order)))
            else:
                steps = [self._compile(missing[k]) for k in order]
            for k, step in zip(order, steps):
                self._store(k, missing[k], step)
        with self._lock:
            return np.asarray([self._cache[k] for k in keys], np.float64)


def evaluate_many(evaluate, configs: Sequence[Config]) -> List[float]:
    """Batch-or-loop shim, delegated through the evaluation-service layer
    (:class:`repro.core.service.CallableServiceAdapter`) so there is
    exactly one place that decides between ``evaluate_batch`` and a
    sequential loop.  Synchronous contract preserved: a failed evaluation
    raises instead of returning a failed result."""
    from repro.core.service import CallableServiceAdapter, EvalRequest

    svc = CallableServiceAdapter(evaluate)
    results = svc.gather(svc.submit([EvalRequest(c) for c in configs]))
    failed = [r for r in results if not r.ok]
    if failed:
        raise RuntimeError(
            f"{len(failed)}/{len(results)} evaluations failed; first: "
            f"{failed[0].error}") from failed[0].exception
    return [float(r.value) for r in results]

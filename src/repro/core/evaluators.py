"""Experiment Unit backends (paper §3.1/§3.4).

* :class:`AnalyticEvaluator` — the *test cluster*: the closed-form cost
  model corrupted with multiplicative Gaussian noise (σ = 2.5 %, the
  paper's measured benchmark deviation).  Milliseconds per call; used for
  the 300-sample ranking phase and every optimizer-comparison benchmark.
* :class:`CompiledEvaluator` — the *product cluster*: applies the config to
  the real step function, ``jit().lower().compile()`` on the production
  mesh and scores the three roofline terms extracted from the compiled
  HLO.  Deterministic, seconds per call; used to validate recommendations
  (the paper's Fig. 5 transfer) and for the §Perf hillclimbs.

Both return *step seconds* (lower is better) and log every evaluation into
the evaluation database (controller.py).
"""

from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.costmodel import (SINGLE_POD, CostBreakdown, Hardware,
                                  MeshShape, V5E, estimate)
from repro.core.space import Config
from repro.models.config import ModelConfig, ShapeCell


def _stable_seed(cfg: Config, salt: int) -> int:
    """Noise must be i.i.d. per *evaluation*, not per config — repeated
    probes of one config see fresh noise (the paper's averaging dilemma)."""
    s = json.dumps({k: str(v) for k, v in sorted(cfg.items())}, sort_keys=True)
    h = hashlib.blake2s(f"{s}|{salt}".encode()).digest()[:8]
    return int.from_bytes(h, "little")


@dataclass
class AnalyticEvaluator:
    model_cfg: ModelConfig
    cell: ShapeCell
    mesh: MeshShape = SINGLE_POD
    hw: Hardware = V5E
    noise_sigma: float = 0.025          # paper: ±2.5 % benchmark deviation
    seed: int = 0
    calls: int = 0
    history: list = field(default_factory=list)

    def breakdown(self, knobs: Config) -> CostBreakdown:
        return estimate(self.model_cfg, self.cell, self.mesh, knobs, self.hw)

    def true_step(self, knobs: Config) -> float:
        """Noise-free objective (tests / regret reporting only)."""
        return self.breakdown(knobs).step_s

    def __call__(self, knobs: Config) -> float:
        bd = self.breakdown(knobs)
        self.calls += 1
        noise = 1.0
        if self.noise_sigma > 0:
            rng = np.random.default_rng(
                _stable_seed(knobs, self.seed + self.calls))
            noise = float(np.exp(rng.normal(0.0, self.noise_sigma)))
        step = bd.step_s * noise
        self.history.append({"knobs": dict(knobs), "step_s": step,
                             "true_step_s": bd.step_s,
                             "feasible": bd.feasible})
        return step


@dataclass
class CompiledEvaluator:
    """Scores a config by lowering+compiling the real step function.

    Lazy-imports the launch layer so ``repro.core`` stays importable in
    processes that must not touch jax device state (the dry-run sets
    XLA_FLAGS before any jax import).
    """
    model_cfg: ModelConfig
    cell: ShapeCell
    multi_pod: bool = False
    calls: int = 0
    history: list = field(default_factory=list)
    _cache: Dict[str, float] = field(default_factory=dict)

    def __call__(self, knobs: Config) -> float:
        from repro.launch.dryrun import compile_cell  # lazy
        key = json.dumps({k: str(v) for k, v in sorted(knobs.items())},
                         sort_keys=True)
        if key in self._cache:
            return self._cache[key]
        res = compile_cell(self.model_cfg, self.cell, knobs,
                           multi_pod=self.multi_pod)
        step = res["roofline"]["step_s"]
        self.calls += 1
        self.history.append({"knobs": dict(knobs), "step_s": step})
        self._cache[key] = step
        return step

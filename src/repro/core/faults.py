"""Deterministic fault injection for the evaluation stack.

A resilience layer you cannot test is a liability, and real-cluster
flakiness is not reproducible on demand — so this module makes it so:
:class:`FaultInjectingService` wraps any ticket-store evaluation service
and injects *seeded* faults per request.  The fault stream is derived
from ``EvalRequest.seed`` (falling back to a config digest), so a chaos
run is bit-replayable: same plan, same seeds, same faults, in the same
places.

Fault kinds (rates set independently by :class:`FaultPlan`):

* **transient** — the probe fails immediately with a
  :class:`~repro.core.resilience.TransientEvalError`, *without* touching
  the backend.  The backend's seeded noise stream therefore stays
  aligned with a fault-free run — the retry (which does reach the
  backend) measures exactly what the fault-free run measured, which is
  what makes the chaos-gate trace bit-identity property testable.
* **death** — same shape, but a ``ConnectionError`` styled as a worker
  death (exercises string/type classification rather than the explicit
  marker).
* **latency** — the dispatch to the backend is delayed by
  ``latency_s`` (stragglers; exercises out-of-order completion paths).
* **hang** — the request is swallowed: never dispatched, never
  completed.  Only a watchdog above (``RetryPolicy.attempt_timeout_s``
  or the worker-pool ``deadline_s``) unwedges it; :meth:`release_hung`
  lets tests settle them manually.
* **drop** — the request reaches the backend but its completion is
  discarded (a lost message; again recovered only by a watchdog).
* **duplicate** — the completion is delivered twice (exercises the
  ticket store's exactly-once guard).

Each (kind, request-key) coin also folds in an *occurrence counter*, so
a retried request draws a fresh coin: a 20%-transient plan fails a
probe's first attempt with p=0.2 and its retry with an independent
p=0.2, instead of deterministically re-failing the same seed forever.
"""

from __future__ import annotations

import hashlib
import threading
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.resilience import TransientEvalError
from repro.core.service import (EvalRequest, EvalResult, EvalTicket,
                                _ServiceBase, _failed, _result)

__all__ = ["FaultPlan", "FaultInjectingService"]

# draw order: at most one fault per dispatch, first trip wins — ordered
# most-disruptive-first so e.g. a plan with both hang_rate and
# duplicate_rate set hangs p_hang of requests outright
_KINDS = ("transient", "death", "hang", "drop", "duplicate", "latency")


@dataclass(frozen=True)
class FaultPlan:
    """Per-request fault rates (independent coins, first trip wins, in
    the order transient > death > hang > drop > duplicate > latency).
    ``seed`` namespaces the whole fault stream — two services with equal
    plans inject identical faults on identical request streams."""
    transient_rate: float = 0.0
    death_rate: float = 0.0
    hang_rate: float = 0.0
    drop_rate: float = 0.0
    duplicate_rate: float = 0.0
    latency_rate: float = 0.0
    latency_s: float = 0.05
    seed: int = 0

    def __post_init__(self):
        for kind in _KINDS:
            rate = getattr(self, f"{kind}_rate")
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{kind}_rate must be in [0, 1], "
                                 f"got {rate}")

    def rate(self, kind: str) -> float:
        return getattr(self, f"{kind}_rate")

    @property
    def active(self) -> bool:
        return any(self.rate(k) > 0.0 for k in _KINDS)

    def coin(self, kind: str, key: str, occurrence: int) -> bool:
        """Deterministic Bernoulli draw for one fault kind on one
        request occurrence."""
        rate = self.rate(kind)
        if rate <= 0.0:
            return False
        h = hashlib.blake2s(
            f"fault|{self.seed}|{kind}|{key}|{occurrence}".encode()
        ).digest()[:8]
        return int.from_bytes(h, "little") / 2.0 ** 64 < rate

    def draw(self, key: str, occurrence: int) -> Optional[str]:
        """The fault (if any) injected on this occurrence of ``key``."""
        for kind in _KINDS:
            if self.coin(kind, key, occurrence):
                return kind
        return None


def _request_key(req: EvalRequest) -> str:
    """Stable identity of a request for the fault stream: the seed when
    present (the replication/retry machinery folds seeds per repeat, so
    distinct probes get distinct streams), else a digest of what the
    backend would see."""
    if req.seed is not None:
        return str(req.seed)
    items = sorted(req.config.items()) if hasattr(req.config, "items") \
        else repr(req.config)
    return hashlib.blake2s(
        f"{items}|{req.fidelity}|{req.workload}".encode()).hexdigest()[:16]


class FaultInjectingService(_ServiceBase):
    """Chaos wrapper: forwards requests to ``inner`` unless the plan's
    seeded coins say otherwise.  Exposes the ``_issue``/``_dispatch``
    split, so it slots anywhere in the service stack — typically
    *between* the :class:`~repro.core.resilience.ResilientService` and
    the real backend, so the resilience layer is what gets exercised.

    ``injected`` counts faults by kind; ``release_hung()`` completes any
    currently-hung tickets as failed-transient (for tests that want to
    settle the world without a watchdog)."""

    def __init__(self, inner: _ServiceBase, plan: FaultPlan):
        if not isinstance(inner, _ServiceBase):
            raise TypeError(
                f"FaultInjectingService needs the _issue/_dispatch split "
                f"of a _ServiceBase; got {type(inner).__name__}")
        super().__init__()
        self.inner = inner
        self.plan = plan
        self.injected: Dict[str, int] = {k: 0 for k in _KINDS}
        # inner uid -> (outer ticket, mode); mode in {"ok","drop","dup"}
        self._routes: Dict[int, Tuple[EvalTicket, str]] = {}
        self._occurrence: Dict[str, int] = {}
        self._hung: List[EvalTicket] = []
        self._latency_timers: List[threading.Timer] = []
        inner._sink = self._on_inner

    # -- submission ---------------------------------------------------------

    def submit(self, requests: Sequence[EvalRequest]) -> List[EvalTicket]:
        tickets = self._issue(requests)
        self._dispatch(tickets)
        return tickets

    def _dispatch(self, tickets: Sequence[EvalTicket]) -> None:
        for t in tickets:
            key = _request_key(t.request)
            with self._cv:
                occ = self._occurrence.get(key, 0)
                self._occurrence[key] = occ + 1
            kind = self.plan.draw(key, occ)
            if kind is not None:
                with self._cv:
                    self.injected[kind] += 1
            self._apply(t, kind)

    def _apply(self, ticket: EvalTicket, kind: Optional[str]) -> None:
        if kind == "transient":
            err = TransientEvalError("injected transient backend fault")
            self._complete(_result(ticket, _failed(err), 0.0))
        elif kind == "death":
            err = ConnectionError(
                "injected worker death: connection reset by peer")
            self._complete(_result(ticket, _failed(err), 0.0))
        elif kind == "hang":
            with self._cv:
                self._hung.append(ticket)
        elif kind == "latency":
            timer = threading.Timer(self.plan.latency_s,
                                    self._forward, (ticket, "ok"))
            timer.daemon = True
            with self._cv:
                self._latency_timers.append(timer)
            timer.start()
        elif kind == "drop":
            self._forward(ticket, "drop")
        elif kind == "duplicate":
            self._forward(ticket, "dup")
        else:
            self._forward(ticket, "ok")

    def _forward(self, outer: EvalTicket, mode: str) -> None:
        inner_tickets = self.inner._issue([outer.request])
        with self._cv:
            self._routes[inner_tickets[0].uid] = (outer, mode)
        self.inner._dispatch(inner_tickets)

    # -- completion routing -------------------------------------------------

    def _on_inner(self, result: EvalResult) -> None:
        with self._cv:
            route = self._routes.pop(result.ticket.uid, None)
        if route is None:
            return
        outer, mode = route
        if mode == "drop":
            return                      # completion lost in the mail
        settled = replace(result, ticket=outer)
        self._complete(settled)
        if mode == "dup":
            self._complete(settled)     # exactly-once guard drops this

    # -- test hooks ---------------------------------------------------------

    @property
    def hung(self) -> int:
        with self._cv:
            return len(self._hung)

    def release_hung(self) -> int:
        """Complete all currently-hung tickets as failed-transient;
        returns how many were released."""
        with self._cv:
            hung, self._hung = self._hung, []
        for t in hung:
            err = TransientEvalError("injected hang released by harness")
            self._complete(_result(t, _failed(err), 0.0))
        return len(hung)

    def close(self):
        with self._cv:
            timers, self._latency_timers = self._latency_timers, []
        for timer in timers:
            timer.cancel()
        self.release_hung()
        self.inner.close()

    def __exit__(self, *exc):
        self.close()

"""Gaussian-process regression for noisy black-box objectives (paper §3.4).

The paper's argument for BO-with-GP is noise tolerance: the GP's noise
hyperparameter lets it approximate the objective *through* noise-corrupted
observations.  Implementation:

* Matérn-5/2 (default) and RBF kernels over the unit cube;
* exact GP with Cholesky solves (≤ a few hundred points — the paper's
  regime, where each point costs a cluster benchmark);
* hyperparameters (lengthscale per-dim or shared, signal var, noise var)
  fit by maximizing the log marginal likelihood with Adam on log-params,
  jit-compiled end to end;
* the Gram matrix hot spot is a Pallas TPU kernel
  (kernels/gp_gram) with a jnp fallback — on a fleet the tuner itself may
  run on an accelerator host, and the Gram matrix is its only O(n²·d) op.
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

SQRT5 = math.sqrt(5.0)


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def _sqdist(xa, xb, inv_ls):
    """Scaled squared distance: xa [n,d], xb [m,d], inv_ls [d] -> [n,m]."""
    a = xa * inv_ls
    b = xb * inv_ls
    a2 = jnp.sum(a * a, axis=1)[:, None]
    b2 = jnp.sum(b * b, axis=1)[None, :]
    return jnp.maximum(a2 + b2 - 2.0 * (a @ b.T), 0.0)


def matern52(xa, xb, lengthscale, signal_var):
    """Matérn-5/2: smooth enough for GP-BO, rougher than RBF (default).

    The sqrt is guarded with the double-``where`` trick: d/dr sqrt(r)|₀ is
    ∞, and zero distances (diagonal) would otherwise poison the
    marginal-likelihood gradients with NaN.
    """
    inv_ls = 1.0 / lengthscale
    d2 = _sqdist(xa, xb, inv_ls)
    safe = jnp.where(d2 > 1e-12, d2, 1.0)
    r = jnp.where(d2 > 1e-12, jnp.sqrt(safe), 0.0)
    s = SQRT5 * r
    return signal_var * (1.0 + s + s * s / 3.0) * jnp.exp(-s)


def rbf(xa, xb, lengthscale, signal_var):
    inv_ls = 1.0 / lengthscale
    return signal_var * jnp.exp(-0.5 * _sqdist(xa, xb, inv_ls))


KERNELS = {"matern52": matern52, "rbf": rbf}


def gram(kind: str, x, lengthscale, signal_var, *, use_pallas: bool = False):
    """Kernel Gram matrix; optionally via the Pallas tile kernel."""
    if use_pallas and kind == "matern52":
        from repro.kernels.gp_gram.ops import matern52_gram
        return matern52_gram(x, lengthscale, signal_var)
    return KERNELS[kind](x, x, lengthscale, signal_var)


# ---------------------------------------------------------------------------
# GP posterior
# ---------------------------------------------------------------------------

class GPParams(NamedTuple):
    log_lengthscale: jnp.ndarray   # [d] (ARD)
    log_signal_var: jnp.ndarray    # []
    log_noise_var: jnp.ndarray     # []


class GPState(NamedTuple):
    params: GPParams
    x: jnp.ndarray                 # [n, d] training inputs (unit cube)
    y: jnp.ndarray                 # [n] standardized targets
    chol: jnp.ndarray              # [n, n] cholesky of K + σ²I
    alpha: jnp.ndarray             # [n] K⁻¹ y
    y_mean: jnp.ndarray
    y_std: jnp.ndarray


def init_params(d: int, lengthscale: float = 0.3, signal: float = 1.0,
                noise: float = 1e-2) -> GPParams:
    return GPParams(
        log_lengthscale=jnp.full((d,), math.log(lengthscale), jnp.float32),
        log_signal_var=jnp.asarray(math.log(signal), jnp.float32),
        log_noise_var=jnp.asarray(math.log(noise), jnp.float32),
    )


def params_to_dict(params: GPParams) -> dict:
    """JSON-serializable snapshot of the GP hyperparameters — the
    first-class artifact warm restarts ship across processes
    (:meth:`repro.core.strategy.BOStrategy.state_dict`).  Values are the
    log-domain parameters exactly as fitted, so a roundtrip through
    :func:`params_from_dict` is bit-exact at f32."""
    return {
        "log_lengthscale": [float(v)
                            for v in np.asarray(params.log_lengthscale)],
        "log_signal_var": float(params.log_signal_var),
        "log_noise_var": float(params.log_noise_var),
    }


def params_from_dict(d: dict) -> GPParams:
    """Inverse of :func:`params_to_dict`."""
    return GPParams(
        log_lengthscale=jnp.asarray(d["log_lengthscale"], jnp.float32),
        log_signal_var=jnp.asarray(float(d["log_signal_var"]), jnp.float32),
        log_noise_var=jnp.asarray(float(d["log_noise_var"]), jnp.float32),
    )


PAD_NOISE = 1e6   # pseudo-point noise: pads contribute ~nothing to the fit


class MTGPParams(NamedTuple):
    """Multi-task (ICM) hyperparameters: the base-kernel triple shared
    across tasks plus a rank-1-plus-diagonal task covariance and a
    per-task mean offset.  ``task_w``/``log_task_kappa``/``task_offset``
    are [T]; everything else matches :class:`GPParams`."""
    log_lengthscale: jnp.ndarray   # [d] (ARD, shared across tasks)
    log_signal_var: jnp.ndarray    # []
    log_noise_var: jnp.ndarray     # []
    task_w: jnp.ndarray            # [T] rank-1 factor of the task kernel
    log_task_kappa: jnp.ndarray    # [T] per-task diagonal boost
    task_offset: jnp.ndarray       # [T] per-task mean (standardized y)


class MTGPState(NamedTuple):
    params: MTGPParams
    x: jnp.ndarray                 # [n, d] inputs (unit cube, no task col)
    tasks: jnp.ndarray             # [n] int32 task indices
    y: jnp.ndarray                 # [n] standardized targets
    chol: jnp.ndarray              # [n, n]
    alpha: jnp.ndarray             # [n] K⁻¹ (y - offset[tasks])
    y_mean: jnp.ndarray
    y_std: jnp.ndarray


def _jitter(nv, sv):
    """Relative diagonal jitter: keeps the condition number f32-safe even
    when the fitted signal variance is large / lengthscale long (K near
    rank-1).  Shared by the posterior build and the select_batch fantasy
    appends — the two paths must stamp identical diagonals."""
    return nv + 1e-4 * sv + 1e-6


def _build(params: GPParams, x, y, kind: str, extra_noise=None,
           use_pallas: bool = False):
    ls = jnp.exp(params.log_lengthscale)
    sv = jnp.exp(params.log_signal_var)
    nv = jnp.exp(params.log_noise_var)
    k = gram(kind, x, ls, sv, use_pallas=use_pallas)
    n = x.shape[0]
    diag = jnp.full((n,), _jitter(nv, sv), k.dtype)
    if extra_noise is not None:
        diag = diag + extra_noise
    kn = k + jnp.diag(diag)
    chol = jnp.linalg.cholesky(kn)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return chol, alpha


# jitted entry for posterior (re)builds outside the Adam loop — the
# constant-liar fantasy update calls this once per batch pick
_build_jit = partial(jax.jit, static_argnames=("kind", "use_pallas"))(_build)


def neg_log_marginal(params: GPParams, x, y, kind: str, extra_noise=None):
    chol, alpha = _build(params, x, y, kind, extra_noise)
    n = x.shape[0]
    return (0.5 * y @ alpha
            + jnp.sum(jnp.log(jnp.diagonal(chol)))
            + 0.5 * n * math.log(2 * math.pi))


@partial(jax.jit, static_argnames=("kind", "steps"))
def _fit(params: GPParams, x, y, kind: str, steps: int = 200,
         lr: float = 0.05, extra_noise=None):
    """Adam on log-hyperparameters maximizing the marginal likelihood."""
    grad_fn = jax.value_and_grad(
        lambda p: neg_log_marginal(p, x, y, kind, extra_noise))

    def step(carry, _):
        p, m, v, t = carry
        loss, g = grad_fn(p)
        g = jax.tree.map(lambda gi: jnp.nan_to_num(gi), g)  # NaN-proof step
        t = t + 1
        m = jax.tree.map(lambda mi, gi: 0.9 * mi + 0.1 * gi, m, g)
        v = jax.tree.map(lambda vi, gi: 0.999 * vi + 0.001 * gi * gi, v, g)
        mhat = jax.tree.map(lambda mi: mi / (1 - 0.9 ** t), m)
        vhat = jax.tree.map(lambda vi: vi / (1 - 0.999 ** t), v)
        p = jax.tree.map(lambda pi, mh, vh: pi - lr * mh / (jnp.sqrt(vh) + 1e-8),
                         p, mhat, vhat)
        # clamp hyperparams to sane boxes (noise floor keeps Cholesky PSD)
        p = GPParams(
            log_lengthscale=jnp.clip(p.log_lengthscale, math.log(1e-2), math.log(3.0)),
            log_signal_var=jnp.clip(p.log_signal_var, math.log(1e-2), math.log(1e2)),
            log_noise_var=jnp.clip(p.log_noise_var, math.log(1e-4), math.log(1.0)),
        )
        return (p, m, v, t), loss

    zeros = jax.tree.map(jnp.zeros_like, params)
    (p, _, _, _), losses = jax.lax.scan(
        step, (params, zeros, zeros, jnp.asarray(0, jnp.float32)),
        None, length=steps)
    return p, losses


def _bucket(n: int) -> int:
    """Pad count: next multiple of 16 — bounds jit recompiles to O(n/16)
    shapes instead of one per BO iteration."""
    return ((n + 15) // 16) * 16


def _prepare(x: np.ndarray, y: np.ndarray, pad: bool,
             pad_to: Optional[int] = None,
             obs_var: Optional[np.ndarray] = None):
    """Standardize y and append huge-noise pseudo-points up to the target
    shape (``pad_to`` or the next bucket).  ``obs_var`` [n] is the
    per-observation measurement variance in *raw* y units (replicated
    measurements report the variance of their mean); it lands on the same
    extra-noise diagonal the pads use, rescaled by 1/y_std² to match the
    standardized targets."""
    x = np.asarray(x, np.float32)
    y_raw = np.asarray(y, np.float32)
    n, d = x.shape
    y_mean, y_std = float(y_raw.mean()), float(y_raw.std())
    if y_std < 1e-12:
        y_std = 1.0
    ys = (y_raw - y_mean) / y_std
    extra = None
    if obs_var is not None:
        extra = np.asarray(obs_var, np.float32) / (y_std * y_std)
    if pad or pad_to:
        m = max(_bucket(n), pad_to or 0)
        if m > n:
            x = np.vstack([x, np.full((m - n, d), 0.5, np.float32)])
            ys = np.concatenate([ys, np.zeros(m - n, np.float32)])
            padded = np.zeros(m, np.float32)
            if extra is not None:
                padded[:n] = extra
            padded[n:] = PAD_NOISE
            extra = padded
    xj = jnp.asarray(x)
    yj = jnp.asarray(ys)
    ej = None if extra is None else jnp.asarray(extra)
    return xj, yj, ej, y_mean, y_std


def fit(x: np.ndarray, y: np.ndarray, kind: str = "matern52",
        steps: int = 200, params: Optional[GPParams] = None,
        pad: bool = True, pad_to: Optional[int] = None,
        use_pallas: bool = False,
        obs_var: Optional[np.ndarray] = None,
        tasks: Optional[np.ndarray] = None):
    """Standardize y, fit hyperparameters, build the posterior.

    ``pad`` appends huge-noise pseudo-points up to a shape bucket so the
    jit caches of ``_fit``/``predict`` are reused across BO iterations
    (the pads' posterior influence is ~1/PAD_NOISE — negligible).
    ``pad_to`` pins the padded size outright: a BO run that knows its
    total budget compiles each jit exactly once instead of once per
    16-point growth bucket.

    ``params`` warm-starts the hyperparameter optimization (e.g. from the
    previous BO round's posterior); with ``steps=0`` they are used as-is.

    ``obs_var`` [n] makes the GP heteroscedastic: per-observation
    measurement variance (raw y units — replicated measurements report
    the variance of their pooled mean) added to the noise diagonal on top
    of the fitted global scalar, through the same ``extra_noise``
    machinery the pads use.  The jitted ``lax.scan`` Adam loop and the
    Pallas gram route are untouched — extra noise only enters the
    diagonal stamp.  ``None`` (the default) is bit-identical to the
    homoscedastic path, and :func:`predict` / :func:`select_batch` /
    :func:`select_batch_sharded` need no variance argument: the
    heteroscedastic diagonal is baked into ``state.chol``, and fantasy
    appends deliberately keep the global-scalar diagonal (a fantasy point
    has no empirical repeat variance).

    ``use_pallas`` routes the posterior Gram build through the
    kernels/gp_gram tile kernel (matern52 only; jnp fallback otherwise).
    The marginal-likelihood Adam loop stays on the jnp kernel — it is
    differentiated, and the Pallas kernel defines no VJP.

    ``tasks`` [n] switches on the multi-task (ICM) path: integer task
    indices aligned with the rows of ``x``.  With more than one distinct
    task the fit routes through :func:`fit_multitask` and returns an
    :class:`MTGPState`; with exactly one distinct task the column is
    dropped and this is *exactly* the single-task fit (same jit cache,
    same GPState) — the fallback the transfer layer relies on when a
    corpus collapses to a single workload.
    """
    if tasks is not None:
        t = np.asarray(tasks, np.int32)
        if t.shape[0] != np.asarray(x).shape[0]:
            raise ValueError(
                f"tasks has {t.shape[0]} rows, x has "
                f"{np.asarray(x).shape[0]}")
        if t.size and int(t.max()) > 0:
            if params is not None and not isinstance(params, MTGPParams):
                raise TypeError("multi-task fit warm-start needs MTGPParams")
            return fit_multitask(x, y, t, kind=kind, steps=steps,
                                 params=params, obs_var=obs_var)
        # exact single-task fallback: one task present, column dropped
    xj, yj, ej, y_mean, y_std = _prepare(x, y, pad, pad_to, obs_var)
    if params is None:
        params = init_params(int(xj.shape[1]))
    if steps > 0:
        params, _ = _fit(params, xj, yj, kind, steps=steps, extra_noise=ej)
    chol, alpha = _build_jit(params, xj, yj, kind, ej,
                             use_pallas=use_pallas)
    return GPState(params, xj, yj, chol, alpha,
                   jnp.asarray(y_mean), jnp.asarray(y_std))


def condition(params: GPParams, x: np.ndarray, y: np.ndarray,
              kind: str = "matern52", pad: bool = True,
              pad_to: Optional[int] = None,
              use_pallas: bool = False,
              obs_var: Optional[np.ndarray] = None) -> GPState:
    """Posterior for (x, y) under *fixed* hyperparameters — no
    marginal-likelihood refit.  This is the constant-liar fantasy update
    of q-batch acquisition: one Cholesky rebuild, no Adam.  (The
    device-resident :func:`select_batch` replaces this per-pick rebuild
    with an O(n²) :func:`chol_append`; ``condition`` remains the
    reference path and the entry for one-off posterior updates.)"""
    return fit(x, y, kind, steps=0, params=params, pad=pad, pad_to=pad_to,
               use_pallas=use_pallas, obs_var=obs_var)


# ---------------------------------------------------------------------------
# multi-task GP (intrinsic coregionalization, rank-1 + diagonal)
# ---------------------------------------------------------------------------

def init_mt_params(d: int, n_tasks: int, lengthscale: float = 0.3,
                   signal: float = 1.0, noise: float = 1e-2,
                   offsets: Optional[np.ndarray] = None) -> MTGPParams:
    """ICM init: ``task_w = 1`` (tasks fully correlated a priori) with a
    small diagonal boost, per-task offsets from the data when given."""
    off = (jnp.zeros((n_tasks,), jnp.float32) if offsets is None
           else jnp.asarray(offsets, jnp.float32))
    return MTGPParams(
        log_lengthscale=jnp.full((d,), math.log(lengthscale), jnp.float32),
        log_signal_var=jnp.asarray(math.log(signal), jnp.float32),
        log_noise_var=jnp.asarray(math.log(noise), jnp.float32),
        task_w=jnp.ones((n_tasks,), jnp.float32),
        log_task_kappa=jnp.full((n_tasks,), math.log(0.1), jnp.float32),
        task_offset=off,
    )


def mt_params_to_dict(params: MTGPParams) -> dict:
    """JSON snapshot of the multi-task hyperparameters (log-domain values
    as fitted, like :func:`params_to_dict`)."""
    return {
        "log_lengthscale": [float(v)
                            for v in np.asarray(params.log_lengthscale)],
        "log_signal_var": float(params.log_signal_var),
        "log_noise_var": float(params.log_noise_var),
        "task_w": [float(v) for v in np.asarray(params.task_w)],
        "log_task_kappa": [float(v)
                           for v in np.asarray(params.log_task_kappa)],
        "task_offset": [float(v) for v in np.asarray(params.task_offset)],
    }


def mt_params_from_dict(d: dict) -> MTGPParams:
    return MTGPParams(
        log_lengthscale=jnp.asarray(d["log_lengthscale"], jnp.float32),
        log_signal_var=jnp.asarray(float(d["log_signal_var"]), jnp.float32),
        log_noise_var=jnp.asarray(float(d["log_noise_var"]), jnp.float32),
        task_w=jnp.asarray(d["task_w"], jnp.float32),
        log_task_kappa=jnp.asarray(d["log_task_kappa"], jnp.float32),
        task_offset=jnp.asarray(d["task_offset"], jnp.float32),
    )


def shared_params(params: MTGPParams) -> GPParams:
    """Project the shared base-kernel triple out of a multi-task fit —
    the warm start a single-task GP on a *new* workload inherits."""
    return GPParams(log_lengthscale=params.log_lengthscale,
                    log_signal_var=params.log_signal_var,
                    log_noise_var=params.log_noise_var)


def _task_cov(params: MTGPParams):
    """B = w wᵀ + diag(exp κ) — rank-1 plus diagonal, always PSD."""
    w = params.task_w
    return w[:, None] * w[None, :] + jnp.diag(
        jnp.exp(params.log_task_kappa))


def _mt_build(params: MTGPParams, x, tasks, y, kind: str,
              extra_noise=None):
    ls = jnp.exp(params.log_lengthscale)
    sv = jnp.exp(params.log_signal_var)
    nv = jnp.exp(params.log_noise_var)
    b = _task_cov(params)
    k = KERNELS[kind](x, x, ls, sv) * b[tasks[:, None], tasks[None, :]]
    n = x.shape[0]
    diag = jnp.full((n,), _jitter(nv, sv), k.dtype)
    if extra_noise is not None:
        diag = diag + extra_noise
    kn = k + jnp.diag(diag)
    chol = jnp.linalg.cholesky(kn)
    r = y - params.task_offset[tasks]
    alpha = jax.scipy.linalg.cho_solve((chol, True), r)
    return chol, alpha, r


def mt_neg_log_marginal(params: MTGPParams, x, tasks, y, kind: str,
                        extra_noise=None):
    chol, alpha, r = _mt_build(params, x, tasks, y, kind, extra_noise)
    n = x.shape[0]
    return (0.5 * r @ alpha
            + jnp.sum(jnp.log(jnp.diagonal(chol)))
            + 0.5 * n * math.log(2 * math.pi))


@partial(jax.jit, static_argnames=("kind", "steps"))
def _mt_fit(params: MTGPParams, x, tasks, y, kind: str, steps: int = 200,
            lr: float = 0.05, extra_noise=None):
    """Adam on the joint (base + task) log-marginal — the same scan body
    as :func:`_fit` with the task blocks clamped to their own boxes."""
    grad_fn = jax.value_and_grad(
        lambda p: mt_neg_log_marginal(p, x, tasks, y, kind, extra_noise))

    def step(carry, _):
        p, m, v, t = carry
        loss, g = grad_fn(p)
        g = jax.tree.map(lambda gi: jnp.nan_to_num(gi), g)
        t = t + 1
        m = jax.tree.map(lambda mi, gi: 0.9 * mi + 0.1 * gi, m, g)
        v = jax.tree.map(lambda vi, gi: 0.999 * vi + 0.001 * gi * gi, v, g)
        mhat = jax.tree.map(lambda mi: mi / (1 - 0.9 ** t), m)
        vhat = jax.tree.map(lambda vi: vi / (1 - 0.999 ** t), v)
        p = jax.tree.map(
            lambda pi, mh, vh: pi - lr * mh / (jnp.sqrt(vh) + 1e-8),
            p, mhat, vhat)
        p = MTGPParams(
            log_lengthscale=jnp.clip(p.log_lengthscale,
                                     math.log(1e-2), math.log(3.0)),
            log_signal_var=jnp.clip(p.log_signal_var,
                                    math.log(1e-2), math.log(1e2)),
            log_noise_var=jnp.clip(p.log_noise_var,
                                   math.log(1e-4), math.log(1.0)),
            task_w=jnp.clip(p.task_w, -3.0, 3.0),
            log_task_kappa=jnp.clip(p.log_task_kappa,
                                    math.log(1e-4), math.log(10.0)),
            task_offset=jnp.clip(p.task_offset, -5.0, 5.0),
        )
        return (p, m, v, t), loss

    zeros = jax.tree.map(jnp.zeros_like, params)
    (p, _, _, _), losses = jax.lax.scan(
        step, (params, zeros, zeros, jnp.asarray(0, jnp.float32)),
        None, length=steps)
    return p, losses


def fit_multitask(x: np.ndarray, y: np.ndarray, tasks: np.ndarray,
                  kind: str = "matern52", steps: int = 200,
                  params: Optional[MTGPParams] = None,
                  obs_var: Optional[np.ndarray] = None) -> MTGPState:
    """Fit the ICM multi-task GP over stacked per-task observations.

    Targets are standardized *globally* (one μ/σ over every task) and the
    per-task level differences are absorbed by the learned ``task_offset``
    mean — initialized at each task's empirical standardized mean so the
    Adam loop starts from the right basin.  No shape padding: a corpus
    fit happens once per transfer warm-start, not once per BO round, so
    jit-cache churn is not on the hot path.
    """
    x = np.asarray(x, np.float32)
    y_raw = np.asarray(y, np.float32)
    t = np.asarray(tasks, np.int32)
    n_tasks = int(t.max()) + 1
    y_mean, y_std = float(y_raw.mean()), float(y_raw.std())
    if y_std < 1e-12:
        y_std = 1.0
    ys = (y_raw - y_mean) / y_std
    extra = None
    if obs_var is not None:
        extra = jnp.asarray(
            np.asarray(obs_var, np.float32) / (y_std * y_std))
    if params is None:
        offsets = np.zeros(n_tasks, np.float32)
        for i in range(n_tasks):
            sel = t == i
            if sel.any():
                offsets[i] = float(ys[sel].mean())
        params = init_mt_params(int(x.shape[1]), n_tasks, offsets=offsets)
    xj, tj, yj = jnp.asarray(x), jnp.asarray(t), jnp.asarray(ys)
    if steps > 0:
        params, _ = _mt_fit(params, xj, tj, yj, kind, steps=steps,
                            extra_noise=extra)
    chol, alpha, _ = _mt_build(params, xj, tj, yj, kind, extra)
    return MTGPState(params, xj, tj, yj, chol, alpha,
                     jnp.asarray(y_mean), jnp.asarray(y_std))


@partial(jax.jit, static_argnames=("kind",))
def _mt_predict(state: MTGPState, xq, w_q, kappa_q, off_q, kind: str):
    ls = jnp.exp(state.params.log_lengthscale)
    sv = jnp.exp(state.params.log_signal_var)
    kq = (KERNELS[kind](xq, state.x, ls, sv)
          * (w_q * state.params.task_w)[state.tasks][None, :])
    mean_s = off_q + kq @ state.alpha
    v = jax.scipy.linalg.solve_triangular(state.chol, kq.T, lower=True)
    prior = (w_q * w_q + kappa_q) * sv
    var_s = jnp.maximum(prior - jnp.sum(v * v, axis=0), 1e-12)
    mean = mean_s * state.y_std + state.y_mean
    std = jnp.sqrt(var_s) * state.y_std
    return mean, std


def predict_multitask(state: MTGPState, xq, task: Optional[int] = None,
                      kind: str = "matern52"):
    """Posterior mean/std at ``xq`` for one task (original y scale).

    ``task=None`` is the **stacked prior** for an *unseen* task: its
    rank-1 weight, diagonal and mean offset are the averages over the
    fitted tasks, so the prediction borrows exactly the structure every
    corpus workload shares and stays honestly wide where they disagree
    (the averaged ``w`` shrinks the cross-covariance, inflating the
    posterior variance — which is what pseudo-observation inflation
    feeds on)."""
    p = state.params
    if task is None:
        w_q = jnp.mean(p.task_w)
        kappa_q = jnp.mean(jnp.exp(p.log_task_kappa))
        off_q = jnp.mean(p.task_offset)
    else:
        w_q = p.task_w[task]
        kappa_q = jnp.exp(p.log_task_kappa)[task]
        off_q = p.task_offset[task]
    return _mt_predict(state, jnp.asarray(xq, jnp.float32),
                       w_q, kappa_q, off_q, kind)


@partial(jax.jit, static_argnames=("kind", "use_pallas"))
def predict(state: GPState, xq, kind: str = "matern52",
            use_pallas: bool = False):
    """Posterior mean/std at query points xq [m,d] (original y scale)."""
    ls = jnp.exp(state.params.log_lengthscale)
    sv = jnp.exp(state.params.log_signal_var)
    if use_pallas and kind == "matern52":
        from repro.kernels.gp_gram.ops import matern52_cross
        kq = matern52_cross(xq, state.x, ls, sv)     # [m, n]
    else:
        kq = KERNELS[kind](xq, state.x, ls, sv)      # [m, n]
    mean_s = kq @ state.alpha
    v = jax.scipy.linalg.solve_triangular(state.chol, kq.T, lower=True)
    var_s = jnp.maximum(sv - jnp.sum(v * v, axis=0), 1e-12)
    mean = mean_s * state.y_std + state.y_mean
    std = jnp.sqrt(var_s) * state.y_std
    return mean, std


def expected_improvement(state: GPState, xq, best_y: float,
                         kind: str = "matern52", xi: float = 0.01):
    """EI for *minimization* of y (y = step time / negative bandwidth)."""
    mean, std = predict(state, xq, kind)
    std = jnp.maximum(std, 1e-9)
    imp = best_y - xi - mean
    z = imp / std
    cdf = 0.5 * (1 + jax.scipy.special.erf(z / math.sqrt(2)))
    pdf = jnp.exp(-0.5 * z * z) / math.sqrt(2 * math.pi)
    return imp * cdf + std * pdf


def ucb(state: GPState, xq, kind: str = "matern52", beta: float = 2.0):
    """Lower-confidence bound for minimization (returns negated for argmax)."""
    mean, std = predict(state, xq, kind)
    return -(mean - beta * std)


# ---------------------------------------------------------------------------
# device-resident q-batch selection
# ---------------------------------------------------------------------------

def chol_append(chol, k_vec, k_ss):
    """Incremental Cholesky append (O(n²), vs the O(n³) rebuild).

    Given ``chol`` (lower-triangular L with L Lᵀ = K, [n, n]), the cross
    column ``k_vec = K(x_new, X)`` [n] and the diagonal entry ``k_ss =
    k(x_new, x_new) + noise``, returns ``(l, d)`` such that
    ``[[L, 0], [lᵀ, d]]`` is the Cholesky factor of the (n+1)-point
    matrix ``[[K, k_vec], [k_vecᵀ, k_ss]]``.  This is the constant-liar
    fantasy update of q-batch acquisition without rebuilding anything.
    """
    l = jax.scipy.linalg.solve_triangular(chol, k_vec, lower=True)
    d = jnp.sqrt(jnp.maximum(k_ss - jnp.dot(l, l), 1e-12))
    return l, d


@partial(jax.jit,
         static_argnames=("q", "kind", "fantasy", "acquisition",
                          "use_pallas"))
def select_batch(state: GPState, cand, y_raw, n, best_y, q: int,
                 kind: str = "matern52", fantasy: str = "liar",
                 acquisition: str = "ei", xi: float = 0.01,
                 use_pallas: bool = False):
    """Fantasized q-EI batch selection as ONE compiled program.

    Replaces the host loop (q acquisition jit calls, q host argmax round
    trips, q full ``condition`` rebuilds at O(n³) each) with a single
    ``lax.scan`` over picks: score the candidate pool, masked argmax,
    fantasize the pick's outcome (constant liar at ``best_y`` or Kriging
    believer at the posterior mean) and append it to the posterior via
    :func:`chol_append` — O(n²) per fantasy point, never leaving the
    device.

    Layout: the fitted padded state (``state.x`` [m, d], ``state.chol``
    [m, m], pads included exactly as :func:`fit` built them) occupies the
    leading block of a fixed [m+q-1]-size working set; fantasy points are
    *appended* into the trailing slots, so every shape is pinned by
    (m, q, |cand|) and the whole selection compiles once per run.  Like
    the rebuild path, the target standardization is recomputed over the
    real + fantasy observations at every pick (``gp.condition`` restamps
    y_mean/y_std per rebuild; this must match to reproduce its picks).

    Args:
      state: posterior from :func:`fit` (padded or not).
      cand:  [M, d] float32 candidate pool (unit cube).
      y_raw: [m] float32 raw targets aligned with ``state.x``; entries at
             index ≥ n (pads) are ignored.
      n:     number of real observations (traced — growing n does not
             recompile).
      best_y: incumbent best raw target (the EI threshold and the liar).
      q:     batch width (static).
      fantasy: "liar" | "believer";  acquisition: "ei" | "ucb".

    Returns ``picks`` [q] int32 — indices into ``cand``, identical to the
    legacy per-pick rebuild loop on the same inputs.
    """
    m, d_dim = state.x.shape
    M = cand.shape[0]
    S = q - 1                               # fantasy slots
    T = m + S
    ls = jnp.exp(state.params.log_lengthscale)
    sv = jnp.exp(state.params.log_signal_var)
    nv = jnp.exp(state.params.log_noise_var)
    kfn = KERNELS[kind]
    cand = cand.astype(jnp.float32)
    y_raw = y_raw.astype(jnp.float32)
    best_y = jnp.asarray(best_y, jnp.float32)

    # the one O(M·m·d) pass over the whole candidate pool (LHS + local
    # ball + axis sweeps fused): cross-Gram against the training block —
    # the Pallas tile kernel's natural shape
    if use_pallas and kind == "matern52":
        from repro.kernels.gp_gram.ops import matern52_cross
        k_cx = matern52_cross(cand, state.x, ls, sv)        # [M, m]
    else:
        k_cx = kfn(cand, state.x, ls, sv)                   # [M, m]

    # fixed-shape working set; inactive fantasy rows are identity rows of
    # L with zeroed cross entries, so prefix arithmetic is exact
    chol0 = jnp.zeros((T, T), jnp.float32)
    chol0 = chol0.at[:m, :m].set(state.chol)
    if S:
        fdiag = jnp.arange(m, T)
        chol0 = chol0.at[fdiag, fdiag].set(1.0)
    real = jnp.arange(m) < n                # real rows of the padded state
    noise_ss = _jitter(nv, sv)              # _build's diagonal, exactly

    # forward-substitution state, computed ONCE against the fitted block
    # and grown one row per pick.  Appending a Cholesky row leaves every
    # existing forward-solve entry untouched, so the O(n²·M) candidate
    # solve is paid once — each scan step only appends its own row:
    #   V [T, M] = L⁻¹ Kᵀ(X, cand)      (posterior-variance vectors)
    #   a [T]    = L⁻¹ (masked raw y)    (mean numerator, raw scale)
    #   b [T]    = L⁻¹ (active mask)     (mean's standardization shift)
    # mean_s = Vᵀ(a − μ·b)/σ exactly reproduces kq @ K⁻¹ys: ys is linear
    # in the raw targets and the active-row indicator, and the per-pick
    # re-standardization (μ, σ over real+fantasy targets — what the
    # rebuild path's _prepare recomputes every condition call) only mixes
    # those two solved vectors.
    y_masked = jnp.where(real, y_raw, 0.0)
    v0 = jnp.zeros((T, M), jnp.float32)
    v0 = v0.at[:m, :].set(jax.scipy.linalg.solve_triangular(
        state.chol, k_cx.T, lower=True))
    a0 = jnp.zeros((T,), jnp.float32)
    a0 = a0.at[:m].set(jax.scipy.linalg.solve_triangular(
        state.chol, y_masked, lower=True))
    b0 = jnp.zeros((T,), jnp.float32)
    b0 = b0.at[:m].set(jax.scipy.linalg.solve_triangular(
        state.chol, real.astype(jnp.float32), lower=True))

    carry0 = (
        chol0, v0, a0, b0,
        jnp.zeros((S,), jnp.float32),       # fantasy raw targets
        jnp.zeros((S, d_dim), jnp.float32),  # fantasy inputs
        jnp.zeros((M,), bool),              # taken mask
    )

    def step(carry, j):
        chol, v, a, b, y_f, x_f, taken = carry
        active = jnp.arange(S) < j if S else jnp.zeros((0,), bool)
        # per-pick re-standardization over real + fantasy targets
        w = jnp.concatenate([real, active]).astype(jnp.float32)
        yr = jnp.concatenate([y_masked, jnp.where(active, y_f, 0.0)])
        cnt = jnp.sum(w)
        mu_y = jnp.sum(yr) / cnt            # masked entries are zero
        std_y = jnp.sqrt(jnp.sum(w * (yr - mu_y) ** 2) / cnt)
        std_y = jnp.where(std_y < 1e-12, 1.0, std_y)

        mean_s = (v.T @ (a - mu_y * b)) / std_y
        var_s = jnp.maximum(sv - jnp.sum(v * v, axis=0), 1e-12)
        mean = mean_s * std_y + mu_y
        std = jnp.sqrt(var_s) * std_y

        if acquisition == "ei":
            std_c = jnp.maximum(std, 1e-9)
            imp = best_y - xi - mean
            z = imp / std_c
            cdf = 0.5 * (1 + jax.scipy.special.erf(z / math.sqrt(2)))
            pdf = jnp.exp(-0.5 * z * z) / math.sqrt(2 * math.pi)
            acq = imp * cdf + std_c * pdf
        else:                               # ucb (minimization, negated)
            acq = -(mean - 2.0 * std)
        acq = jnp.where(taken, -jnp.inf, acq)
        i = jnp.argmax(acq)
        taken = taken.at[i].set(True)

        if S:                               # fantasy-append (skipped q=1)
            x_new = cand[i]
            lie = mean[i] if fantasy == "believer" else best_y
            k_f_new = jnp.where(active, kfn(x_new[None], x_f, ls, sv)[0],
                                0.0)
            k_vec = jnp.concatenate([k_cx[i], k_f_new])
            l, dg = chol_append(chol, k_vec, sv + noise_ss)
            slot = jnp.minimum(j, S - 1)
            row = m + slot
            grow = j < S                    # the last pick appends nothing
            chol = jnp.where(grow, chol.at[row, :].set(l.at[row].set(dg)),
                             chol)
            # grow the forward-substitution state by the appended row
            col_c = kfn(cand, x_new[None], ls, sv)[:, 0]
            v = jnp.where(grow, v.at[row, :].set((col_c - l @ v) / dg), v)
            a = jnp.where(grow, a.at[row].set((lie - l @ a) / dg), a)
            b = jnp.where(grow, b.at[row].set((1.0 - l @ b) / dg), b)
            y_f = jnp.where(grow, y_f.at[slot].set(lie), y_f)
            x_f = jnp.where(grow, x_f.at[slot, :].set(x_new), x_f)
        return (chol, v, a, b, y_f, x_f, taken), i

    _, picks = jax.lax.scan(step, carry0, jnp.arange(q))
    return picks


# ---------------------------------------------------------------------------
# sharded q-batch selection (multi-device candidate pool)
# ---------------------------------------------------------------------------

_INT32_MAX = np.iinfo(np.int32).max


def _select_scan_sharded(state: GPState, cand_l, taken0_l, y_raw, n, best_y,
                         xi, *, q: int, kind: str, fantasy: str,
                         acquisition: str, use_pallas: bool,
                         axis: str = "pool"):
    """Shard-local body of :func:`select_batch_sharded`.

    Runs under ``shard_map``/``pmap`` with ``cand_l`` [Ml, d] the local
    shard of the pool and everything else replicated.  Mirrors
    :func:`select_batch` step for step; the only cross-device traffic per
    pick is the argmax reduction (one pmax + one pmin) and three masked
    psum gathers of the winner's row — O(m + d) floats, independent of
    pool size.

    Bit-exactness contract: the replicated carry (chol/a/b/fantasy
    block) sees exactly the arithmetic of the single-device path, and the
    per-candidate columns (v, mean, acq) are computed per shard with the
    same per-column ops.  The collective argmax reproduces jnp.argmax's
    first-occurrence tie-break: take the max acquisition via ``pmax``,
    then the *smallest global index* attaining it via ``pmin`` (losing
    shards contribute int32-max).  Exactly one shard owns the winner, so
    each masked psum adds the winner's row to zeros — no rounding.
    """
    m, d_dim = state.x.shape
    Ml = cand_l.shape[0]
    S = q - 1
    T = m + S
    ls = jnp.exp(state.params.log_lengthscale)
    sv = jnp.exp(state.params.log_signal_var)
    nv = jnp.exp(state.params.log_noise_var)
    kfn = KERNELS[kind]
    cand_l = cand_l.astype(jnp.float32)
    y_raw = y_raw.astype(jnp.float32)
    best_y = jnp.asarray(best_y, jnp.float32)
    idx0 = jax.lax.axis_index(axis).astype(jnp.int32) * Ml

    if use_pallas and kind == "matern52":
        from repro.kernels.gp_gram.ops import matern52_cross
        k_cx = matern52_cross(cand_l, state.x, ls, sv)      # [Ml, m]
    else:
        k_cx = kfn(cand_l, state.x, ls, sv)                 # [Ml, m]

    chol0 = jnp.zeros((T, T), jnp.float32)
    chol0 = chol0.at[:m, :m].set(state.chol)
    if S:
        fdiag = jnp.arange(m, T)
        chol0 = chol0.at[fdiag, fdiag].set(1.0)
    real = jnp.arange(m) < n
    noise_ss = _jitter(nv, sv)

    y_masked = jnp.where(real, y_raw, 0.0)
    v0 = jnp.zeros((T, Ml), jnp.float32)
    v0 = v0.at[:m, :].set(jax.scipy.linalg.solve_triangular(
        state.chol, k_cx.T, lower=True))
    a0 = jnp.zeros((T,), jnp.float32)
    a0 = a0.at[:m].set(jax.scipy.linalg.solve_triangular(
        state.chol, y_masked, lower=True))
    b0 = jnp.zeros((T,), jnp.float32)
    b0 = b0.at[:m].set(jax.scipy.linalg.solve_triangular(
        state.chol, real.astype(jnp.float32), lower=True))

    carry0 = (
        chol0, v0, a0, b0,
        jnp.zeros((S,), jnp.float32),
        jnp.zeros((S, d_dim), jnp.float32),
        taken0_l,                           # pool pads pre-marked taken
    )

    def step(carry, j):
        chol, v, a, b, y_f, x_f, taken = carry
        active = jnp.arange(S) < j if S else jnp.zeros((0,), bool)
        w = jnp.concatenate([real, active]).astype(jnp.float32)
        yr = jnp.concatenate([y_masked, jnp.where(active, y_f, 0.0)])
        cnt = jnp.sum(w)
        mu_y = jnp.sum(yr) / cnt
        std_y = jnp.sqrt(jnp.sum(w * (yr - mu_y) ** 2) / cnt)
        std_y = jnp.where(std_y < 1e-12, 1.0, std_y)

        mean_s = (v.T @ (a - mu_y * b)) / std_y             # [Ml]
        var_s = jnp.maximum(sv - jnp.sum(v * v, axis=0), 1e-12)
        mean = mean_s * std_y + mu_y
        std = jnp.sqrt(var_s) * std_y

        if acquisition == "ei":
            std_c = jnp.maximum(std, 1e-9)
            imp = best_y - xi - mean
            z = imp / std_c
            cdf = 0.5 * (1 + jax.scipy.special.erf(z / math.sqrt(2)))
            pdf = jnp.exp(-0.5 * z * z) / math.sqrt(2 * math.pi)
            acq = imp * cdf + std_c * pdf
        else:
            acq = -(mean - 2.0 * std)
        acq = jnp.where(taken, -jnp.inf, acq)

        # collective first-occurrence argmax over the global pool
        li = jnp.argmax(acq).astype(jnp.int32)
        lmax = acq[li]
        gmax = jax.lax.pmax(lmax, axis)
        gi = jax.lax.pmin(
            jnp.where(lmax == gmax, idx0 + li, _INT32_MAX), axis)
        off = gi - idx0
        has = (off >= 0) & (off < Ml)       # this shard owns the winner
        il = jnp.clip(off, 0, Ml - 1)
        taken = jnp.where(has, taken.at[il].set(True), taken)

        # replicate the winner's row: exactly one shard contributes
        x_new = jax.lax.psum(
            jnp.where(has, cand_l[il], jnp.zeros((d_dim,), jnp.float32)),
            axis)
        k_ci = jax.lax.psum(
            jnp.where(has, k_cx[il], jnp.zeros((m,), jnp.float32)), axis)
        mean_i = jax.lax.psum(jnp.where(has, mean[il], 0.0), axis)

        if S:
            lie = mean_i if fantasy == "believer" else best_y
            k_f_new = jnp.where(active, kfn(x_new[None], x_f, ls, sv)[0],
                                0.0)
            k_vec = jnp.concatenate([k_ci, k_f_new])
            l, dg = chol_append(chol, k_vec, sv + noise_ss)
            slot = jnp.minimum(j, S - 1)
            row = m + slot
            grow = j < S
            chol = jnp.where(grow, chol.at[row, :].set(l.at[row].set(dg)),
                             chol)
            col_c = kfn(cand_l, x_new[None], ls, sv)[:, 0]
            v = jnp.where(grow, v.at[row, :].set((col_c - l @ v) / dg), v)
            a = jnp.where(grow, a.at[row].set((lie - l @ a) / dg), a)
            b = jnp.where(grow, b.at[row].set((1.0 - l @ b) / dg), b)
            y_f = jnp.where(grow, y_f.at[slot].set(lie), y_f)
            x_f = jnp.where(grow, x_f.at[slot, :].set(x_new), x_f)
        return (chol, v, a, b, y_f, x_f, taken), gi

    _, picks = jax.lax.scan(step, carry0, jnp.arange(q))
    return picks


# compiled sharded selectors, keyed by (devices, q, kind, fantasy,
# acquisition, use_pallas, use_shard_map) — shapes retrace under jit/pmap
_SHARDED_CACHE: dict = {}


def _sharded_fn(devs, q, kind, fantasy, acquisition, use_pallas,
                use_shard_map):
    key = (devs, q, kind, fantasy, acquisition, use_pallas, use_shard_map)
    fn = _SHARDED_CACHE.get(key)
    if fn is not None:
        return fn
    body = partial(_select_scan_sharded, q=q, kind=kind, fantasy=fantasy,
                   acquisition=acquisition, use_pallas=use_pallas)
    if use_shard_map:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        mesh = Mesh(np.array(devs), ("pool",))
        fn = jax.jit(shard_map(
            body, mesh=mesh,
            in_specs=(P(), P("pool"), P("pool"), P(), P(), P(), P()),
            out_specs=P(), check_rep=False))
    else:
        fn = jax.pmap(body, axis_name="pool",
                      in_axes=(None, 0, 0, None, None, None, None),
                      devices=list(devs))
    _SHARDED_CACHE[key] = fn
    return fn


def select_batch_sharded(state: GPState, cand, y_raw, n, best_y, q: int,
                         kind: str = "matern52", fantasy: str = "liar",
                         acquisition: str = "ei", xi: float = 0.01,
                         use_pallas: bool = False, devices=None,
                         use_shard_map: Optional[bool] = None):
    """:func:`select_batch` with the candidate pool sharded over devices.

    The pool (LHS + local ball + axis sweeps, [M, d]) is split row-wise
    across ``devices`` (default: all host devices); each device scores
    its shard against the replicated posterior and a masked all-reduce
    argmax picks every winner.  Per-pick traffic is O(m + d) — constant
    in pool size — so M can grow with ``jax.device_count()`` at constant
    wall-clock.

    Picks are bit-identical to :func:`select_batch` on the same pool (see
    :func:`_select_scan_sharded` for the tie-break argument).  The pool
    is padded to a multiple of the device count with unit-cube midpoints
    pre-marked taken, so padding never changes a pick.

    ``use_shard_map`` selects the mesh entry point: ``shard_map`` (the
    mesh-native path, default off-CPU) or ``pmap`` (the CPU-host
    fallback, where forced host devices lack a true mesh runtime).
    """
    devs = tuple(devices) if devices is not None else tuple(jax.devices())
    nd = len(devs)
    if use_shard_map is None:
        use_shard_map = devs[0].platform != "cpu"
    cand = jnp.asarray(cand, jnp.float32)
    M, d = cand.shape
    Ml = -(-M // nd)
    Mp = Ml * nd
    if Mp > M:
        cand = jnp.concatenate(
            [cand, jnp.full((Mp - M, d), 0.5, jnp.float32)])
    taken0 = jnp.arange(Mp) >= M
    fn = _sharded_fn(devs, q, kind, fantasy, acquisition, bool(use_pallas),
                     bool(use_shard_map))
    y_raw = jnp.asarray(y_raw, jnp.float32)
    n = jnp.asarray(n, jnp.int32)
    best_y = jnp.asarray(best_y, jnp.float32)
    xi = jnp.asarray(xi, jnp.float32)
    if use_shard_map:
        return fn(state, cand, taken0, y_raw, n, best_y, xi)
    picks = fn(state, cand.reshape(nd, Ml, d), taken0.reshape(nd, Ml),
               y_raw, n, best_y, xi)
    return picks[0]

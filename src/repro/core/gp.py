"""Gaussian-process regression for noisy black-box objectives (paper §3.4).

The paper's argument for BO-with-GP is noise tolerance: the GP's noise
hyperparameter lets it approximate the objective *through* noise-corrupted
observations.  Implementation:

* Matérn-5/2 (default) and RBF kernels over the unit cube;
* exact GP with Cholesky solves (≤ a few hundred points — the paper's
  regime, where each point costs a cluster benchmark);
* hyperparameters (lengthscale per-dim or shared, signal var, noise var)
  fit by maximizing the log marginal likelihood with Adam on log-params,
  jit-compiled end to end;
* the Gram matrix hot spot is a Pallas TPU kernel
  (kernels/gp_gram) with a jnp fallback — on a fleet the tuner itself may
  run on an accelerator host, and the Gram matrix is its only O(n²·d) op.
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

SQRT5 = math.sqrt(5.0)


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

def _sqdist(xa, xb, inv_ls):
    """Scaled squared distance: xa [n,d], xb [m,d], inv_ls [d] -> [n,m]."""
    a = xa * inv_ls
    b = xb * inv_ls
    a2 = jnp.sum(a * a, axis=1)[:, None]
    b2 = jnp.sum(b * b, axis=1)[None, :]
    return jnp.maximum(a2 + b2 - 2.0 * (a @ b.T), 0.0)


def matern52(xa, xb, lengthscale, signal_var):
    """Matérn-5/2: smooth enough for GP-BO, rougher than RBF (default).

    The sqrt is guarded with the double-``where`` trick: d/dr sqrt(r)|₀ is
    ∞, and zero distances (diagonal) would otherwise poison the
    marginal-likelihood gradients with NaN.
    """
    inv_ls = 1.0 / lengthscale
    d2 = _sqdist(xa, xb, inv_ls)
    safe = jnp.where(d2 > 1e-12, d2, 1.0)
    r = jnp.where(d2 > 1e-12, jnp.sqrt(safe), 0.0)
    s = SQRT5 * r
    return signal_var * (1.0 + s + s * s / 3.0) * jnp.exp(-s)


def rbf(xa, xb, lengthscale, signal_var):
    inv_ls = 1.0 / lengthscale
    return signal_var * jnp.exp(-0.5 * _sqdist(xa, xb, inv_ls))


KERNELS = {"matern52": matern52, "rbf": rbf}


def gram(kind: str, x, lengthscale, signal_var, *, use_pallas: bool = False):
    """Kernel Gram matrix; optionally via the Pallas tile kernel."""
    if use_pallas and kind == "matern52":
        from repro.kernels.gp_gram.ops import matern52_gram
        return matern52_gram(x, lengthscale, signal_var)
    return KERNELS[kind](x, x, lengthscale, signal_var)


# ---------------------------------------------------------------------------
# GP posterior
# ---------------------------------------------------------------------------

class GPParams(NamedTuple):
    log_lengthscale: jnp.ndarray   # [d] (ARD)
    log_signal_var: jnp.ndarray    # []
    log_noise_var: jnp.ndarray     # []


class GPState(NamedTuple):
    params: GPParams
    x: jnp.ndarray                 # [n, d] training inputs (unit cube)
    y: jnp.ndarray                 # [n] standardized targets
    chol: jnp.ndarray              # [n, n] cholesky of K + σ²I
    alpha: jnp.ndarray             # [n] K⁻¹ y
    y_mean: jnp.ndarray
    y_std: jnp.ndarray


def init_params(d: int, lengthscale: float = 0.3, signal: float = 1.0,
                noise: float = 1e-2) -> GPParams:
    return GPParams(
        log_lengthscale=jnp.full((d,), math.log(lengthscale), jnp.float32),
        log_signal_var=jnp.asarray(math.log(signal), jnp.float32),
        log_noise_var=jnp.asarray(math.log(noise), jnp.float32),
    )


PAD_NOISE = 1e6   # pseudo-point noise: pads contribute ~nothing to the fit


def _build(params: GPParams, x, y, kind: str, extra_noise=None):
    ls = jnp.exp(params.log_lengthscale)
    sv = jnp.exp(params.log_signal_var)
    nv = jnp.exp(params.log_noise_var)
    k = KERNELS[kind](x, x, ls, sv)
    n = x.shape[0]
    # relative jitter: keeps the condition number f32-safe even when the
    # fitted signal variance is large / lengthscale long (K near rank-1)
    diag = jnp.full((n,), nv + 1e-4 * sv + 1e-6, k.dtype)
    if extra_noise is not None:
        diag = diag + extra_noise
    kn = k + jnp.diag(diag)
    chol = jnp.linalg.cholesky(kn)
    alpha = jax.scipy.linalg.cho_solve((chol, True), y)
    return chol, alpha


# jitted entry for posterior (re)builds outside the Adam loop — the
# constant-liar fantasy update calls this once per batch pick
_build_jit = partial(jax.jit, static_argnames=("kind",))(_build)


def neg_log_marginal(params: GPParams, x, y, kind: str, extra_noise=None):
    chol, alpha = _build(params, x, y, kind, extra_noise)
    n = x.shape[0]
    return (0.5 * y @ alpha
            + jnp.sum(jnp.log(jnp.diagonal(chol)))
            + 0.5 * n * math.log(2 * math.pi))


@partial(jax.jit, static_argnames=("kind", "steps"))
def _fit(params: GPParams, x, y, kind: str, steps: int = 200,
         lr: float = 0.05, extra_noise=None):
    """Adam on log-hyperparameters maximizing the marginal likelihood."""
    grad_fn = jax.value_and_grad(
        lambda p: neg_log_marginal(p, x, y, kind, extra_noise))

    def step(carry, _):
        p, m, v, t = carry
        loss, g = grad_fn(p)
        g = jax.tree.map(lambda gi: jnp.nan_to_num(gi), g)  # NaN-proof step
        t = t + 1
        m = jax.tree.map(lambda mi, gi: 0.9 * mi + 0.1 * gi, m, g)
        v = jax.tree.map(lambda vi, gi: 0.999 * vi + 0.001 * gi * gi, v, g)
        mhat = jax.tree.map(lambda mi: mi / (1 - 0.9 ** t), m)
        vhat = jax.tree.map(lambda vi: vi / (1 - 0.999 ** t), v)
        p = jax.tree.map(lambda pi, mh, vh: pi - lr * mh / (jnp.sqrt(vh) + 1e-8),
                         p, mhat, vhat)
        # clamp hyperparams to sane boxes (noise floor keeps Cholesky PSD)
        p = GPParams(
            log_lengthscale=jnp.clip(p.log_lengthscale, math.log(1e-2), math.log(3.0)),
            log_signal_var=jnp.clip(p.log_signal_var, math.log(1e-2), math.log(1e2)),
            log_noise_var=jnp.clip(p.log_noise_var, math.log(1e-4), math.log(1.0)),
        )
        return (p, m, v, t), loss

    zeros = jax.tree.map(jnp.zeros_like, params)
    (p, _, _, _), losses = jax.lax.scan(
        step, (params, zeros, zeros, jnp.asarray(0, jnp.float32)),
        None, length=steps)
    return p, losses


def _bucket(n: int) -> int:
    """Pad count: next multiple of 16 — bounds jit recompiles to O(n/16)
    shapes instead of one per BO iteration."""
    return ((n + 15) // 16) * 16


def _prepare(x: np.ndarray, y: np.ndarray, pad: bool,
             pad_to: Optional[int] = None):
    """Standardize y and append huge-noise pseudo-points up to the target
    shape (``pad_to`` or the next bucket)."""
    x = np.asarray(x, np.float32)
    y_raw = np.asarray(y, np.float32)
    n, d = x.shape
    y_mean, y_std = float(y_raw.mean()), float(y_raw.std())
    if y_std < 1e-12:
        y_std = 1.0
    ys = (y_raw - y_mean) / y_std
    extra = None
    if pad or pad_to:
        m = max(_bucket(n), pad_to or 0)
        if m > n:
            x = np.vstack([x, np.full((m - n, d), 0.5, np.float32)])
            ys = np.concatenate([ys, np.zeros(m - n, np.float32)])
            extra = np.zeros(m, np.float32)
            extra[n:] = PAD_NOISE
    xj = jnp.asarray(x)
    yj = jnp.asarray(ys)
    ej = None if extra is None else jnp.asarray(extra)
    return xj, yj, ej, y_mean, y_std


def fit(x: np.ndarray, y: np.ndarray, kind: str = "matern52",
        steps: int = 200, params: Optional[GPParams] = None,
        pad: bool = True, pad_to: Optional[int] = None) -> GPState:
    """Standardize y, fit hyperparameters, build the posterior.

    ``pad`` appends huge-noise pseudo-points up to a shape bucket so the
    jit caches of ``_fit``/``predict`` are reused across BO iterations
    (the pads' posterior influence is ~1/PAD_NOISE — negligible).
    ``pad_to`` pins the padded size outright: a BO run that knows its
    total budget compiles each jit exactly once instead of once per
    16-point growth bucket.

    ``params`` warm-starts the hyperparameter optimization (e.g. from the
    previous BO round's posterior); with ``steps=0`` they are used as-is.
    """
    xj, yj, ej, y_mean, y_std = _prepare(x, y, pad, pad_to)
    if params is None:
        params = init_params(int(xj.shape[1]))
    if steps > 0:
        params, _ = _fit(params, xj, yj, kind, steps=steps, extra_noise=ej)
    chol, alpha = _build_jit(params, xj, yj, kind, ej)
    return GPState(params, xj, yj, chol, alpha,
                   jnp.asarray(y_mean), jnp.asarray(y_std))


def condition(params: GPParams, x: np.ndarray, y: np.ndarray,
              kind: str = "matern52", pad: bool = True,
              pad_to: Optional[int] = None) -> GPState:
    """Posterior for (x, y) under *fixed* hyperparameters — no
    marginal-likelihood refit.  This is the constant-liar fantasy update
    of q-batch acquisition: one Cholesky rebuild, no Adam."""
    return fit(x, y, kind, steps=0, params=params, pad=pad, pad_to=pad_to)


@partial(jax.jit, static_argnames=("kind",))
def predict(state: GPState, xq, kind: str = "matern52"):
    """Posterior mean/std at query points xq [m,d] (original y scale)."""
    ls = jnp.exp(state.params.log_lengthscale)
    sv = jnp.exp(state.params.log_signal_var)
    kq = KERNELS[kind](xq, state.x, ls, sv)          # [m, n]
    mean_s = kq @ state.alpha
    v = jax.scipy.linalg.solve_triangular(state.chol, kq.T, lower=True)
    var_s = jnp.maximum(sv - jnp.sum(v * v, axis=0), 1e-12)
    mean = mean_s * state.y_std + state.y_mean
    std = jnp.sqrt(var_s) * state.y_std
    return mean, std


def expected_improvement(state: GPState, xq, best_y: float,
                         kind: str = "matern52", xi: float = 0.01):
    """EI for *minimization* of y (y = step time / negative bandwidth)."""
    mean, std = predict(state, xq, kind)
    std = jnp.maximum(std, 1e-9)
    imp = best_y - xi - mean
    z = imp / std
    cdf = 0.5 * (1 + jax.scipy.special.erf(z / math.sqrt(2)))
    pdf = jnp.exp(-0.5 * z * z) / math.sqrt(2 * math.pi)
    return imp * cdf + std * pdf


def ucb(state: GPState, xq, kind: str = "matern52", beta: float = 2.0):
    """Lower-confidence bound for minimization (returns negated for argmax)."""
    mean, std = predict(state, xq, kind)
    return -(mean - beta * std)

"""The raw TPU-fleet knob space SAPPHIRE tunes (DESIGN.md §5).

Mirrors the structure of Ceph's 1536-knob space at framework scale
(~380 knobs here):

* ~40 performance knobs that the step function / cost model actually read
  (mapped 1:1 onto :class:`repro.runconfig.RunConfig`);
* module-selector knobs (C3) gating implementation-specific sub-knobs, the
  ``osd_objectstore`` analogue (``attention_impl`` gates flash block sizes,
  ``remat_policy`` gates granularity, ``optimizer`` gates betas…);
* C4 interdependencies (VMEM product budget for flash tiles; HBM fraction
  sum; microbatch divides the per-replica batch);
* a large family of **inert** knobs (telemetry, logging, debug — Ceph's
  ``debug_*`` analogue) that the ranking phase must discover to be
  irrelevant — they are generated programmatically per subsystem;
* **unconfigurable** C1 knobs (ids, addresses, topology facts) that the
  washing stage must remove.

``build_raw_space(cfg, cell, mesh)`` returns the *raw* space;
``clean_space(...)`` runs the §3.2 resolver and returns the tuned domain.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.core import constraints as cres
from repro.core.space import Divides, Knob, ProductLeq, Space, SumLeq
from repro.models.config import ModelConfig, ShapeCell
from repro.core.costmodel import MeshShape, V5E


def _perf_knobs(cfg: ModelConfig, cell: ShapeCell, mesh: MeshShape) -> List[Knob]:
    per_replica = max(cell.global_batch // max(mesh.dp, 1), 1)
    ks: List[Knob] = [
        # ---- distribution layout (module selectors, C3 parents) ----
        Knob("fsdp_shard_params", "bool", True, module="parallel",
             description="ZeRO-3 shard params/grads/opt state over DP"),
        Knob("tensor_parallel", "bool", True, module="parallel",
             description="Megatron TP over the model mesh axis"),
        Knob("sequence_parallel", "bool", False, module="parallel",
             gated_by=("tensor_parallel", (True,)),
             description="shard activation seq on the model axis"),
        Knob("pod_in_batch", "bool", True, module="parallel",
             description="multi-pod: pod axis joins data parallelism"),
        Knob("shard_kv_seq", "bool", False, module="serving",
             description="flash-decode style KV-seq sharding"),

        # ---- step structure ----
        Knob("microbatch", "int", 1, lo=1, hi=per_replica,
             module="step",
             description="grad-accum microbatch (divides per-replica batch); "
                         "default 1 is the conservative small-machine value"),
        Knob("remat_policy", "categorical", "none",
             choices=("none", "dots", "block", "full"), module="step",
             description="activation checkpointing policy"),
        Knob("grad_accum_unroll", "bool", False, module="step"),

        # ---- attention module selection + gated sub-knobs ----
        Knob("attention_impl", "categorical", "reference",
             choices=("reference", "chunked", "flash"), module="attention",
             description="attention backend (osd_objectstore analogue)"),
        Knob("flash_block_q", "int", 512, lo=128, hi=2048, align=128,
             dynamic_bound=True, gated_by=("attention_impl", ("flash",)),
             module="attention", description="flash q-tile rows"),
        Knob("flash_block_k", "int", 512, lo=128, hi=2048, align=128,
             dynamic_bound=True, gated_by=("attention_impl", ("flash",)),
             module="attention", description="flash k-tile cols"),
        Knob("chunk_size_k", "int", 2048, lo=256, hi=16384, align=256,
             log_scale=True, gated_by=("attention_impl", ("chunked",)),
             module="attention"),

        # ---- numerics ----
        Knob("matmul_precision", "categorical", "default",
             choices=("default", "high", "highest"), module="numerics"),
        Knob("grad_allreduce_dtype", "categorical", "float32",
             choices=("float32", "bfloat16"), module="numerics",
             description="gradient compression for the DP reduction"),
        Knob("tp_reduce_dtype", "categorical", "float32",
             choices=("float32", "bfloat16"), module="numerics",
             gated_by=("tensor_parallel", (True,)),
             description="TP partial-sum reduction dtype (halves AR bytes)"),
        Knob("master_weights_f32", "bool", True, module="numerics"),

        # ---- collectives ----
        Knob("allreduce_per_microbatch", "bool", False, module="collective",
             description="issue grad reduction per microbatch (overlap)"),
        Knob("pod_hierarchical_allreduce", "bool", True, module="collective"),
        Knob("ici_collective_chunk_kb", "int", 1024, lo=64, hi=16384,
             log_scale=True, dynamic_bound=True, module="collective"),

        # ---- memory economy (C4 sum, the bluestore-cache-ratio analogue) ----
        Knob("act_hbm_frac", "float", 0.5, lo=0.05, hi=0.9, module="memory"),
        Knob("kvcache_hbm_frac", "float", 0.3, lo=0.05, hi=0.9, module="memory"),

        # ---- optimizer module + gated hyperparams (perf-inert, quality-live) --
        Knob("optimizer", "categorical", "adamw",
             choices=("adamw", "adafactor"), module="optimizer"),
        Knob("learning_rate", "float", 3e-4, lo=1e-5, hi=1e-2, log_scale=True,
             module="optimizer", inert=True),
        Knob("weight_decay", "float", 0.1, lo=0.0, hi=0.5, module="optimizer",
             inert=True),
        Knob("beta1", "float", 0.9, lo=0.5, hi=0.99, module="optimizer",
             gated_by=("optimizer", ("adamw",)), inert=True),
        Knob("beta2", "float", 0.95, lo=0.9, hi=0.999, module="optimizer",
             gated_by=("optimizer", ("adamw",)), inert=True),
        Knob("grad_clip_norm", "float", 1.0, lo=0.1, hi=10.0, log_scale=True,
             module="optimizer", inert=True),
    ]

    if cfg.has_moe:
        ks += [
            Knob("expert_parallel", "bool", True, module="moe"),
            Knob("moe_impl", "categorical", "dense",
                 choices=("dense", "dropping"), module="moe"),
            Knob("moe_capacity_factor", "float", 1.25, lo=1.0, hi=2.5,
                 gated_by=("moe_impl", ("dropping",)), module="moe"),
        ]
    if any(s.kind in ("mamba",) for s in cfg.pattern):
        ks.append(Knob("ssm_chunk", "int", 256, lo=64, hi=2048, align=64,
                       log_scale=True, dynamic_bound=True, module="ssm"))
    if any(s.kind in ("mlstm", "slstm") for s in cfg.pattern):
        ks.append(Knob("mlstm_chunk", "int", 256, lo=64, hi=2048, align=64,
                       log_scale=True, dynamic_bound=True, module="ssm"))
    if cell.mode in ("prefill", "decode"):
        ks += [
            Knob("kv_cache_dtype", "categorical", "bfloat16",
                 choices=("bfloat16", "int8"), module="serving"),
            Knob("kv_layout", "categorical", "bshd", choices=("bshd", "bhsd"),
                 module="serving"),
            Knob("prefill_chunk", "int", 0, lo=0, hi=8192, align=512,
                 module="serving"),
            Knob("decode_batch_tile", "int", 0, lo=0, hi=256, align=8,
                 module="serving"),
        ]
    return ks


_INERT_SUBSYSTEMS = (
    "rpc", "telemetry", "dataloader", "checkpoint", "scheduler", "compiler",
    "memory_tracker", "profiler", "logging", "metrics", "watchdog", "tracing",
    "health", "discovery", "manifest", "registry", "eviction", "gc",
    "heartbeat", "lease",
)

_INERT_TEMPLATES = (
    # (suffix, kind, default, lo, hi, log)
    ("debug_level", "int", 1, 0, 20, False),
    ("trace_every_steps", "int", 100, 1, 100000, True),
    ("buffer_kb", "int", 256, 16, 65536, True),
    ("history_len", "int", 64, 1, 4096, True),
    ("sample_rate", "float", 0.01, 0.0, 1.0, False),
    ("timeout_ms", "int", 5000, 100, 600000, True),
    ("retry_limit", "int", 3, 0, 64, False),
    ("flush_interval_s", "float", 30.0, 0.1, 3600.0, True),
    ("max_inflight", "int", 8, 1, 1024, True),
    ("verbose", "bool", False, None, None, False),
    ("compress_logs", "bool", True, None, None, False),
    ("export_format", "categorical", "proto", None, None, False),
    ("shard_hint", "int", 0, 0, 512, False),
    ("queue_depth", "int", 32, 1, 4096, True),
    ("batch_emit", "bool", True, None, None, False),
)


def _inert_knobs() -> List[Knob]:
    """Ceph's debug_* family analogue: 20 subsystems × 15 knobs = 300."""
    ks: List[Knob] = []
    for sub in _INERT_SUBSYSTEMS:
        for suffix, kind, default, lo, hi, log in _INERT_TEMPLATES:
            name = f"{sub}_{suffix}"
            if kind == "bool":
                ks.append(Knob(name, "bool", default, module=sub, inert=True,
                               restart_required=False))
            elif kind == "categorical":
                ks.append(Knob(name, "categorical", "proto",
                               choices=("proto", "json", "csv"),
                               module=sub, inert=True, restart_required=False))
            else:
                ks.append(Knob(name, kind, default, lo=lo, hi=hi,
                               log_scale=log and lo and lo > 0, module=sub,
                               inert=True, restart_required=False))
    return ks


def _unconfigurable_knobs(cfg: ModelConfig, mesh: MeshShape) -> List[Knob]:
    """C1: facts the washing stage must strip (ids, topology, model dims)."""
    fixed = [
        ("job_id", 0), ("host_rank", 0), ("coordinator_port", 8476),
        ("mesh_data_axis", mesh.data), ("mesh_model_axis", mesh.model),
        ("mesh_pod_axis", mesh.pod), ("n_layers", cfg.n_layers),
        ("d_model", cfg.d_model), ("n_heads", cfg.n_heads),
        ("vocab_size", cfg.vocab_size), ("device_generation", 5),
        ("slice_id", 0), ("worker_id", 0), ("dcn_topology_id", 1),
        ("hbm_gib", 16), ("ici_links", 6), ("runtime_version", 2),
        ("checkpoint_dir_inode", 0), ("rng_fold_in", 0), ("build_hash", 0),
    ]
    return [Knob(n, "int", int(v), lo=int(v), hi=max(int(v), int(v) + 1),
                 configurable=False, module="topology") for n, v in fixed]


def build_raw_space(cfg: ModelConfig, cell: ShapeCell,
                    mesh: MeshShape) -> Space:
    per_replica = max(cell.global_batch // max(mesh.dp, 1), 1)
    knobs = _perf_knobs(cfg, cell, mesh) + _inert_knobs() \
        + _unconfigurable_knobs(cfg, mesh)
    cons = [
        Divides(("microbatch",), target=per_replica),
        SumLeq(("act_hbm_frac", "kvcache_hbm_frac"), limit=0.9),
        ProductLeq(("flash_block_q", "flash_block_k"),
                   limit=V5E.vmem_bytes / 8),   # f32 score tile budget
    ]
    return Space(tuple(knobs), tuple(cons))


def clean_space(cfg: ModelConfig, cell: ShapeCell, mesh: MeshShape,
                pinned: Optional[Dict[str, object]] = None):
    """Raw space -> §3.2-resolved clean domain (+ pins + stage report)."""
    raw = build_raw_space(cfg, cell, mesh)
    return cres.resolve(raw, pinned)

"""Lasso regression via cyclic coordinate descent, in JAX (paper §3.3).

The paper selects important knobs with L1-penalized least squares; we
implement it from scratch (no sklearn in this container):

* ``lasso_fit``   — coordinate descent for one λ (soft-thresholding),
  jit-compiled; warm-startable.
* ``lasso_path``  — geometric λ grid from λ_max (all-zero solution) down,
  warm-started — the standard pathwise algorithm (Friedman et al.).
* ``ridge_fit``   — closed-form L2 baseline (the paper's comparison: ridge
  can't zero out coefficients, so it can't *select*).

Features are standardized internally (zero mean / unit variance); returned
coefficients are on the standardized scale, which is exactly what the
importance ranking wants (comparable magnitudes across knobs).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class Standardized(NamedTuple):
    x: jnp.ndarray       # [n, d] standardized features
    y: jnp.ndarray       # [n] centered target
    x_mean: jnp.ndarray
    x_std: jnp.ndarray
    y_mean: jnp.ndarray


def standardize(x: jnp.ndarray, y: jnp.ndarray) -> Standardized:
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    xm = x.mean(axis=0)
    xs = x.std(axis=0)
    xs = jnp.where(xs < 1e-12, 1.0, xs)   # constant cols -> coef stays 0
    ym = y.mean()
    return Standardized((x - xm) / xs, y - ym, xm, xs, ym)


def lambda_max(std: Standardized) -> float:
    """Smallest λ with all-zero solution: max |xᵀy| / n."""
    n = std.x.shape[0]
    return float(jnp.max(jnp.abs(std.x.T @ std.y)) / n)


def _cd_impl(x, y, lam, beta0, max_iter: int = 500, tol: float = 1e-6):
    """Cyclic coordinate descent.  x standardized [n,d], y centered [n].
    Traceable core shared by the one-λ jit and the whole-path scan."""
    n, d = x.shape
    col_sq = jnp.sum(x * x, axis=0) / n            # ~1 after standardization

    def one_sweep(beta):
        def body(j, state):
            beta, r = state                        # r = y - x @ beta
            bj = beta[j]
            xj = x[:, j]
            rho = (xj @ r) / n + col_sq[j] * bj
            bj_new = jnp.sign(rho) * jnp.maximum(jnp.abs(rho) - lam, 0.0) \
                / jnp.maximum(col_sq[j], 1e-12)
            r = r + xj * (bj - bj_new)
            beta = beta.at[j].set(bj_new)
            return beta, r

        r = y - x @ beta
        beta_new, _ = jax.lax.fori_loop(0, d, body, (beta, r))
        return beta_new

    def cond(state):
        beta, beta_prev, it = state
        delta = jnp.max(jnp.abs(beta - beta_prev))
        return jnp.logical_and(it < max_iter, delta > tol)

    def step(state):
        beta, _, it = state
        return one_sweep(beta), beta, it + 1

    beta0 = jnp.asarray(beta0, jnp.float32)
    init = (one_sweep(beta0), beta0, jnp.asarray(1))
    beta, _, _ = jax.lax.while_loop(cond, step, init)
    return beta


_cd = partial(jax.jit, static_argnames=("max_iter",))(_cd_impl)


@partial(jax.jit, static_argnames=("max_iter",))
def _fista_path(x, y, lams, max_iter: int, tol: float = 1e-7):
    """Warm-started FISTA over the whole λ grid inside ONE jit.

    Works on the Gram matrix, so each inner iteration is a single [d,d]
    matvec — fully vectorized across features, unlike cyclic CD's
    inherently sequential per-column sweep (the ranking-phase hot spot:
    ~380 dummy-coded features × 50 λs).  Lasso is convex, so FISTA and CD
    converge to the same path up to tolerance; a lax.scan carries β down
    the grid (the standard pathwise warm start) in one dispatch.
    """
    n, d = x.shape
    g = x.T @ x / n                                 # [d, d] gram
    b = x.T @ y / n                                 # [d]
    # Lipschitz constant of ∇(½‖y−xβ‖²/n): the exact top eigenvalue (an
    # underestimate would make the gradient step overshoot and the whole
    # warm-started path diverge silently). One [d,d] eigh per path call
    # is cheap next to the λ-grid solve itself.
    lip = jnp.maximum(jnp.linalg.eigvalsh(g)[-1], 1e-6) * 1.01

    def soft(u, t):
        return jnp.sign(u) * jnp.maximum(jnp.abs(u) - t, 0.0)

    def per_lam(beta, lam):
        def cond(state):
            beta, _, _, prev, it = state
            return jnp.logical_and(it < max_iter,
                                   jnp.max(jnp.abs(beta - prev)) > tol)

        def step(state):
            beta, z, t, _, it = state
            beta_new = soft(z - (g @ z - b) / lip, lam / lip)
            t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
            z = beta_new + (t - 1.0) / t_new * (beta_new - beta)
            return beta_new, z, t_new, beta, it + 1

        init = step((beta, beta, jnp.asarray(1.0, jnp.float32),
                     beta + 2 * tol, jnp.asarray(0)))
        beta, _, _, _, _ = jax.lax.while_loop(cond, step, init)
        return beta, beta

    _, betas = jax.lax.scan(per_lam, jnp.zeros((d,), jnp.float32), lams)
    return betas


def lasso_fit(x, y, lam: float, beta0=None, max_iter: int = 500) -> np.ndarray:
    """Fit one λ; returns standardized-scale coefficients [d]."""
    std = standardize(x, y)
    d = std.x.shape[1]
    if beta0 is None:
        beta0 = jnp.zeros((d,), jnp.float32)
    beta = _cd(std.x, std.y, jnp.asarray(lam, jnp.float32), beta0,
               max_iter=max_iter)
    return np.asarray(beta)


def lasso_path(x, y, n_lambdas: int = 50, eps: float = 1e-3,
               max_iter: int = 300) -> Tuple[np.ndarray, np.ndarray]:
    """Pathwise CD over a geometric λ grid (warm starts).

    Returns (lambdas [L] descending, betas [L, d] standardized scale).
    """
    std = standardize(x, y)
    lmax = max(lambda_max(std), 1e-12)
    lams = np.geomspace(lmax, lmax * eps, n_lambdas)
    betas = _fista_path(std.x, std.y, jnp.asarray(lams, jnp.float32),
                        max_iter=max_iter)
    return lams, np.asarray(betas)


def ridge_fit(x, y, lam: float) -> np.ndarray:
    """Closed-form ridge (comparison baseline; cannot select features)."""
    std = standardize(x, y)
    n, d = std.x.shape
    a = std.x.T @ std.x / n + lam * jnp.eye(d, dtype=jnp.float32)
    b = std.x.T @ std.y / n
    return np.asarray(jnp.linalg.solve(a, b))


def path_importance(lams: np.ndarray, betas: np.ndarray) -> np.ndarray:
    """Per-feature importance from a lasso path.

    λ-weighted area under |β_j(λ)|: ∫ λ·|β_j(λ)| dlogλ.  Features that
    enter *early* (at large λ, where the L1 penalty only admits strong
    signals) dominate; spurious features that creep in at the small-λ
    overfitting tail get negligible weight.  More stable than |β| at one λ
    and consistent with entry-order ranking (paper Fig. 6's drastically
    dropping curve is this quantity, normalized).
    """
    logl = np.log(lams)
    w = np.abs(np.gradient(logl)) * lams   # λ·dlogλ weights
    return np.einsum("l,ld->d", w, np.abs(betas))

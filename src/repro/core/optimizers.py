"""Black-box optimizer baselines the paper compares against BO (§3.4).

.. deprecated::
    The algorithms now live in :mod:`repro.core.strategy` as ask/tell
    strategies (:class:`RandomStrategy`, :class:`AnnealingStrategy`,
    :class:`GeneticStrategy`) that never call an objective; these
    functions survive as thin synchronous drivers so existing callers
    keep working.  New code should drive a strategy through
    :meth:`repro.core.controller.Controller.run`.

* random_search       — sanity floor;
* simulated_annealing — memoryless Metropolis walk; the paper's critique is
  exactly that it "does not learn from the old experience";
* genetic_algorithm   — population evolution; the paper's critique is the
  measurement cost (a whole population per generation).

All share the objective interface of ``bo.minimize`` (lower is better) and
return a compatible trace, so the Fig.-style optimizer-comparison benchmark
plots them side by side under identical evaluation budgets and noise.
"""

from __future__ import annotations

import warnings
from typing import Callable, Optional, Tuple

from repro.core.space import Config, Space
from repro.core.strategy import (AnnealingStrategy, GAConfig,  # noqa: F401
                                 GeneticStrategy, RandomStrategy, SAConfig,
                                 SearchStrategy, Trace)

BOTrace = Trace     # legacy name


def _drive(strategy: SearchStrategy,
           f: Callable[[Config], float]) -> Tuple[Config, float, Trace]:
    """Synchronous closed loop: ask the strategy's preferred batch, score
    each config through ``f``, tell, repeat until the budget is told."""
    warnings.warn(
        f"optimizers.* wrappers are deprecated: drive the strategy through "
        f"the experiment loop instead — Controller(evaluator, EvalDB())"
        f".run(make_strategy(..., space, budget=...)) replaces this "
        f"{type(strategy).__name__} closed loop (Controller.run_async for "
        f"the overlapped version)",
        DeprecationWarning, stacklevel=3)
    while not strategy.finished:
        cfgs = strategy.ask()
        if not cfgs:
            break
        strategy.tell(cfgs, [float(f(c)) for c in cfgs])
    best_c, best_v = strategy.best()
    return best_c, best_v, strategy.trace


def random_search(f: Callable[[Config], float], space: Space, budget: int,
                  seed: int = 0) -> Tuple[Config, float, Trace]:
    return _drive(RandomStrategy(space, budget, seed=seed), f)


def simulated_annealing(f: Callable[[Config], float], space: Space,
                        budget: int, cfg: Optional[SAConfig] = None
                        ) -> Tuple[Config, float, Trace]:
    return _drive(AnnealingStrategy(space, budget, cfg), f)


def genetic_algorithm(f: Callable[[Config], float], space: Space,
                      budget: int, cfg: Optional[GAConfig] = None
                      ) -> Tuple[Config, float, Trace]:
    return _drive(GeneticStrategy(space, budget, cfg), f)

"""Black-box optimizer baselines the paper compares against BO (§3.4).

* RandomSearch        — sanity floor;
* SimulatedAnnealing  — memoryless Metropolis walk; the paper's critique is
  exactly that it "does not learn from the old experience";
* GeneticAlgorithm    — population evolution; the paper's critique is the
  measurement cost (a whole population per generation).

All share the objective interface of ``bo.minimize`` (lower is better) and
return a compatible trace, so the Fig.-style optimizer-comparison benchmark
plots them side by side under identical evaluation budgets and noise.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.core.bo import BOTrace
from repro.core.sampling import latin_hypercube, lhs_unit
from repro.core.space import Config, Space


def random_search(f: Callable[[Config], float], space: Space, budget: int,
                  seed: int = 0) -> Tuple[Config, float, BOTrace]:
    trace = BOTrace()
    for c in latin_hypercube(space, budget, seed=seed):
        v = float(f(c))
        trace.configs.append(c)
        trace.values.append(v)
        trace.best_values.append(min(trace.values))
    best_c, best_v = trace.best
    return best_c, best_v, trace


@dataclass
class SAConfig:
    t0: float = 1.0           # initial temperature (in units of objective std)
    cooling: float = 0.93     # geometric cooling per step
    sigma: float = 0.12       # proposal stddev in unit cube
    seed: int = 0


def simulated_annealing(f: Callable[[Config], float], space: Space,
                        budget: int, cfg: Optional[SAConfig] = None
                        ) -> Tuple[Config, float, BOTrace]:
    cfg = cfg or SAConfig()
    rng = np.random.default_rng(cfg.seed)
    trace = BOTrace()

    cur = space.project(space.default_config())
    cur_v = float(f(cur))
    trace.configs.append(cur)
    trace.values.append(cur_v)
    trace.best_values.append(cur_v)

    t = cfg.t0
    d = len(space)
    for _ in range(budget - 1):
        u = space.to_unit(cur)
        prop_u = np.clip(u + rng.normal(0, cfg.sigma, d), 0, 1)
        prop = space.from_unit(prop_u)
        v = float(f(prop))
        trace.configs.append(prop)
        trace.values.append(v)
        trace.best_values.append(min(trace.values))
        # Metropolis accept on the *current* state only (no history — the
        # paper's point about SA's unreliability under noise).
        scale = max(np.std(trace.values), 1e-9)
        if v < cur_v or rng.random() < np.exp(-(v - cur_v) / (t * scale)):
            cur, cur_v = prop, v
        t *= cfg.cooling
    best_c, best_v = trace.best
    return best_c, best_v, trace


@dataclass
class GAConfig:
    population: int = 8
    elite: int = 2
    tournament: int = 3
    crossover_p: float = 0.5
    mutation_sigma: float = 0.1
    mutation_p: float = 0.25
    seed: int = 0


def genetic_algorithm(f: Callable[[Config], float], space: Space,
                      budget: int, cfg: Optional[GAConfig] = None
                      ) -> Tuple[Config, float, BOTrace]:
    cfg = cfg or GAConfig()
    rng = np.random.default_rng(cfg.seed)
    trace = BOTrace()
    d = len(space)

    def eval_cfg(c: Config) -> float:
        v = float(f(c))
        trace.configs.append(c)
        trace.values.append(v)
        trace.best_values.append(min(trace.values))
        return v

    pop_u = lhs_unit(rng, cfg.population, d)
    pop = [space.from_unit(u) for u in pop_u]
    fit = [eval_cfg(c) for c in pop]

    while len(trace.values) < budget:
        order = np.argsort(fit)
        new_pop: List[Config] = [pop[i] for i in order[:cfg.elite]]
        while len(new_pop) < cfg.population:
            def pick():
                idx = rng.choice(len(pop), size=cfg.tournament, replace=False)
                return pop[min(idx, key=lambda i: fit[i])]
            a, b = space.to_unit(pick()), space.to_unit(pick())
            mask = rng.random(d) < cfg.crossover_p
            child = np.where(mask, a, b)
            mut = rng.random(d) < cfg.mutation_p
            child = np.clip(child + mut * rng.normal(0, cfg.mutation_sigma, d), 0, 1)
            new_pop.append(space.from_unit(child))
        pop = new_pop[:cfg.population]
        fit = []
        for c in pop:
            if len(trace.values) >= budget:
                fit.append(float("inf"))
                continue
            fit.append(eval_cfg(c))
    best_c, best_v = trace.best
    return best_c, best_v, trace

"""Parameter-importance ranking (paper §3.3).

Pipeline:  sample the clean domain (LHS, ~300 configs — the paper's budget)
  -> evaluate each on the test-cluster evaluator (noisy)
  -> preprocess:  categorical -> dummy variables;  numeric + target ->
     ``log1p`` (the paper's normalization: same order of magnitude,
     variance-stabilized)
  -> Lasso path -> per-feature importance (area under |β(λ)|)
  -> fold dummy groups back to their knob (max over group)
  -> rank, return the top-K sub-space.

The returned :class:`RankingResult` carries the full importance curve so
the Fig.-6 benchmark can plot the drastic drop-off.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core.evaluators import evaluate_many
from repro.core.lasso import lasso_path, path_importance
from repro.core.sampling import latin_hypercube
from repro.core.space import Config, Space

if TYPE_CHECKING:      # pragma: no cover - import cycle guard (controller
    from repro.core.controller import Controller      # imports evaluators)


# ---------------------------------------------------------------------------
# preprocessing (paper §3.3: dummy encoding + log1p)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FeatureMap:
    """Expanded design-matrix layout: feature j -> owning knob index."""
    columns: Tuple[str, ...]
    owner: Tuple[int, ...]       # knob index per column


def encode(space: Space, configs: Sequence[Config]) -> Tuple[np.ndarray, FeatureMap]:
    cols: List[str] = []
    owner: List[int] = []
    feats: List[np.ndarray] = []
    for ki, k in enumerate(space.knobs):
        vals = [c[k.name] for c in configs]
        if k.kind == "categorical":
            # dummy variables, one per category (paper: n binary params)
            for choice in k.choices:
                cols.append(f"{k.name}={choice}")
                owner.append(ki)
                feats.append(np.array([1.0 if v == choice else 0.0
                                       for v in vals]))
        elif k.kind == "bool":
            cols.append(k.name)
            owner.append(ki)
            feats.append(np.array([1.0 if v else 0.0 for v in vals]))
        else:
            cols.append(k.name)
            owner.append(ki)
            x = np.array([float(v) for v in vals])
            # log1p on magnitudes (sign-preserving for rare negatives)
            feats.append(np.sign(x) * np.log1p(np.abs(x)))
    return np.stack(feats, axis=1), FeatureMap(tuple(cols), tuple(owner))


def encode_target(y: Sequence[float]) -> np.ndarray:
    return np.log1p(np.asarray(y, np.float64))


# ---------------------------------------------------------------------------
# ranking
# ---------------------------------------------------------------------------

@dataclass
class RankingResult:
    space: Space
    importance: np.ndarray           # [n_knobs], descending NOT sorted
    order: np.ndarray                # knob indices sorted by importance desc
    feature_importance: np.ndarray   # [n_features] raw per-column
    fmap: FeatureMap
    samples: List[Config]
    values: List[float]

    def top(self, k: int) -> List[str]:
        return [self.space.knobs[i].name for i in self.order[:k]]

    def top_space(self, k: int) -> Space:
        return self.space.subset(self.top(k))

    def table(self, k: int = 16) -> List[Dict[str, object]]:
        """Paper Table-2 style rows for the top-k knobs."""
        rows = []
        for i in self.order[:k]:
            kn = self.space.knobs[i]
            rng = (f"[{kn.lo:g}, {kn.hi:g}]" if kn.kind in ("int", "float")
                   else "|".join(str(c) for c in (kn.choices or ("True", "False"))))
            if kn.dynamic_bound:
                rng += " (dynamic)"
            rows.append({
                "knob": kn.name, "type": kn.kind, "default": kn.default,
                "range": rng, "module": kn.module,
                "importance": float(self.importance[i]),
                "description": kn.description,
            })
        return rows


def rank(space: Space, evaluate: Callable[[Config], float],
         n_samples: int = 300, seed: int = 0,
         samples: Optional[List[Config]] = None,
         values: Optional[List[float]] = None,
         stability_rounds: int = 0,
         batch_size: int = 1) -> RankingResult:
    """Run the §3.3 pipeline.  Pass pre-collected (samples, values) to rank
    an existing evaluation database without new experiments.

    ``batch_size > 1`` scores the LHS design as that many-config batches
    through the evaluator's ``evaluate_batch`` (one vmapped cost-model
    sweep + one DB append per chunk) instead of n_samples sequential
    calls — the test cluster can bench configs concurrently, so the 300
    ranking experiments collapse to a handful of batch rounds.

    ``stability_rounds > 0`` enables **stability selection** (beyond-paper,
    Meinshausen & Bühlmann 2010): the lasso path is refit on that many
    half-subsamples and each feature's importance is multiplied by its
    selection frequency among early entrants — pure-noise features that
    only enter on lucky subsamples are suppressed.  The paper's plain
    single-fit ranking is the default (rounds = 0).
    """
    if samples is None:
        samples = latin_hypercube(space, n_samples, seed=seed)
    if values is None:
        if batch_size > 1:
            values = []
            for i in range(0, len(samples), batch_size):
                values.extend(evaluate_many(evaluate,
                                            samples[i:i + batch_size]))
        else:
            values = [float(evaluate(c)) for c in samples]

    x, fmap = encode(space, samples)
    y = encode_target(values)
    lams, betas = lasso_path(x, y)
    fimp = path_importance(lams, betas)

    if stability_rounds > 0:
        rng = np.random.default_rng(seed)
        n = x.shape[0]
        hits = np.zeros(x.shape[1])
        for _ in range(stability_rounds):
            idx = rng.choice(n, size=n // 2, replace=False)
            ls, bs = lasso_path(x[idx], y[idx], n_lambdas=30)
            early = np.abs(bs[: len(ls) // 3]).max(axis=0) > 1e-8
            hits += early
        fimp = fimp * (hits / stability_rounds)

    n_knobs = len(space)
    imp = np.zeros(n_knobs)
    for col, ki in enumerate(fmap.owner):
        imp[ki] = max(imp[ki], fimp[col])   # fold dummies to their knob
    order = np.argsort(-imp, kind="stable")
    return RankingResult(space, imp, order, fimp, fmap,
                         list(samples), list(values))


def rank_with_controller(space: Space, controller: "Controller",
                         n_samples: int = 300, seed: int = 0,
                         batch_size: Optional[int] = None,
                         strategy: str = "random",
                         stability_rounds: int = 0,
                         async_eval: bool = False,
                         max_in_flight: Optional[int] = None,
                         min_ask: int = 1) -> RankingResult:
    """The §3.3 ranking stage as strategy + experiment loop: a design
    strategy from the registry (LHS by default) is driven through the
    controller's evaluation service — every design batch is one tagged DB
    append — and the resulting trace feeds the Lasso-path ranking.  The
    samples and values are identical to :func:`rank` under the same seed
    (the evaluator noise stream is indexed per evaluation, not per batch
    shape).  ``async_eval`` drives the design through the overlapped
    :meth:`~repro.core.controller.Controller.run_async` loop — a design
    strategy never blocks on ``tell``, so the whole LHS streams through
    the service as fast as it completes (identical samples/values on the
    immediate analytic service).  Failed evaluations are *excluded* from
    the Lasso fit on the async path: the penalty values the strategy is
    told would otherwise enter the regression as huge outliers."""
    from repro.core.strategy import make_strategy   # lazy: avoid cycle
    strat = make_strategy(strategy, space, budget=n_samples, seed=seed,
                          batch_size=batch_size)
    if async_eval:
        n0 = len(controller.db)
        controller.run_async(strat, batch_size=batch_size,
                             max_in_flight=max_in_flight, min_ask=min_ask)
        ok = [r for r in controller.db.records[n0:] if r.ok]
        samples = [dict(r.config) for r in ok]
        values = [r.value for r in ok]
    else:
        trace = controller.run(strat)
        samples, values = trace.configs, trace.values
    return rank(space, None, samples=samples, values=values,
                seed=seed, stability_rounds=stability_rounds)

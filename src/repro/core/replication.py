"""Replicated measurements — the paper's answer to benchmark noise.

Sapphire's Experiment Unit averages several runs per configuration to tame
storage-system noise (the "averaging dilemma": too few repeats and the
tuner chases noise, too many and the budget evaporates).  This module is
that replication layer for the evaluation-service stack:

* :class:`RepeatStats` — streaming mean/variance over repeat observations
  (Chan et al. parallel merge), the one place pooled statistics are
  computed so aggregation is invariant to how repeats are grouped;
* :class:`ReplicationPolicy` — how the Controller replicates: fixed-k
  repeats per probe, optionally *adaptive* re-measurement of only the
  configs whose credible interval straddles the incumbent;
* :class:`ReplicatingService` — wraps any built-in evaluation service and
  fans each request into ``n_repeats`` seed-derived sub-probes, returning
  ONE aggregated :class:`~repro.core.service.EvalResult` per request
  (empirical mean, failure-widened variance of the mean, repeat count);
* :class:`AdaptiveRacer` — the re-measurement loop
  :meth:`~repro.core.controller.Controller.run_async` drives: completed
  probes whose interval straddles the current best are topped up with
  extra repeats through the same in-flight machinery instead of being
  told to the strategy at a noisy value.

Seed contract: every sub-probe's seed is derived from the request seed via
:func:`~repro.core.service.fold_seed` (``jax.random.fold_in``-style
splitting), so a replicated measurement is bit-reproducible end to end —
same (config, fidelity, seed) in, same aggregated result out, regardless
of which service ran it or in what order repeats completed.  Requests
without a seed get one derived from the service seed and ticket uid, so a
fresh service replays a fresh run deterministically.
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.service import (EvalRequest, EvalResult, EvalTicket,
                                _ServiceBase, fold_seed)


# ---------------------------------------------------------------------------
# pooled repeat statistics
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RepeatStats:
    """Streaming statistics over the successful repeats of one config.

    ``count`` successful observations with empirical ``mean`` and ``m2``
    (sum of squared deviations — Chan et al.'s merge state, so groups of
    repeats pool to the same statistics however they are split);
    ``failures`` counts repeats that failed.  A failed repeat never
    enters the mean — it *widens* the variance instead, by shrinking the
    effective sample behind :attr:`mean_var`.
    """

    count: int = 0
    mean: float = 0.0
    m2: float = 0.0
    failures: int = 0

    @classmethod
    def from_values(cls, values: Sequence[float],
                    failures: int = 0) -> "RepeatStats":
        st = cls(failures=failures)
        for v in values:
            st = st.push(float(v))
        return st

    def push(self, value: float) -> "RepeatStats":
        """Welford single-observation update."""
        n = self.count + 1
        delta = value - self.mean
        mean = self.mean + delta / n
        return RepeatStats(n, mean, self.m2 + delta * (value - mean),
                           self.failures)

    def merge(self, other: "RepeatStats") -> "RepeatStats":
        """Chan parallel merge: pooled mean/m2 of the two groups."""
        if other.count == 0:
            return replace(self, failures=self.failures + other.failures)
        if self.count == 0:
            return replace(other, failures=self.failures + other.failures)
        n = self.count + other.count
        delta = other.mean - self.mean
        mean = self.mean + delta * other.count / n
        m2 = (self.m2 + other.m2
              + delta * delta * self.count * other.count / n)
        return RepeatStats(n, mean, m2, self.failures + other.failures)

    @property
    def obs_var(self) -> float:
        """Unbiased variance of a single observation (0 when unknowable)."""
        return self.m2 / (self.count - 1) if self.count > 1 else 0.0

    @property
    def mean_var(self) -> float:
        """Failure-widened variance of the reported mean.

        The clean estimator is ``s²/k``; each failed repeat inflates it
        by ``(k + f)/k`` — the measurement spent ``k + f`` runs to get
        ``k`` usable ones, so the reported mean deserves proportionally
        less trust.  This is the per-observation noise the
        heteroscedastic GP consumes.
        """
        if self.count < 2:
            return 0.0
        widen = (self.count + self.failures) / self.count
        return (self.obs_var / self.count) * widen

    @classmethod
    def from_result(cls, result: EvalResult) -> "RepeatStats":
        """Reconstruct merge state from an aggregated result (exact
        inverse of :attr:`mean_var` for ``repeats >= 2``; a single
        measurement contributes its value with unknown spread)."""
        k, f = int(result.repeats), int(result.failures)
        if not result.ok or k <= 0:
            return cls(failures=max(f, 1))
        if k == 1:
            return cls(1, float(result.value), 0.0, f)
        obs_var = float(result.variance) * k * k / (k + f)
        return cls(k, float(result.value), obs_var * (k - 1), f)


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ReplicationPolicy:
    """How the Controller replicates measurements.

    ``n_repeats`` is the fixed-k policy: every probe is measured that
    many times and told to the strategy as one pooled observation.  With
    ``adaptive=True`` the initial count is ``max(n_repeats, 2)`` (a
    variance estimate needs two points) and
    :meth:`~repro.core.controller.Controller.run_async` then re-measures
    — ``increment`` repeats at a time, up to ``max_repeats`` total — only
    the configs whose ``±z``-sd credible interval still straddles the
    incumbent best: exactly the probes whose ranking the noise leaves
    undecided.  Everything else is told at its initial pooled value, so
    the repeat budget concentrates where it changes decisions (the
    paper's fixed-k averaging spends it uniformly).
    """

    n_repeats: int = 1
    adaptive: bool = False
    max_repeats: int = 8
    increment: int = 1
    z: float = 1.0
    seed: int = 0
    gp_prior: bool = True     # adaptive racing intervals borrow the
                              # strategy's GP-implied measurement noise
                              # (when the strategy exposes one) instead
                              # of trusting a 2-repeat empirical variance

    @property
    def initial_repeats(self) -> int:
        k = max(int(self.n_repeats), 1)
        return max(k, 2) if self.adaptive else k

    @property
    def active(self) -> bool:
        return self.adaptive or self.initial_repeats > 1


# ---------------------------------------------------------------------------
# the replicating service wrapper
# ---------------------------------------------------------------------------

class _Group:
    __slots__ = ("ticket", "results", "remaining")

    def __init__(self, ticket: EvalTicket, k: int):
        self.ticket = ticket
        self.results: List[Optional[EvalResult]] = [None] * k
        self.remaining = k


def aggregate_repeats(ticket: EvalTicket,
                      results: Sequence[EvalResult]) -> EvalResult:
    """Pool the repeats of one request into a single result.

    The mean is over *successful* repeats only, computed in slot (seed)
    order so the aggregate is bit-identical regardless of completion
    order; a failed repeat widens :class:`RepeatStats.mean_var` instead
    of poisoning the mean.  All-repeats-failed aggregates to a failed
    result carrying the first error.  ``wall_s`` is the summed
    measurement cost of every repeat, failed ones included.
    """
    ok = [r for r in results if r.ok]
    wall = float(sum(r.wall_s for r in results))
    if not ok:
        first = next(r for r in results if not r.ok)
        return replace(first, ticket=ticket, wall_s=wall,
                       repeats=0, failures=len(results))
    stats = RepeatStats.from_values([r.value for r in ok],
                                    failures=len(results) - len(ok))
    return EvalResult(
        ticket, stats.mean, "ok",
        all(r.feasible for r in ok),
        ok[0].breakdown, "", wall, None,
        variance=stats.mean_var, repeats=stats.count,
        failures=stats.failures)


class ReplicatingService(_ServiceBase):
    """Fan each request into ``n_repeats`` seed-derived sub-probes on the
    wrapped service and aggregate them into one result per request.

    Sub-probe ``i`` of a request carries seed ``fold_seed(base, i)``
    where ``base`` is the request's own seed (or, unseeded, a seed
    derived from this service's ``seed`` and the ticket uid — a fresh
    wrapper therefore replays a fresh run bit for bit).  Repeat ``i`` is
    thus the same measurement whether the request asked for 1 repeat or
    8, and whether the inner service completes in order (immediate) or
    out of order (worker pool).  A request's ``n_repeats`` field
    overrides the wrapper default (the adaptive racer submits 1-repeat
    top-ups this way).

    Completions stream back through the inner service's result sink
    (the :class:`~repro.core.service.FidelityRouter` mechanism), so the
    wrapped service must not be polled directly while attached.
    ``close()`` detaches the sink; closing the inner service stays with
    its owner.  ``measurements`` counts every sub-probe issued — the
    replication budget the benchmarks meter.
    """

    def __init__(self, inner: _ServiceBase, n_repeats: int = 3,
                 seed: int = 0):
        if not isinstance(inner, _ServiceBase):
            raise TypeError(
                "ReplicatingService wraps the built-in service base "
                f"(sink-capable); got {type(inner).__name__}")
        super().__init__()
        self.inner = inner
        self.n_repeats = max(int(n_repeats), 1)
        self.seed = int(seed)
        self.measurements = 0
        self._groups: Dict[int, _Group] = {}
        self._sub: Dict[int, Tuple[int, int]] = {}   # inner uid -> (uid, slot)
        self._rep_lock = threading.Lock()
        inner._sink = self._on_sub

    def submit(self, requests: Sequence[EvalRequest]) -> List[EvalTicket]:
        tickets = self._issue(requests)
        subs: List[EvalRequest] = []
        meta: List[Tuple[int, int]] = []
        for t in tickets:
            r = t.request
            k = max(int(r.n_repeats), 1) if r.n_repeats else self.n_repeats
            base = (r.seed if r.seed is not None
                    else fold_seed(self.seed, t.uid))
            with self._rep_lock:
                self._groups[t.uid] = _Group(t, k)
            for i in range(k):
                subs.append(replace(r, seed=fold_seed(base, i),
                                    n_repeats=None))
                meta.append((t.uid, i))
        # issue on the inner service, register the uid map, THEN dispatch
        # (an immediate inner completes inside its dispatch call — the
        # map must already be in place, and no lock may be held)
        sub_tickets = self.inner._issue(subs)
        with self._rep_lock:
            for st, m in zip(sub_tickets, meta):
                self._sub[st.uid] = m
            self.measurements += len(subs)
        self.inner._dispatch(sub_tickets)
        return tickets

    def _on_sub(self, result: EvalResult):
        with self._rep_lock:
            m = self._sub.pop(result.ticket.uid, None)
            if m is None:
                return
            uid, slot = m
            g = self._groups[uid]
            g.results[slot] = result
            g.remaining -= 1
            if g.remaining:
                return
            del self._groups[uid]
        self._complete(aggregate_repeats(g.ticket, g.results))

    def close(self):
        if self.inner._sink is not None:
            self.inner._sink = None


# ---------------------------------------------------------------------------
# adaptive re-measurement (driven by Controller.run_async)
# ---------------------------------------------------------------------------

class AdaptiveRacer:
    """Decide, per completed probe, whether the measurement is settled.

    A probe's pooled mean carries a ``±z·sd`` credible interval
    (:attr:`RepeatStats.mean_var`).  While that interval straddles the
    incumbent best mean, the probe's rank against the incumbent is
    noise-undecided, so the racer submits ``increment`` more repeats
    through the evaluation service (same config, a fresh fold of the
    seed) instead of releasing the result — the racing principle:
    re-measure only what the noise leaves ambiguous, up to
    ``max_repeats`` total runs per probe.  Single-threaded by design:
    ``run_async`` feeds it from the driver thread only.

    ``noise_prior`` lets the credible interval come from the GP
    posterior, not only the empirical repeat variance: a callable
    ``config -> variance-of-one-measurement`` (raw objective units, or
    ``None`` when no posterior exists yet — e.g.
    :meth:`repro.core.strategy.BOStrategy.measurement_variance`).  A
    2-repeat probe's own variance estimate has a single degree of
    freedom; the GP's fitted noise scalar is pooled over every config
    told so far, so the racer blends the two as a
    ``prior_weight``-pseudo-repeat inverse-chi-square style shrinkage:
    ``(ν·s² + w·σ²_GP) / (ν + w)`` with ``ν = k−1``.  Without a prior
    (the default) the decision rule is exactly the empirical one.
    """

    def __init__(self, policy: ReplicationPolicy, service,
                 noise_prior=None, prior_weight: float = 2.0):
        self.policy = policy
        self.service = service
        self.noise_prior = noise_prior
        self.prior_weight = float(prior_weight)
        self.incumbent = math.inf
        self._groups: Dict[int, dict] = {}       # outer uid -> group state
        self._follow: Dict[int, int] = {}        # follow-up uid -> outer uid

    @property
    def busy(self) -> int:
        """Probes currently held back awaiting top-up repeats."""
        return len(self._groups)

    def offer(self, uid: int, result: EvalResult, asked, prepared):
        """First completion of a probe: release it, or start racing it.
        Returns the ``(result, asked, prepared)`` wave entry when the
        probe is settled, ``None`` when it was held for re-measurement."""
        if not result.ok:
            return result, asked, prepared       # penalty path owns failures
        g = {"stats": RepeatStats.from_result(result),
             "result": result, "asked": asked, "prepared": prepared,
             "measured": int(result.repeats) + int(result.failures),
             "extras": 0}
        return self._decide(uid, g)

    def absorb(self, result: EvalResult):
        """A top-up repeat landed: merge and re-decide.  Returns a wave
        entry when settled, ``None`` when still racing or not ours."""
        uid = self._follow.pop(result.ticket.uid, None)
        if uid is None:
            return None
        g = self._groups.pop(uid)
        g["stats"] = g["stats"].merge(RepeatStats.from_result(result))
        g["measured"] += max(int(result.repeats), 0) + int(result.failures)
        return self._decide(uid, g)

    def _mean_var(self, g: dict) -> float:
        """Variance of the probe's pooled mean for the racing decision:
        empirical by default; with a ``noise_prior``, the per-observation
        variance is shrunk toward the GP's pooled noise estimate
        (``prior_weight`` pseudo-repeats) before dividing by the repeat
        count — small-k probes then race on an interval the whole trace
        informs, not on a 1-dof variance draw."""
        st: RepeatStats = g["stats"]
        if self.noise_prior is None:
            return st.mean_var
        v0 = self.noise_prior(g["asked"])
        if v0 is None or not v0 > 0.0:
            return st.mean_var
        nu = st.count - 1
        pooled = ((nu * st.obs_var + self.prior_weight * v0)
                  / (nu + self.prior_weight))
        widen = (st.count + st.failures) / st.count
        return (pooled / st.count) * widen

    def _decide(self, uid: int, g: dict):
        st: RepeatStats = g["stats"]
        room = self.policy.max_repeats - g["measured"]
        if st.count >= 2 and room > 0:
            sd = math.sqrt(self._mean_var(g))
            lo, hi = st.mean - self.policy.z * sd, st.mean + self.policy.z * sd
            if sd > 0.0 and lo <= self.incumbent <= hi:
                self._remeasure(uid, g, min(self.policy.increment, room))
                return None
        return self._release(g)

    def _remeasure(self, uid: int, g: dict, k: int):
        req: EvalRequest = g["result"].request
        seed = None
        if req.seed is not None:
            # continue the request's own seed stream so explicit-seed
            # replays stay bit-deterministic (unseeded requests let the
            # service derive a fresh base from the new ticket uid)
            g["extras"] += 1
            seed = fold_seed(req.seed, 1_000_000 + g["extras"])
        (t,) = self.service.submit([replace(req, seed=seed, n_repeats=k)])
        self._follow[t.uid] = uid
        self._groups[uid] = g

    def _release(self, g: dict):
        st: RepeatStats = g["stats"]
        self.incumbent = min(self.incumbent, st.mean)
        out = replace(g["result"], value=st.mean, variance=st.mean_var,
                      repeats=st.count, failures=st.failures)
        return out, g["asked"], g["prepared"]

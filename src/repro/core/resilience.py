"""Resilient evaluation: retries, deadlines, and circuit breaking.

Real test clusters flake — benchmark runs hang, workers die, connections
reset — and a tuner that cannot tell a *transient* infrastructure fault
from a *permanently* broken config either wastes budget on penalty rows
for configs that were fine, or wedges behind a probe that will never
return.  This module is the resilience layer between the experiment loop
and any :class:`~repro.core.service.EvaluationService`:

* :func:`classify_failure` splits failed results into ``"transient"``
  (retrying the same probe may succeed) vs ``"permanent"`` (the config
  itself is broken — an infeasible row, as before).
* :class:`RetryPolicy` — how hard to try: max attempts, exponential
  backoff with *deterministic* jitter (derived from the request seed, so
  a chaos run is bit-replayable), an optional per-attempt timeout and a
  per-request deadline across all attempts.
* :class:`ResilientService` — a wrapper that resubmits
  transiently-failed probes and stamps every outcome with
  ``error_kind`` / ``attempts``.  One outer ticket per request, however
  many inner attempts it took: drivers that count completions (the
  async controller's ``n_evaluations``) are never inflated by retries.
* :class:`CircuitBreaker` — per-backend consecutive-transient-failure
  trip wire used by the shared evaluation pool to shed load instead of
  burning budget against a downed backend, half-opening on a timer.

Retried attempts reuse the *original* measurement seed by default, so a
probe that eventually succeeds reports exactly the measurement the
fault-free run would have — the chaos-gate bit-identity property.  Set
``RetryPolicy(reseed_attempts=True)`` to fold the attempt index into the
seed instead (independent noise per attempt, e.g. when the fault *is*
seed-correlated).
"""

from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.service import (EvalRequest, EvalResult, EvalTicket,
                                _ServiceBase, fold_seed)

__all__ = [
    "TransientEvalError", "classify_failure", "RetryPolicy",
    "ResilientService", "CircuitBreaker",
]

# seed-fold namespace for reseeded retry attempts — disjoint from the
# replication sub-repeat folds (0..k) and the adaptive racer's top-up
# namespace (1_000_000+), so attempt streams never collide with either
_ATTEMPT_NS = 2_000_000


class TransientEvalError(RuntimeError):
    """An infrastructure fault, not a verdict on the config: raising (or
    wrapping a failure in) this marks the probe as retryable.  The
    hung-probe watchdog, the fault-injection harness and backend shims
    use it to classify unambiguously."""


# exception types that are transient by construction — infrastructure
# hiccups, never evidence about the config under test.  (OSError at
# large is deliberately absent: FileNotFoundError etc. are permanent.)
_TRANSIENT_TYPES = (TransientEvalError, TimeoutError, ConnectionError,
                    BrokenPipeError, InterruptedError)

# message fragments that mark a stringly-typed failure as transient —
# matched case-insensitively against ``EvalResult.error``
_TRANSIENT_PATTERNS = (
    "timeout", "timed out", "deadline", "transient", "temporarily",
    "unavailable", "connection", "reset by peer", "broken pipe",
    "worker died", "worker death", "hung worker", "try again",
)


def classify_failure(result: EvalResult) -> str:
    """``"transient"`` or ``"permanent"`` for a failed result.

    Precedence: an explicit ``error_kind`` stamp (the watchdog and the
    chaos harness know what they injected) > the exception type > error-
    string patterns > ``"permanent"``.  Defaulting to permanent is the
    safe side: a misclassified transient costs one penalty row (exactly
    the pre-resilience behaviour), a misclassified permanent would burn
    retry budget on a config that can never pass.
    """
    if result.error_kind:
        return result.error_kind
    exc = result.exception
    if exc is not None and isinstance(exc, _TRANSIENT_TYPES):
        return "transient"
    msg = result.error.lower()
    if any(p in msg for p in _TRANSIENT_PATTERNS):
        return "transient"
    return "permanent"


@dataclass(frozen=True)
class RetryPolicy:
    """How a :class:`ResilientService` spends attempts on one request.

    ``max_attempts`` counts the first try (3 = one probe + two retries).
    Backoff for retry *i* (1-based) is ``backoff_s * backoff_mult**(i-1)``
    capped at ``max_backoff_s``, scaled by a deterministic jitter factor
    in ``[1 - jitter/2, 1 + jitter/2)`` derived from the request seed —
    no wall-clock or global RNG, so two chaos runs at equal seeds sleep
    identically.  ``attempt_timeout_s`` arms a per-attempt watchdog (an
    attempt that neither completes nor fails within it is treated as a
    transient failure — the recovery path for hung probes and dropped
    completions); ``deadline_s`` bounds the total wall-clock spent across
    all attempts of one request.  ``reseed_attempts`` folds the attempt
    index into the measurement seed on retries (see module docstring for
    why the default reuses the original seed).
    """
    max_attempts: int = 3
    backoff_s: float = 0.05
    backoff_mult: float = 2.0
    max_backoff_s: float = 2.0
    jitter: float = 0.5
    attempt_timeout_s: Optional[float] = None
    deadline_s: Optional[float] = None
    reseed_attempts: bool = False

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got "
                             f"{self.max_attempts}")

    @property
    def active(self) -> bool:
        """Whether wrapping a service in this policy changes anything."""
        return (self.max_attempts > 1 or self.attempt_timeout_s is not None
                or self.deadline_s is not None)

    def delay_s(self, seed: Optional[int], attempt: int) -> float:
        """Backoff before retry attempt ``attempt`` (2-based: the delay
        preceding the i-th attempt), deterministically jittered."""
        base = min(self.backoff_s * self.backoff_mult ** max(attempt - 2, 0),
                   self.max_backoff_s)
        if base <= 0.0 or self.jitter <= 0.0:
            return max(base, 0.0)
        h = hashlib.blake2s(
            f"retry|{seed}|{attempt}".encode()).digest()[:8]
        u = int.from_bytes(h, "little") / 2.0 ** 64        # [0, 1)
        return base * (1.0 + self.jitter * (u - 0.5))

    def attempt_seed(self, seed: Optional[int], attempt: int) -> Optional[int]:
        """Measurement seed for attempt ``attempt`` (1-based)."""
        if seed is None or attempt == 1 or not self.reseed_attempts:
            return seed
        return fold_seed(seed, _ATTEMPT_NS + attempt)


class ResilientService(_ServiceBase):
    """Retry wrapper over any ticket-store service.

    Issues one *outer* ticket per request and drives up to
    ``policy.max_attempts`` *inner* attempts against the wrapped service.
    Ok results pass through (stamped with ``attempts``); failures are
    classified — permanent ones complete the outer ticket immediately as
    today's infeasible rows, transient ones are resubmitted after a
    deterministic backoff until attempts or the deadline run out, at
    which point the outer ticket completes failed with
    ``error_kind="transient"`` and the full attempt count.

    The wrapped service must expose the ``_issue``/``_dispatch`` split
    (every built-in service does) so attempt registration can precede
    dispatch — immediate services complete *inside* dispatch, and the
    completion must already know which outer ticket it belongs to.  This
    wrapper exposes the same split, so a
    :class:`~repro.core.replication.ReplicatingService` can stack on top:
    each sub-repeat then retries independently, and the Chan merge only
    ever sees one settled result per repeat.
    """

    def __init__(self, inner: _ServiceBase, policy: RetryPolicy = None):
        if not isinstance(inner, _ServiceBase):
            raise TypeError(
                f"ResilientService needs the _issue/_dispatch split of a "
                f"_ServiceBase; got {type(inner).__name__}.  (Wrap the "
                "backend, not an arbitrary protocol object.)")
        super().__init__()
        self.inner = inner
        self.policy = policy if policy is not None else RetryPolicy()
        # stats — mutated under self._cv
        self.retries = 0          # resubmitted attempts
        self.exhausted = 0        # requests that ran out of attempts/deadline
        self.timeouts = 0         # attempts reaped by the attempt watchdog
        # inner uid -> (outer ticket, attempt#); guarded by self._cv
        self._attempts: Dict[int, Tuple[EvalTicket, int]] = {}
        self._started: Dict[int, float] = {}      # outer uid -> t0
        self._timers: Dict[int, threading.Timer] = {}   # keyed by inner uid
        self._retry_timers: Dict[int, threading.Timer] = {}  # by outer uid
        self._closed = False
        inner._sink = self._on_inner

    # -- submission ---------------------------------------------------------

    def submit(self, requests: Sequence[EvalRequest]) -> List[EvalTicket]:
        tickets = self._issue(requests)
        self._dispatch(tickets)
        return tickets

    def _dispatch(self, tickets: Sequence[EvalTicket]) -> None:
        now = time.monotonic()
        with self._cv:
            for t in tickets:
                self._started[t.uid] = now
        for t in tickets:
            self._launch(t, 1)

    def _launch(self, outer: EvalTicket, attempt: int) -> None:
        req = outer.request
        seed = self.policy.attempt_seed(req.seed, attempt)
        if seed != req.seed:
            req = replace(req, seed=seed)
        inner_tickets = self.inner._issue([req])
        it = inner_tickets[0]
        with self._cv:
            self._retry_timers.pop(outer.uid, None)
            if self._closed or outer.uid not in self._inflight:
                # closed (or watchdog settled the outer ticket) while the
                # retry timer was pending: the inner ticket must still
                # complete so the inner store stays consistent
                self._attempts[it.uid] = (outer, -attempt)
            else:
                self._attempts[it.uid] = (outer, attempt)
                if self.policy.attempt_timeout_s is not None:
                    timer = threading.Timer(self.policy.attempt_timeout_s,
                                            self._reap_attempt, (it,))
                    timer.daemon = True
                    self._timers[it.uid] = timer
                    timer.start()
        self.inner._dispatch(inner_tickets)

    # -- completion / retry -------------------------------------------------

    def _reap_attempt(self, inner_ticket: EvalTicket) -> None:
        """Attempt watchdog: the inner service neither completed nor
        failed this attempt in time — synthesize a transient failure so
        the retry machinery (and ultimately ``gather``/``drain``) make
        progress.  A late real completion is ignored (its attempt entry
        is gone)."""
        with self._cv:
            if inner_ticket.uid not in self._attempts:
                return                          # real completion won
            self.timeouts += 1
        err = TransientEvalError(
            f"attempt exceeded its "
            f"{self.policy.attempt_timeout_s}s timeout (hung probe or "
            "dropped completion)")
        self._on_inner(EvalResult(
            ticket=inner_ticket, value=float("nan"), status="failed",
            feasible=False, error=repr(err), exception=err,
            error_kind="transient"))

    def _on_inner(self, result: EvalResult) -> None:
        with self._cv:
            entry = self._attempts.pop(result.ticket.uid, None)
            timer = self._timers.pop(result.ticket.uid, None)
        if timer is not None:
            timer.cancel()
        if entry is None:
            return                  # late completion after the watchdog won
        outer, attempt = entry
        if attempt < 0:
            return                  # orphaned attempt (service closed)

        if result.ok:
            self._complete(replace(result, ticket=outer, attempts=attempt))
            return

        kind = classify_failure(result)
        if kind == "transient" and self._can_retry(outer, attempt):
            with self._cv:
                self.retries += 1
            delay = self.policy.delay_s(outer.request.seed, attempt + 1)
            if delay <= 0.0:
                self._launch(outer, attempt + 1)
                return
            timer = threading.Timer(delay, self._launch,
                                    (outer, attempt + 1))
            timer.daemon = True
            with self._cv:
                if self._closed:
                    delay = None
                else:
                    self._retry_timers[outer.uid] = timer
            if delay is None:
                self._give_up(outer, attempt, result, kind)
            else:
                timer.start()
            return

        if kind == "transient":
            with self._cv:
                self.exhausted += 1
        self._give_up(outer, attempt, result, kind)

    def _can_retry(self, outer: EvalTicket, attempt: int) -> bool:
        if attempt >= self.policy.max_attempts:
            return False
        if self.policy.deadline_s is not None:
            with self._cv:
                t0 = self._started.get(outer.uid)
            if t0 is not None and (time.monotonic() - t0
                                   >= self.policy.deadline_s):
                return False
        return True

    def _give_up(self, outer: EvalTicket, attempt: int,
                 result: EvalResult, kind: str) -> None:
        with self._cv:
            self._started.pop(outer.uid, None)
        self._complete(replace(result, ticket=outer, error_kind=kind,
                               attempts=attempt))

    def _complete(self, result: EvalResult):
        with self._cv:
            self._started.pop(result.ticket.uid, None)
        super()._complete(result)

    # -- protocol plumbing --------------------------------------------------

    def close(self):
        with self._cv:
            self._closed = True
            timers = (list(self._timers.values())
                      + list(self._retry_timers.values()))
            self._timers.clear()
            self._retry_timers.clear()
        for t in timers:
            t.cancel()
        self.inner.close()

    def __exit__(self, *exc):
        self.close()


# ---------------------------------------------------------------------------
# circuit breaker (used per-backend by the shared evaluation pool)
# ---------------------------------------------------------------------------

@dataclass
class CircuitBreaker:
    """Consecutive-transient-failure trip wire.

    ``closed`` (normal): requests flow; each transient failure increments
    a consecutive counter, any success (or permanent failure — those are
    verdicts on configs, not the backend) resets it.  At ``threshold``
    consecutive transient failures the breaker *opens*: :meth:`allow`
    refuses until ``reset_s`` has elapsed, at which point it *half-opens*
    and admits exactly one trial request — success closes the breaker,
    failure re-opens it for another ``reset_s``.

    ``clock`` is injectable for tests (defaults to ``time.monotonic``).
    Not thread-safe by itself — callers (the pool) serialize access under
    their own lock.
    """
    threshold: int = 5
    reset_s: float = 30.0
    clock: object = field(default=time.monotonic, repr=False)

    _failures: int = field(default=0, init=False)
    _state: str = field(default="closed", init=False)
    _opened_at: float = field(default=0.0, init=False)
    _trial_pending: bool = field(default=False, init=False)
    trips: int = field(default=0, init=False)   # times the breaker opened

    @property
    def state(self) -> str:
        if (self._state == "open"
                and self.clock() - self._opened_at >= self.reset_s):
            return "half_open"
        return self._state

    def allow(self) -> bool:
        """Whether a new request may be sent to this backend now."""
        if self._state == "closed":
            return True
        if self.state == "half_open":
            if self._trial_pending:
                return False            # one trial at a time
            self._state = "half_open"
            self._trial_pending = True
            return True
        return False

    def record_success(self) -> None:
        self._failures = 0
        self._trial_pending = False
        self._state = "closed"

    def record_failure(self) -> None:
        """Record a *transient* failure (permanent failures are config
        verdicts — report those as successes of the backend)."""
        self._trial_pending = False
        if self._state in ("open", "half_open"):
            self._state = "open"        # failed trial: re-open the window
            self._opened_at = self.clock()
            return
        self._failures += 1
        if self._failures >= self.threshold:
            self._state = "open"
            self._opened_at = self.clock()
            self.trips += 1

"""Constraint-aware sampling of the clean parameter space (paper §3.3).

The ranking phase needs ~300 random configurations spread over the whole
space.  Two samplers:

* ``random_configs``  — iid uniform in the unit cube (log-aware), projected
  through the C3/C4 constraint solver so every sample is a *valid* config
  (the paper's requirement that the domain "contains no misconfigurations").
* ``latin_hypercube`` — stratified LHS for better space coverage at the
  same sample count (what we actually use for ranking; iid kept for tests
  and for the GA/SA initializers).
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.space import Config, Space


def random_unit(rng: np.random.Generator, n: int, d: int) -> np.ndarray:
    return rng.random((n, d))


def lhs_unit(rng: np.random.Generator, n: int, d: int) -> np.ndarray:
    """Latin hypercube in [0,1]^d: one sample per stratum per dim."""
    u = (rng.permuted(np.tile(np.arange(n), (d, 1)), axis=1).T
         + rng.random((n, d))) / n
    return u


def random_configs(space: Space, n: int, seed: int = 0) -> List[Config]:
    rng = np.random.default_rng(seed)
    u = random_unit(rng, n, len(space))
    return space.decode_batch(u)


def latin_hypercube(space: Space, n: int, seed: int = 0) -> List[Config]:
    rng = np.random.default_rng(seed)
    u = lhs_unit(rng, n, len(space))
    return space.decode_batch(u)


def init_design(space: Space, n: int, rng: np.random.Generator,
                init_configs: Optional[List[Config]] = None) -> List[Config]:
    """The optimizer initial design: caller-supplied configs first (warm
    starts, e.g. the incumbent production config), then LHS fill up to
    ``n``.  Every returned config is projected onto the clean domain."""
    init = list(init_configs or [])
    need = max(n - len(init), 0)
    if need:
        init += space.decode_batch(lhs_unit(rng, need, len(space)))
    return space.project_batch(init)

"""Evaluation services — the Experiment Unit as an asynchronous job queue.

The paper's Experiment Unit runs benchmarks on a test cluster where the
*evaluation* latency, not the optimizer, dominates wall-clock; BestConfig's
parallelized sampling rounds and Magpie's decoupled tuning agent both exploit
that by keeping many measurements in flight.  The bare-float evaluator
contract (``__call__``/``evaluate_batch``) cannot express in-flight work,
fidelities, workloads or failures, so this module replaces it with a
first-class API:

* :class:`EvalRequest`  — what to measure: a config plus its *fidelity*
  (which cluster / cost tier scores it), *workload* (the arch×shape cell
  the measurement belongs to), a free-form *tag* and an optional *seed*;
* :class:`EvalTicket`   — the handle ``submit`` returns for each request;
* :class:`EvalResult`   — value + feasibility/breakdown + ``ok | failed``
  status + per-evaluation wall time.  A worker that raises produces a
  *failed* result, never an exception out of the service;
* :class:`EvaluationService` — the protocol: ``submit`` returns tickets
  immediately, ``poll`` hands back whatever has completed (optionally
  blocking for the first completion), ``gather`` blocks for specific
  tickets, ``drain`` blocks until nothing is in flight.

Three concrete services cover the repo's backends:

* :class:`ImmediateEvaluationService` — the analytic test cluster: every
  request completes *at submit time* through the backend's batched path
  (``evaluate_batch_detailed``/``evaluate_batch`` when present), so the
  vmapped per-row-key noise stream is bit-compatible with the legacy
  evaluator calls.  Accepts one backend or a ``{fidelity: backend}`` dict —
  fidelity is a request field, not a choice of evaluator object.
* :class:`WorkerPoolEvaluationService` — the compiled product cluster: a
  persistent thread pool that streams completions *out of order* as
  compiles finish.
* :class:`CallableServiceAdapter` — keeps any legacy
  ``Callable[[Config], float]`` working (and serves every fidelity with it).

:class:`FidelityRouter` composes per-fidelity services (e.g. an immediate
analytic screen + a pooled compiled promotion) behind one service, and
:func:`as_service` normalizes "service or evaluator or bare callable" at
the Controller boundary.
"""

from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass, replace
from typing import (Any, Callable, Dict, List, Mapping, Optional, Protocol,
                    Sequence, Tuple, Union, runtime_checkable)

from repro.core.space import Config

DEFAULT_FIDELITY = "test"


def fold_seed(seed: int, i: int) -> int:
    """Derive sub-stream ``i`` of ``seed`` (``jax.random.fold_in``-style
    splitting, host-side): deterministic, stable across processes, and
    collision-resistant, so a replicated request fans into repeats whose
    noise streams are independent yet bit-reproducible.  Stays in the
    63-bit range the analytic evaluator's key builder expects."""
    h = hashlib.blake2s(f"fold|{seed}|{i}".encode()).digest()[:8]
    return int.from_bytes(h, "little") >> 1


# ---------------------------------------------------------------------------
# the request / ticket / result triple
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class EvalRequest:
    """One measurement to run.

    ``fidelity`` names the cluster / cost tier that scores the config (the
    service routes on it); ``workload`` names the cell the measurement
    belongs to (e.g. ``"yi-6b:train_4k"``) so a shared evaluation database
    can be sliced per workload; ``tag`` is the experiment phase (``rank``,
    ``bo``, ``screen``…).  ``seed`` pins the measurement's noise stream:
    the built-in services pass it to seed-aware backends
    (``accepts_seeds`` / ``wants_request``), making any (config,
    fidelity, seed) probe bit-reproducible — the replication contract.
    ``n_repeats`` lets a single request override a
    :class:`~repro.core.replication.ReplicatingService`'s default repeat
    count (the adaptive re-measurement path submits 1-repeat top-ups);
    services that do not replicate ignore it.
    """
    config: Config
    fidelity: str = DEFAULT_FIDELITY
    workload: str = ""
    tag: str = ""
    seed: Optional[int] = None
    n_repeats: Optional[int] = None


@dataclass(frozen=True)
class EvalTicket:
    """Handle for an in-flight request (``uid`` is unique per service)."""
    uid: int
    request: EvalRequest


@dataclass(frozen=True)
class EvalResult:
    """Outcome of one request.  ``status`` is ``"ok"`` or ``"failed"``;
    failed results carry ``value = nan``, the worker's error string, and
    the original exception object (for ``raise ... from`` chains) — the
    *caller* decides the penalty (the async controller records them as
    infeasible instead of killing the run)."""
    ticket: EvalTicket
    value: float
    status: str = "ok"
    feasible: bool = True
    breakdown: Optional[Any] = None     # backend-specific (CostBreakdown)
    error: str = ""
    wall_s: float = 0.0
    exception: Optional[BaseException] = None
    # replication fields (ReplicatingService aggregates): ``value`` is the
    # empirical mean over ``repeats`` successful measurements, ``variance``
    # the variance OF THAT MEAN (failure-widened: failed repeats shrink
    # the effective sample without touching the mean), ``failures`` how
    # many repeats failed.  Single measurements keep the defaults —
    # variance 0.0 means "no empirical noise estimate", and downstream
    # consumers (the heteroscedastic GP) fall back to the global scalar.
    variance: float = 0.0
    repeats: int = 1
    failures: int = 0
    # failure classification (repro.core.resilience): ``"transient"`` —
    # the measurement infrastructure flaked (worker death, timeout,
    # connection reset; retrying the same probe may succeed) vs
    # ``"permanent"`` — the config itself is broken (an infeasible row).
    # ``""`` means unclassified: raw backend failures leave it empty and
    # the resilience layer stamps it after classifying.  Ok results keep
    # the default.
    error_kind: str = ""
    # how many attempts a ResilientService spent on this request (1 =
    # first try succeeded / no resilience layer in the path)
    attempts: int = 1

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    @property
    def request(self) -> EvalRequest:
        return self.ticket.request

    @property
    def config(self) -> Config:
        return self.ticket.request.config


@runtime_checkable
class EvaluationService(Protocol):
    """What the experiment loop needs from an Experiment Unit."""

    def submit(self, requests: Sequence[EvalRequest]) -> List[EvalTicket]:
        """Enqueue requests; returns one ticket per request immediately."""
        ...

    def poll(self, timeout: Optional[float] = 0.0) -> List[EvalResult]:
        """Claim completed-but-unclaimed results, in completion order.
        ``timeout=0`` never blocks; a positive timeout waits up to that
        long for the first completion; ``timeout=None`` blocks until at
        least one result is available or nothing is in flight."""
        ...

    def gather(self, tickets: Sequence[EvalTicket]) -> List[EvalResult]:
        """Block until the given tickets complete; results in ticket order."""
        ...

    def drain(self) -> List[EvalResult]:
        """Block until nothing is in flight; claim everything unclaimed."""
        ...


# ---------------------------------------------------------------------------
# shared ticket / completion bookkeeping
# ---------------------------------------------------------------------------

class _ServiceBase:
    """Thread-safe ticket issue + completion store behind the protocol.

    Subclasses implement :meth:`submit` by calling :meth:`_issue` for the
    tickets and delivering one :meth:`_complete` per ticket (from any
    thread).  Every code path must complete its ticket — exceptions are
    wrapped into failed results — so ``gather``/``drain`` cannot deadlock.
    """

    def __init__(self):
        self._cv = threading.Condition()
        self._uid = 0
        self._inflight: set = set()
        self._done: Dict[int, EvalResult] = {}
        self._order: List[int] = []          # completion order of _done
        self._sink: Optional[Callable[[EvalResult], None]] = None

    # -- subclass side ------------------------------------------------------

    def _issue(self, requests: Sequence[EvalRequest]) -> List[EvalTicket]:
        with self._cv:
            tickets = []
            for r in requests:
                tickets.append(EvalTicket(self._uid, r))
                self._inflight.add(self._uid)
                self._uid += 1
            return tickets

    def _complete(self, result: EvalResult):
        with self._cv:
            uid = result.ticket.uid
            if uid not in self._inflight:
                # late or duplicate completion: the ticket already settled
                # (a hung-probe watchdog fired first, a chaos harness
                # injected a duplicate) — exactly-once delivery is this
                # store's contract, so the straggler is dropped here
                return
            self._inflight.discard(uid)
            sink = self._sink
            if sink is None:
                self._done[uid] = result
                self._order.append(uid)
            self._cv.notify_all()
        if sink is not None:
            sink(result)                    # routed (FidelityRouter)

    # -- protocol side ------------------------------------------------------

    @property
    def in_flight(self) -> int:
        with self._cv:
            return len(self._inflight)

    @property
    def ready(self) -> int:
        """Completed-but-unclaimed results (what ``poll(0)`` would return)."""
        with self._cv:
            return len(self._order)

    def _claim_all(self) -> List[EvalResult]:
        out = [self._done.pop(uid) for uid in self._order]
        self._order.clear()
        return out

    def poll(self, timeout: Optional[float] = 0.0,
             min_results: int = 1) -> List[EvalResult]:
        """Claim completed-but-unclaimed results.  A blocking poll
        (``timeout != 0``) waits for at least ``min_results`` completions
        — or for everything in flight to land, whichever comes first.
        Drivers that coalesce tell waves (``Controller.run_async`` with
        ``min_ask > 1``) use this to wake once per wave instead of once
        per straggler; the default reproduces the one-completion wakeup
        of the base protocol."""
        with self._cv:
            if timeout != 0.0:
                self._cv.wait_for(
                    lambda: len(self._order) >= min_results
                    or not self._inflight, timeout)
            return self._claim_all()

    def gather(self, tickets: Sequence[EvalTicket]) -> List[EvalResult]:
        uids = [t.uid for t in tickets]
        with self._cv:
            unknown = [u for u in uids
                       if u not in self._inflight and u not in self._done]
            if unknown:
                raise KeyError(f"gather: tickets {unknown} are not in flight "
                               "(never submitted here, or already claimed)")
            self._cv.wait_for(lambda: all(u in self._done for u in uids))
            out = [self._done.pop(u) for u in uids]
            claimed = set(uids)
            self._order = [u for u in self._order if u not in claimed]
            return out

    def drain(self) -> List[EvalResult]:
        with self._cv:
            self._cv.wait_for(lambda: not self._inflight)
            return self._claim_all()

    def close(self):                        # overridden by pooled services
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# ---------------------------------------------------------------------------
# scoring helpers shared by the concrete services
# ---------------------------------------------------------------------------

_Scored = Tuple[float, bool, Optional[Any], str, str,
                Optional[BaseException]]    # value, feasible, breakdown,
                                            # status, error, exception


def _failed(e: BaseException) -> _Scored:
    return float("nan"), False, None, "failed", repr(e), e


def _seeds_of(requests: Optional[Sequence[Optional[EvalRequest]]],
              n: int) -> List[Optional[int]]:
    if requests is None:
        return [None] * n
    return [r.seed if r is not None else None for r in requests]


def _score_one(backend, cfg: Config,
               request: Optional[EvalRequest] = None) -> _Scored:
    seed = request.seed if request is not None else None
    try:
        detailed = getattr(backend, "evaluate_batch_detailed", None)
        if detailed is not None:
            if seed is not None and getattr(backend, "accepts_seeds", False):
                (v,), (bd,) = detailed([cfg], seeds=[seed])
            else:
                (v,), (bd,) = detailed([cfg])
            return float(v), bool(bd.feasible), bd, "ok", "", None
        if request is not None and getattr(backend, "wants_request", False):
            # request-aware backends (e.g. kernels.autotune.KernelEvaluator)
            # see the fidelity/tag/seed of the measurement they run
            return float(backend(cfg, request=request)), True, None, \
                "ok", "", None
        return float(backend(cfg)), True, None, "ok", "", None
    except Exception as e:                  # a failed benchmark, not a crash
        return _failed(e)


def _score_batch(backend, cfgs: Sequence[Config],
                 requests: Optional[Sequence[EvalRequest]] = None,
                 ) -> List[_Scored]:
    """Batched scoring with per-config failure isolation: the backend's
    batch path is tried first (bit-compatible with the legacy evaluator
    noise stream); if it raises — or returns the wrong number of values,
    which would otherwise orphan tickets and deadlock gather/drain — each
    config is retried alone so one bad config fails one result, not the
    whole batch.  Request seeds ride the batch path on seed-aware
    backends (``accepts_seeds``) so a seeded probe draws the same noise
    whether it is scored batched, alone, or by a worker thread."""
    seeds = _seeds_of(requests, len(cfgs))
    try:
        detailed = getattr(backend, "evaluate_batch_detailed", None)
        if detailed is not None:
            if (any(s is not None for s in seeds)
                    and getattr(backend, "accepts_seeds", False)):
                vals, bds = detailed(cfgs, seeds=seeds)
            else:
                vals, bds = detailed(cfgs)
            out = [(float(v), bool(bd.feasible), bd, "ok", "", None)
                   for v, bd in zip(vals, bds)]
            if len(out) == len(cfgs):
                return out
        else:
            batch = getattr(backend, "evaluate_batch", None)
            if batch is not None:
                out = [(float(v), True, None, "ok", "", None)
                       for v in batch(cfgs)]
                if len(out) == len(cfgs):
                    return out
    except Exception:
        pass                                # isolate the failure per config
    if requests is None:
        requests = [None] * len(cfgs)
    return [_score_one(backend, c, r) for c, r in zip(cfgs, requests)]


def _result(ticket: EvalTicket, scored: _Scored, wall_s: float) -> EvalResult:
    v, feasible, bd, status, err, exc = scored
    return EvalResult(ticket, v, status, feasible, bd, err, wall_s, exc)


Backend = Union[Callable[[Config], float], Any]
Backends = Union[Backend, Mapping[str, Backend]]


class _BackendService(_ServiceBase):
    """Backend table shared by the immediate and pooled services: either a
    single backend serving *every* fidelity, or ``{fidelity: backend}``.
    ``submit`` splits into issue + dispatch so :class:`FidelityRouter` can
    register its ticket map between the two."""

    def __init__(self, backends: Backends,
                 default_fidelity: str = DEFAULT_FIDELITY):
        super().__init__()
        self.default_fidelity = default_fidelity
        if isinstance(backends, Mapping):
            self._any: Optional[Backend] = None
            self.backends: Dict[str, Backend] = dict(backends)
        else:
            self._any = backends
            self.backends = {default_fidelity: backends}

    @property
    def fidelities(self) -> Tuple[str, ...]:
        return tuple(sorted(self.backends))

    def _backend(self, fidelity: str) -> Backend:
        if self._any is not None:
            return self._any
        try:
            return self.backends[fidelity]
        except KeyError:
            raise KeyError(f"no backend for fidelity {fidelity!r}; "
                           f"this service hosts {self.fidelities}") from None

    def submit(self, requests: Sequence[EvalRequest]) -> List[EvalTicket]:
        tickets = self._issue(requests)
        self._dispatch(tickets)
        return tickets

    def _dispatch(self, tickets: Sequence[EvalTicket]) -> None:
        raise NotImplementedError


class ImmediateEvaluationService(_BackendService):
    """The analytic test cluster as a service: every request completes at
    submit time.  Requests are grouped per fidelity and scored through the
    backend's batched path, so an :class:`~repro.core.evaluators.
    AnalyticEvaluator` backend keeps its vmapped per-row-key noise stream —
    a submit of n requests is bit-compatible with the legacy
    ``evaluate_batch`` call (and with n sequential ``__call__``\\ s)."""

    def _dispatch(self, tickets: Sequence[EvalTicket]) -> None:
        groups: Dict[str, List[EvalTicket]] = {}
        for t in tickets:
            groups.setdefault(t.request.fidelity, []).append(t)
        for fidelity, group in groups.items():
            cfgs = [t.request.config for t in group]
            t0 = time.monotonic()
            try:
                backend = self._backend(fidelity)
            except KeyError as e:
                scored = [_failed(e)] * len(cfgs)
            else:
                scored = _score_batch(backend, cfgs,
                                      [t.request for t in group])
            wall = (time.monotonic() - t0) / max(len(cfgs), 1)
            for t, s in zip(group, scored):
                self._complete(_result(t, s, wall))


class CallableServiceAdapter(ImmediateEvaluationService):
    """Legacy shim: any ``Callable[[Config], float]`` (or batch-capable
    evaluator object) as an :class:`EvaluationService`.  The one callable
    serves every fidelity — legacy objective functions know nothing of
    fidelity, so the field passes through to the result untouched."""

    def __init__(self, fn: Backend, default_fidelity: str = DEFAULT_FIDELITY):
        super().__init__(fn, default_fidelity)


class WorkerPoolEvaluationService(_BackendService):
    """The compiled product cluster as a service: a persistent worker pool
    scores one request per worker thread and streams completions *out of
    order* as they finish.  The compile path releases the GIL inside XLA,
    so distinct configs genuinely overlap; a worker that raises delivers a
    failed result, never an exception.  ``close()`` (or use as a context
    manager) shuts the pool down.

    ``deadline_s`` arms the hung-probe watchdog: a ticket whose worker has
    not completed within that many seconds is completed *by the watchdog*
    as a failed-transient result (``error_kind="transient"``) so
    ``gather``/``drain`` terminate instead of wedging behind one stuck
    benchmark.  The worker thread itself cannot be killed (Python threads
    are uninterruptible) — when it eventually finishes, its late result is
    dropped by the completion store's exactly-once guard — so a hung
    backend still occupies a pool slot until it returns; the watchdog
    bounds the *driver's* wait, not the worker's."""

    def __init__(self, backends: Backends, max_workers: int = 4,
                 default_fidelity: str = DEFAULT_FIDELITY,
                 deadline_s: Optional[float] = None):
        super().__init__(backends, default_fidelity)
        self.max_workers = max_workers
        self.deadline_s = deadline_s
        self.timed_out = 0              # watchdog-expired tickets (stats)
        self._pool = None
        self._pool_lock = threading.Lock()
        self._watchdogs: Dict[int, threading.Timer] = {}

    def _ensure_pool(self):
        from concurrent.futures import ThreadPoolExecutor
        with self._pool_lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    self.max_workers, thread_name_prefix="evalsvc")
            return self._pool

    def _dispatch(self, tickets: Sequence[EvalTicket]) -> None:
        for t in tickets:
            if self.deadline_s is not None:
                timer = threading.Timer(self.deadline_s, self._expire, (t,))
                timer.daemon = True
                with self._pool_lock:
                    self._watchdogs[t.uid] = timer
                timer.start()
            try:
                self._ensure_pool().submit(self._work, t)
            except RuntimeError as e:
                # racing close(): a ticket is never orphaned — gather/
                # drain on it must terminate, so it completes as failed
                self._cancel_watchdog(t.uid)
                self._complete(_result(t, _failed(e), 0.0))

    def _cancel_watchdog(self, uid: int) -> None:
        with self._pool_lock:
            timer = self._watchdogs.pop(uid, None)
        if timer is not None:
            timer.cancel()

    def _expire(self, ticket: EvalTicket):
        """Watchdog fired: the worker exceeded its deadline.  Complete
        the ticket as failed-transient (a hang is an infrastructure
        fault, not evidence the config is bad) — unless the worker beat
        the timer, in which case ``_complete`` drops this as a dup."""
        with self._pool_lock:
            self._watchdogs.pop(ticket.uid, None)
        with self._cv:
            live = ticket.uid in self._inflight
        if not live:
            return
        self.timed_out += 1
        err = TimeoutError(
            f"probe exceeded its {self.deadline_s}s deadline "
            "(hung worker?)")
        self._complete(replace(
            _result(ticket, _failed(err), float(self.deadline_s or 0.0)),
            error_kind="transient"))

    def _work(self, ticket: EvalTicket):
        t0 = time.monotonic()
        try:
            backend = self._backend(ticket.request.fidelity)
            scored = _score_one(backend, ticket.request.config,
                                ticket.request)
        except Exception as e:              # _backend KeyError and the like
            scored = _failed(e)
        self._cancel_watchdog(ticket.uid)
        self._complete(_result(ticket, scored, time.monotonic() - t0))

    def close(self):
        with self._pool_lock:
            pool, self._pool = self._pool, None
            watchdogs = list(self._watchdogs.values())
            self._watchdogs.clear()
        for timer in watchdogs:
            timer.cancel()
        if pool is not None:
            pool.shutdown(wait=True)


# ---------------------------------------------------------------------------
# composition: many services behind one, routed on the fidelity field
# ---------------------------------------------------------------------------

class FidelityRouter(_ServiceBase):
    """One service facade over per-fidelity services — e.g. an immediate
    analytic ``"screen"`` plus a worker-pooled compiled ``"promote"``.
    Each request is routed by its ``fidelity`` field; completions from
    every route stream back through this router's queue (a route delivers
    into the router, so a routed service should not be polled directly
    while attached).  ``close()`` detaches the routes (and leaves closing
    the underlying services to their owners)."""

    def __init__(self, routes: Mapping[str, _BackendService]):
        super().__init__()
        self.routes: Dict[str, _BackendService] = dict(routes)
        self._map: Dict[Tuple[int, int], EvalTicket] = {}
        self._map_lock = threading.Lock()
        self._sinks: Dict[int, Callable[[EvalResult], None]] = {}
        for svc in self.routes.values():
            sink = (lambda res, sid=id(svc): self._on_result(sid, res))
            self._sinks[id(svc)] = sink
            svc._sink = sink

    def submit(self, requests: Sequence[EvalRequest]) -> List[EvalTicket]:
        tickets = self._issue(requests)
        # issue on the route first, register the uid map, *then* dispatch:
        # an immediate route completes inside its dispatch call.  A
        # request with no route completes as a *failed* result — the
        # service contract (a bad request is a result, never an exception
        # or an orphaned ticket) — so gather/drain cannot deadlock on it.
        by_route: Dict[int, Tuple[_BackendService, List[int]]] = {}
        for i, r in enumerate(requests):
            svc = self.routes.get(r.fidelity)
            if svc is None:
                err = (f"no route for fidelity {r.fidelity!r}; "
                       f"routed: {tuple(sorted(self.routes))}")
                self._complete(EvalResult(tickets[i], float("nan"),
                                          "failed", False, None, err))
            else:
                by_route.setdefault(id(svc), (svc, []))[1].append(i)
        for svc, idxs in by_route.values():
            sub = svc._issue([requests[i] for i in idxs])
            with self._map_lock:
                for i, st in zip(idxs, sub):
                    self._map[(id(svc), st.uid)] = tickets[i]
            svc._dispatch(sub)
        return tickets

    def _on_result(self, sid: int, result: EvalResult):
        with self._map_lock:
            mine = self._map.pop((sid, result.ticket.uid), None)
        if mine is not None:
            self._complete(replace(result, ticket=mine))

    def close(self):
        for svc in self.routes.values():
            if svc._sink is self._sinks.get(id(svc)):
                svc._sink = None


# ---------------------------------------------------------------------------
# normalization at the Controller boundary
# ---------------------------------------------------------------------------

def as_service(obj) -> EvaluationService:
    """Normalize anything evaluator-shaped into an
    :class:`EvaluationService`: a service passes through; a backend that
    declares ``service_kind = "pool"`` (the compiled evaluator — seconds
    per call, GIL released inside XLA) gets a persistent worker pool; any
    other callable — the analytic evaluator, a bare objective function —
    completes immediately through :class:`CallableServiceAdapter`."""
    if isinstance(obj, EvaluationService):
        return obj
    if getattr(obj, "service_kind", "immediate") == "pool":
        deadline = getattr(obj, "deadline_s", None)
        return WorkerPoolEvaluationService(
            obj, max_workers=int(getattr(obj, "max_workers", 4)),
            deadline_s=None if deadline is None else float(deadline))
    if not callable(obj) and not hasattr(obj, "evaluate_batch"):
        raise TypeError(f"cannot adapt {type(obj).__name__} into an "
                        "EvaluationService (not callable, no evaluate_batch, "
                        "no submit/poll/gather/drain)")
    return CallableServiceAdapter(obj)

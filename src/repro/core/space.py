"""Typed configuration parameter space (paper §3.2, Table 1).

A :class:`Space` is an ordered collection of :class:`Knob` definitions plus
cross-knob :class:`Constraint` objects — the four constraint classes the
paper catalogues for Ceph:

  C1  unconfigurable knobs      -> ``Knob.configurable = False`` (washed out)
  C2  strict value boundaries   -> ``lo``/``hi`` (optionally ``dynamic``,
                                    i.e. the boundary may be enlarged by the
                                    optimizer — paper Fig. 4) and alignment
  C3  module-selector gating    -> ``gated_by = (selector_name, {values})``
  C4  inter-knob dependencies   -> Constraint objects (sum-, order-,
                                    divides-) enforced by projection

Knob values are plain Python scalars inside a *config*: ``Dict[str, value]``.
For the ML models every knob maps to a **unit interval** dimension
(log-scaled when flagged); categoricals are index-coded here and
dummy-coded by the ranking preprocessor (paper §3.3).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

Value = Union[int, float, bool, str]
Config = Dict[str, Value]


# ---------------------------------------------------------------------------
# knobs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Knob:
    name: str
    kind: str                      # "int" | "float" | "bool" | "categorical"
    default: Value
    lo: Optional[float] = None     # numeric bounds (C2); None for bool/cat
    hi: Optional[float] = None
    choices: Optional[Tuple[Value, ...]] = None   # categorical candidates
    log_scale: bool = False        # optimize in log space
    dynamic_bound: bool = False    # C2: boundary may be enlarged (Fig. 4)
    align: int = 1                 # int knobs: value must be multiple of this
    configurable: bool = True      # C1: False -> washed out
    gated_by: Optional[Tuple[str, Tuple[Value, ...]]] = None  # C3
    module: str = "core"           # owning subsystem (for pruning/reports)
    restart_required: bool = True  # False: runtime-injectable (data knobs)
    inert: bool = False            # ground truth for tests: no perf effect
    description: str = ""

    def __post_init__(self):
        if self.kind in ("int", "float"):
            assert self.lo is not None and self.hi is not None, self.name
            assert self.lo <= self.hi, self.name
            if self.log_scale:
                assert self.lo > 0, f"{self.name}: log scale needs lo>0"
        elif self.kind == "bool":
            pass
        elif self.kind == "categorical":
            assert self.choices, self.name
        else:
            raise ValueError(f"{self.name}: unknown kind {self.kind}")

    # ---- value handling ----------------------------------------------------

    def clip(self, v: Value) -> Value:
        if self.kind == "int":
            v = int(round(float(v)))
            if self.align > 1:
                v = int(round(v / self.align)) * self.align
            return int(min(max(v, self.lo), self.hi))
        if self.kind == "float":
            return float(min(max(float(v), self.lo), self.hi))
        if self.kind == "bool":
            return bool(v)
        if self.kind == "categorical":
            if v in self.choices:
                # canonical choice object (256.0 == 256 passes the `in`,
                # but the stored int is what configs should carry)
                return self.choices[self.choices.index(v)]
            # numeric choice sets (tiling ladders like 64/128/256) snap to
            # the nearest choice — constraint projection (ProductLeq's
            # halving) hands clip off-ladder values and a default-bounce
            # would teleport instead of shrink.  Ties go to the smaller
            # choice (projection shrinks).  Non-numeric sets keep the
            # default fallback.
            numeric = all(isinstance(c, (int, float, np.integer, np.floating))
                          and not isinstance(c, (bool, np.bool_))
                          for c in self.choices)
            if numeric and isinstance(v, (int, float, np.integer,
                                          np.floating)) \
                    and not isinstance(v, (bool, np.bool_)):
                return min(self.choices,
                           key=lambda c: (abs(float(c) - float(v)),
                                          float(c)))
            return self.default
        raise AssertionError

    def validate(self, v: Value) -> bool:
        if self.kind == "int":
            return (isinstance(v, (int, np.integer)) and self.lo <= v <= self.hi
                    and v % self.align == 0)
        if self.kind == "float":
            return isinstance(v, (int, float, np.floating)) and self.lo <= v <= self.hi
        if self.kind == "bool":
            return isinstance(v, (bool, np.bool_))
        return v in self.choices

    # ---- unit-cube encoding (for GP / SA / GA) ------------------------------

    def n_dims(self) -> int:
        return 1

    def to_unit(self, v: Value) -> float:
        if self.kind == "bool":
            return 1.0 if v else 0.0
        if self.kind == "categorical":
            i = self.choices.index(v)
            return i / max(len(self.choices) - 1, 1)
        lo, hi = float(self.lo), float(self.hi)
        if self.log_scale:
            lo, hi, v = math.log(lo), math.log(hi), math.log(max(float(v), 1e-300))
        if hi == lo:
            return 0.0
        return float((float(v) - lo) / (hi - lo))

    def from_unit(self, u: float) -> Value:
        u = min(max(float(u), 0.0), 1.0)
        if self.kind == "bool":
            return bool(u >= 0.5)
        if self.kind == "categorical":
            i = int(round(u * (len(self.choices) - 1)))
            return self.choices[i]
        lo, hi = float(self.lo), float(self.hi)
        if self.log_scale:
            v = math.exp(math.log(lo) + u * (math.log(hi) - math.log(lo)))
        else:
            v = lo + u * (hi - lo)
        return self.clip(v)

    def expanded(self, factor: float = 2.0) -> "Knob":
        """Dynamic-boundary enlargement (paper Fig. 4): widen [lo, hi]."""
        if self.kind not in ("int", "float") or not self.dynamic_bound:
            return self
        lo, hi = float(self.lo), float(self.hi)
        if self.log_scale:
            # clamp the log-span growth: repeated expansions otherwise
            # overflow exp() after ~a dozen boundary events
            span = min(math.log(hi) - math.log(lo), 80.0)
            lo = math.exp(max(math.log(lo) - span * (factor - 1) / 2, -80.0))
            hi = math.exp(min(math.log(hi) + span * (factor - 1) / 2, 80.0))
            lo = max(lo, 1e-12)
        else:
            span = hi - lo
            lo = lo - span * (factor - 1) / 2
            hi = min(hi + span * (factor - 1) / 2, 1e18)
        if self.kind == "int":
            lo, hi = math.floor(lo), math.ceil(hi)
            lo = max(lo, self.align)
        return replace(self, lo=lo, hi=hi)


# ---------------------------------------------------------------------------
# C4 constraints (value interdependencies)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Constraint:
    """Base for C4 inter-knob constraints."""
    knobs: Tuple[str, ...]

    def satisfied(self, cfg: Config) -> bool:  # pragma: no cover - interface
        raise NotImplementedError

    def project(self, cfg: Config, space: "Space") -> Config:
        """Minimally adjust ``cfg`` so the constraint holds."""
        raise NotImplementedError


@dataclass(frozen=True)
class SumLeq(Constraint):
    """sum(knobs) <= limit (e.g. bluestore cache ratios; HBM fractions)."""
    limit: float = 1.0

    def satisfied(self, cfg: Config) -> bool:
        return sum(float(cfg[k]) for k in self.knobs if k in cfg) <= self.limit + 1e-9

    def project(self, cfg: Config, space: "Space") -> Config:
        present = [k for k in self.knobs if k in cfg]
        total = sum(float(cfg[k]) for k in present)
        # same tolerance as satisfied(): keeps projection idempotent
        # (a bare > would rescale ULP-level overshoot forever)
        if total <= self.limit + 1e-9 or total == 0:
            return cfg
        # shrink only the headroom above each knob's lower bound — naive
        # uniform rescaling gets clipped back UP at lo and never converges
        out = dict(cfg)
        los = {k: float(space.knob(k).lo or 0.0) for k in present}
        lo_sum = sum(los.values())
        head = {k: float(cfg[k]) - los[k] for k in present}
        head_sum = sum(head.values())
        if head_sum <= 0 or self.limit < lo_sum:
            return out                         # infeasible box; leave as-is
        alpha = (self.limit - lo_sum) / head_sum
        for k in present:
            out[k] = space.knob(k).clip(los[k] + head[k] * min(alpha, 1.0))
        return out


@dataclass(frozen=True)
class Leq(Constraint):
    """knobs[0] <= knobs[1]  (e.g. ms_async_op_threads <= max_op_threads)."""

    def satisfied(self, cfg: Config) -> bool:
        a, b = self.knobs
        if a not in cfg or b not in cfg:
            return True
        return float(cfg[a]) <= float(cfg[b]) + 1e-9

    def project(self, cfg: Config, space: "Space") -> Config:
        a, b = self.knobs
        if a not in cfg or b not in cfg or self.satisfied(cfg):
            return cfg
        out = dict(cfg)
        out[a] = space.knob(a).clip(float(cfg[b]))
        return out


@dataclass(frozen=True)
class Divides(Constraint):
    """knobs[0] divides knobs[1] (e.g. microbatch divides per-replica batch).

    knobs[1] may name a knob or be pinned via ``target`` (a fixed int from
    the workload, e.g. global batch per replica).
    """
    target: Optional[int] = None

    def _rhs(self, cfg: Config) -> Optional[int]:
        if self.target is not None:
            return int(self.target)
        if len(self.knobs) > 1 and self.knobs[1] in cfg:
            return int(cfg[self.knobs[1]])
        return None

    def satisfied(self, cfg: Config) -> bool:
        a = self.knobs[0]
        rhs = self._rhs(cfg)
        if a not in cfg or rhs is None:
            return True
        v = int(cfg[a])
        return v != 0 and rhs % v == 0

    def project(self, cfg: Config, space: "Space") -> Config:
        a = self.knobs[0]
        rhs = self._rhs(cfg)
        if a not in cfg or rhs is None or self.satisfied(cfg):
            return cfg
        v = max(int(cfg[a]), 1)
        # snap to the nearest divisor of rhs
        divisors = [d for d in range(1, rhs + 1) if rhs % d == 0]
        knob = space.knob(a)
        valid = [d for d in divisors if knob.lo <= d <= knob.hi] or divisors
        best = min(valid, key=lambda d: abs(d - v))
        out = dict(cfg)
        out[a] = int(best)
        return out


@dataclass(frozen=True)
class ProductLeq(Constraint):
    """prod(knobs) <= limit (e.g. flash block_q*block_k VMEM budget)."""
    limit: float = float("inf")

    def satisfied(self, cfg: Config) -> bool:
        p = 1.0
        for k in self.knobs:
            if k in cfg:
                p *= float(cfg[k])
        return p <= self.limit + 1e-9

    def project(self, cfg: Config, space: "Space") -> Config:
        if self.satisfied(cfg):
            return cfg
        out = dict(cfg)
        # shrink the largest factor until the budget holds
        for _ in range(64):
            p = 1.0
            for k in self.knobs:
                if k in out:
                    p *= float(out[k])
            if p <= self.limit:
                break
            big = max((k for k in self.knobs if k in out), key=lambda k: float(out[k]))
            knob = space.knob(big)
            shrunk = float(out[big]) / 2
            nxt = knob.clip(shrunk)
            if float(nxt) >= float(out[big]):  # cannot shrink further
                break
            out[big] = nxt
        return out


# ---------------------------------------------------------------------------
# the space
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Space:
    knobs: Tuple[Knob, ...]
    constraints: Tuple[Constraint, ...] = ()

    def __post_init__(self):
        names = [k.name for k in self.knobs]
        assert len(names) == len(set(names)), "duplicate knob names"

    # ---- lookups ------------------------------------------------------------

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(k.name for k in self.knobs)

    def knob(self, name: str) -> Knob:
        for k in self.knobs:
            if k.name == name:
                return k
        raise KeyError(name)

    def __len__(self) -> int:
        return len(self.knobs)

    def subset(self, names: Sequence[str]) -> "Space":
        """Keep only ``names`` (plus constraints fully inside the subset)."""
        keep = set(names)
        knobs = tuple(k for k in self.knobs if k.name in keep)
        cons = tuple(c for c in self.constraints
                     if all(k in keep for k in c.knobs))
        return Space(knobs, cons)

    def with_knob(self, new: Knob) -> "Space":
        return Space(tuple(new if k.name == new.name else k for k in self.knobs),
                     self.constraints)

    # ---- defaults / projection ----------------------------------------------

    def default_config(self) -> Config:
        return {k.name: k.default for k in self.knobs}

    def completer(self, base: Optional[Config] = None):
        """Map a sub-space config onto this full space: start from
        ``base`` (default: this space's defaults), overlay the given
        knobs, project onto the clean domain.  The standard
        ``Controller.prepare`` hook for top-K search — non-top knobs
        stay pinned at their defaults inside every evaluation.

        Projection clips to THIS space's bounds: when the search may
        enlarge dynamic boundaries (paper Fig. 4), complete through
        ``full.overlaid(strategy.space).completer()`` instead, so enlarged
        probes reach the evaluator unclipped (see Sapphire.search_stage).
        """
        base_cfg = dict(base) if base is not None else self.default_config()

        def complete(cfg: Config) -> Config:
            full = dict(base_cfg)
            full.update(cfg)
            return self.project(full)
        return complete

    def overlaid(self, sub: "Space") -> "Space":
        """This space with matching knobs replaced by ``sub``'s versions
        — e.g. dynamic-boundary-enlarged top-K knobs, so projection
        respects the enlarged bounds."""
        sp = self
        for k in sub.knobs:
            if k.name in self.names:
                sp = sp.with_knob(k)
        return sp

    def project(self, cfg: Config) -> Config:
        """Clip to bounds, enforce gating (C3) and constraints (C4)."""
        out: Config = {}
        for k in self.knobs:
            v = cfg.get(k.name, k.default)
            out[k.name] = k.clip(v)
        # C3: gated knobs whose selector is not at an enabling value are
        # pinned to their default (they would be ignored by the system, but
        # pinning keeps the search space honest).
        for k in self.knobs:
            if k.gated_by is None:
                continue
            sel, enabling = k.gated_by
            if sel in out and out[sel] not in enabling:
                out[k.name] = k.default
        for c in self.constraints:
            out = c.project(out, self)
        return out

    def validate(self, cfg: Config) -> List[str]:
        """Return list of violation messages (empty = clean)."""
        errs = []
        for k in self.knobs:
            if k.name not in cfg:
                errs.append(f"missing {k.name}")
            elif not k.validate(cfg[k.name]):
                errs.append(f"bad value {k.name}={cfg[k.name]!r}")
        for c in self.constraints:
            if not c.satisfied(cfg):
                errs.append(f"violated {type(c).__name__}{c.knobs}")
        return errs

    def is_active(self, name: str, cfg: Config) -> bool:
        """C3: does this knob currently take effect?"""
        k = self.knob(name)
        if k.gated_by is None:
            return True
        sel, enabling = k.gated_by
        return cfg.get(sel) in enabling

    # ---- unit-cube encode/decode ---------------------------------------------

    def to_unit(self, cfg: Config) -> np.ndarray:
        return np.array([k.to_unit(cfg[k.name]) for k in self.knobs], np.float64)

    def from_unit(self, u: np.ndarray) -> Config:
        cfg = {k.name: k.from_unit(u[i]) for i, k in enumerate(self.knobs)}
        return self.project(cfg)

    # ---- batched encode/decode (vectorized across configs) --------------------

    def encode_batch(self, configs: Sequence[Config]) -> np.ndarray:
        """Vectorized :meth:`to_unit`: n configs -> ``[n, d]`` unit matrix.

        One numpy expression per knob (the batch axis is the long one);
        matches ``to_unit`` row-by-row exactly.
        """
        n = len(configs)
        u = np.zeros((n, len(self.knobs)), np.float64)
        for j, k in enumerate(self.knobs):
            vals = [c[k.name] for c in configs]
            if k.kind == "bool":
                u[:, j] = np.fromiter((1.0 if v else 0.0 for v in vals),
                                      np.float64, n)
            elif k.kind == "categorical":
                idx = {c: i for i, c in enumerate(k.choices)}
                denom = max(len(k.choices) - 1, 1)
                u[:, j] = np.fromiter((idx[v] for v in vals),
                                      np.float64, n) / denom
            else:
                x = np.asarray([float(v) for v in vals], np.float64)
                lo, hi = float(k.lo), float(k.hi)
                if k.log_scale:
                    lo, hi = math.log(lo), math.log(hi)
                    x = np.log(np.maximum(x, 1e-300))
                if hi != lo:
                    u[:, j] = (x - lo) / (hi - lo)
        return u

    def decode_batch(self, u: np.ndarray, project: bool = True) -> List[Config]:
        """Vectorized :meth:`from_unit`: ``[n, d]`` unit matrix -> n configs.

        The unit->value map runs as one numpy expression per knob; the
        C3/C4 projection (dict-shaped constraint logic) then runs per
        config.  Matches ``from_unit`` row-by-row (bit-exact except
        log-scaled floats, where vectorized exp may differ by 1 ulp).
        """
        u = np.asarray(u, np.float64)
        cols: List[list] = []
        for j, k in enumerate(self.knobs):
            c = np.clip(u[:, j], 0.0, 1.0)
            if k.kind == "bool":
                cols.append([bool(b) for b in c >= 0.5])
            elif k.kind == "categorical":
                idx = np.rint(c * (len(k.choices) - 1)).astype(int)
                cols.append([k.choices[i] for i in idx])
            else:
                lo, hi = float(k.lo), float(k.hi)
                if k.log_scale:
                    v = np.exp(math.log(lo) + c * (math.log(hi) - math.log(lo)))
                else:
                    v = lo + c * (hi - lo)
                if k.kind == "int":
                    v = np.rint(v)
                    if k.align > 1:
                        v = np.rint(v / k.align) * k.align
                    v = np.minimum(np.maximum(v, lo), hi)
                    cols.append([int(x) for x in v])
                else:
                    v = np.minimum(np.maximum(v, lo), hi)
                    cols.append([float(x) for x in v])
        names = self.names
        cfgs = [dict(zip(names, row)) for row in zip(*cols)]
        if not project:
            return cfgs
        return self.project_batch(cfgs, clip=False)   # decode already clipped

    def project_batch(self, configs: Sequence[Config],
                      clip: bool = True) -> List[Config]:
        """Batched :meth:`project`: bound-clipping vectorized per knob, then
        the per-config C3 gating and C4 constraint passes."""
        outs: List[Config]
        if clip:
            cols: List[list] = []
            for k in self.knobs:
                vals = [c.get(k.name, k.default) for c in configs]
                if k.kind == "int":
                    v = np.rint([float(x) for x in vals])
                    if k.align > 1:
                        v = np.rint(v / k.align) * k.align
                    v = np.minimum(np.maximum(v, float(k.lo)), float(k.hi))
                    cols.append([int(x) for x in v])
                elif k.kind == "float":
                    v = np.minimum(np.maximum(
                        np.asarray([float(x) for x in vals]),
                        float(k.lo)), float(k.hi))
                    cols.append([float(x) for x in v])
                elif k.kind == "bool":
                    cols.append([bool(x) for x in vals])
                else:
                    # same nearest-snap semantics as Knob.clip
                    cols.append([x if x in k.choices else k.clip(x)
                                 for x in vals])
            names = self.names
            outs = [dict(zip(names, row)) for row in zip(*cols)]
        else:
            outs = [dict(c) for c in configs]
        for out in outs:
            for k in self.knobs:
                if k.gated_by is None:
                    continue
                sel, enabling = k.gated_by
                if sel in out and out[sel] not in enabling:
                    out[k.name] = k.default
            for c in self.constraints:
                new = c.project(out, self)
                if new is not out:
                    out.update(new)
        return outs

    # ---- dynamic boundary (paper Fig. 4) --------------------------------------

    def near_boundary(self, cfg: Config, tol: float = 0.05) -> List[str]:
        """Knobs whose value sits within ``tol`` of a dynamic bound."""
        out = []
        for k in self.knobs:
            if not k.dynamic_bound or k.kind not in ("int", "float"):
                continue
            u = k.to_unit(cfg[k.name])
            if u <= tol or u >= 1 - tol:
                out.append(k.name)
        return out

    def expand_boundaries(self, names: Sequence[str], factor: float = 2.0) -> "Space":
        sp = self
        for n in names:
            sp = sp.with_knob(sp.knob(n).expanded(factor))
        return sp


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def pow2_knob(name: str, default: int, lo: int, hi: int, **kw) -> Knob:
    """A categorical knob over the power-of-two ladder [lo, hi] — the
    natural shape of kernel tiling parameters (block sizes, chunk widths,
    warp counts).  The numeric choice set means :meth:`Knob.clip` snaps
    off-ladder values (e.g. a halved ProductLeq projection) to the
    nearest rung instead of bouncing to the default."""
    assert lo > 0 and lo & (lo - 1) == 0, f"{name}: lo not a power of two"
    assert hi >= lo and hi & (hi - 1) == 0, f"{name}: hi not a power of two"
    choices = []
    v = lo
    while v <= hi:
        choices.append(v)
        v *= 2
    assert default in choices, f"{name}: default {default} off the ladder"
    return Knob(name, "categorical", default, choices=tuple(choices), **kw)

"""Ask/tell search strategies — the paper's Search Unit with control inverted.

The paper's Fig. 3 separates the *Search Unit* (which configs to try next)
from the *Experiment Unit* (how to measure them).  BestConfig (Zhu et al.,
2017) and Magpie (Zhu et al., 2022) frame tuning the same way: a pluggable
search algorithm behind a fixed experiment-driver interface.  This module
is that interface:

    strategy = make_strategy("bo", space, cfg=BOConfig(...))
    while not strategy.finished:
        probes = strategy.ask()          # never calls an objective
        values = <measure probes however you like>
        strategy.tell(probes, values)
    best_config, best_value = strategy.best()

A :class:`SearchStrategy` proposes configs (``ask``) and learns from
results (``tell``) but *never* evaluates anything — the experiment loops
(:meth:`repro.core.controller.Controller.run` and the overlapped
:meth:`~repro.core.controller.Controller.run_async`) own evaluation,
batching, the evaluation DB, and fidelity scheduling.  ``tell`` accepts
partial and out-of-order batches: the async controller streams results in
as workers finish, successive halving promotes only a screened subset,
and warm-start history injects observations the strategy never asked
for — injected observations extend the trace but do not consume the
search budget.  Asked-but-untold probes *do* count against the budget, so
an async driver that keeps many probes in flight cannot overshoot it.

Four strategies re-express the previous closed-loop optimizers:

* :class:`BOStrategy`     — GP-BO with constant-liar q-EI, warm-started
  hyperparameters and dynamic boundary enlargement (paper §3.4, Fig. 4);
* :class:`RandomStrategy` — LHS design (the sanity floor, and the ranking
  phase's sampler);
* :class:`AnnealingStrategy` — memoryless Metropolis walk (§3.4 critique);
* :class:`GeneticStrategy`   — population evolution (§3.4 critique).

Each reproduces the evaluation trace of its legacy closed-loop counterpart
bit for bit under the same seed and batch schedule (guarded by
``tests/test_strategy.py``); ``bo.minimize`` and the ``optimizers.py``
functions survive as thin deprecated wrappers over these classes.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import (Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, Union, runtime_checkable)

import numpy as np

from repro.core import gp
from repro.core.sampling import init_design, latin_hypercube, lhs_unit
from repro.core.space import Config, Space


# ---------------------------------------------------------------------------
# the evaluation trace (shared by every strategy; formerly bo.BOTrace)
# ---------------------------------------------------------------------------

@dataclass
class Trace:
    configs: List[Config] = field(default_factory=list)
    values: List[float] = field(default_factory=list)
    best_values: List[float] = field(default_factory=list)   # running min
    boundary_events: List[Tuple[int, str]] = field(default_factory=list)
    # per-observation measurement variance (variance of the reported mean,
    # from replicated measurements); 0.0 = "no empirical noise estimate" —
    # the GP then falls back to its fitted global noise scalar for the row
    variances: List[float] = field(default_factory=list)

    @property
    def best(self) -> Tuple[Config, float]:
        i = int(np.argmin(self.values))
        return self.configs[i], self.values[i]

    def extend(self, configs: Sequence[Config], values: Sequence[float],
               variances: Optional[Sequence[float]] = None):
        if variances is None:
            variances = [0.0] * len(configs)
        for c, v, var in zip(configs, values, variances):
            self.configs.append(c)
            self.values.append(float(v))
            self.variances.append(float(var))
            self.best_values.append(min(self.best_values[-1], float(v))
                                    if self.best_values else float(v))


# ---------------------------------------------------------------------------
# strategy configs
# ---------------------------------------------------------------------------

@dataclass
class BOConfig:
    n_init: int = 8                 # initial LHS design
    n_iter: int = 48                # BO evaluations after the design
    batch_size: int = 1             # q: probes per GP refit (constant-liar
                                    # q-EI); 1 = the classic sequential loop
    n_candidates: int = 2048        # acquisition candidates per iteration
    n_local: int = 256              # perturbations around the incumbent
    local_sigma: float = 0.08
    kernel: str = "matern52"
    fit_steps: int = 150
    fit_steps_warm: Optional[int] = None   # Adam steps on warm-started
                                           # rounds (None: fit_steps // 3)
    warm_start: bool = False        # reuse GP hyperparams across rounds.
                                    # Off by default so sequential callers
                                    # keep the paper's full refit-per-eval
                                    # loop; Sapphire turns it on whenever
                                    # batching is requested
    acquisition: str = "ei"         # ei | ucb
    log_objective: bool = True      # model log(y): heavy-tailed penalties
                                    # (OOM probes) otherwise flatten the GP
    fantasy: str = "liar"           # q-batch fantasy value: "liar"
                                    # (constant liar at the incumbent best
                                    # — matches the sequential optimum
                                    # within noise on every seed tried) |
                                    # "believer" (Kriging believer —
                                    # posterior mean at the pick)
    dynamic_boundary: bool = True
    boundary_tol: float = 0.05
    boundary_factor: float = 2.0
    boundary_damping: bool = True   # k knobs triggering in ONE round each
                                    # expand by factor**(1/k): a wide async
                                    # wave inflates the domain volume by at
                                    # most `boundary_factor` per round
                                    # instead of factor**k
    use_pallas: bool = False        # route Gram builds and candidate
                                    # scoring through the kernels/gp_gram
                                    # Pallas tile kernel (matern52; jnp
                                    # fallback elsewhere)
    refit_async: bool = False       # marginal-likelihood refit on a
                                    # background executor over a snapshot
                                    # of the trace: ask() never blocks on
                                    # the Adam loop, selection runs against
                                    # the last *completed* posterior
    shard_candidates: Union[bool, int] = False
                                    # score the candidate pool sharded over
                                    # host devices (gp.select_batch_sharded;
                                    # True: all devices, int: that many).
                                    # Picks are bit-identical to the
                                    # single-device path at equal pool; on
                                    # a 1-device host this falls back to
                                    # plain select_batch
    refit_device: Optional[int] = None
                                    # pin the refit_async background fit to
                                    # jax.devices()[i] (None: the spare
                                    # device when >1 exists, else share)
    seed: int = 0


@dataclass
class SAConfig:
    t0: float = 1.0           # initial temperature (in units of objective std)
    cooling: float = 0.93     # geometric cooling per step
    sigma: float = 0.12       # proposal stddev in unit cube
    seed: int = 0


@dataclass
class GAConfig:
    population: int = 8
    elite: int = 2
    tournament: int = 3
    crossover_p: float = 0.5
    mutation_sigma: float = 0.1
    mutation_p: float = 0.25
    seed: int = 0


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------

@runtime_checkable
class SearchStrategy(Protocol):
    """What the experiment loop needs from a search algorithm."""

    space: Space                 # current domain (BO may enlarge it)
    trace: Trace                 # every observation told so far

    @property
    def finished(self) -> bool:  # search budget fully observed
        ...

    def ask(self, n: Optional[int] = None) -> List[Config]:
        """Propose up to ``n`` configs to evaluate (``None``: the
        strategy's preferred batch).  May return fewer — or ``[]`` when
        the budget is exhausted or the strategy is blocked on ``tell``."""
        ...

    def tell(self, configs: Sequence[Config], values: Sequence[float],
             variances: Optional[Sequence[float]] = None) -> None:
        """Report results.  Partial batches, out-of-order results and
        never-asked (injected) observations are all accepted.
        ``variances`` carries per-observation measurement variance from
        replicated measurements (0.0 = no estimate); strategies that
        cannot use it store it in the trace and ignore it."""
        ...

    def best(self) -> Tuple[Config, float]:
        ...


def _config_key(cfg: Config) -> Tuple:
    """Canonical hashable key with dict-equality semantics.  Numpy scalars
    hash and compare like their Python values, and knob names are unique
    within a config, so the sort never compares two values."""
    return tuple(sorted(cfg.items(), key=lambda kv: kv[0]))


def _json_cfg(cfg: Config) -> Config:
    """JSON-safe copy of a config (numpy scalars to Python values)."""
    out = {}
    for k, v in cfg.items():
        if isinstance(v, np.integer):
            v = int(v)
        elif isinstance(v, np.floating):
            v = float(v)
        elif isinstance(v, np.bool_):
            v = bool(v)
        out[k] = v
    return out


class _PendingSet:
    """Asked-but-untold probes keyed by canonical config tuple.

    The legacy bookkeeping was ``list.remove`` with dict equality —
    O(pending) dict comparisons per told probe, so a q-wide async wave
    cost O(q·n).  Keyed FIFO buckets make the whole wave O(q).  An
    optional payload rides along with each entry (the genetic strategy
    keys its population index this way)."""

    def __init__(self):
        self._buckets: Dict[Tuple, List] = {}
        self._n = 0

    def add(self, cfg: Config, payload=None) -> None:
        self._buckets.setdefault(_config_key(cfg), []).append(payload)
        self._n += 1

    def pop(self, cfg: Config) -> Tuple[bool, Optional[object]]:
        """Remove the oldest pending entry equal to ``cfg``; returns
        ``(matched, payload)`` — ``(False, None)`` when nothing matches
        (an injected observation)."""
        key = _config_key(cfg)
        bucket = self._buckets.get(key)
        if not bucket:
            return False, None
        payload = bucket.pop(0)
        if not bucket:
            del self._buckets[key]
        self._n -= 1
        return True, payload

    def __len__(self) -> int:
        return self._n

    def __bool__(self) -> bool:
        return self._n > 0


class _StrategyBase:
    """Trace + pending-probe bookkeeping shared by every strategy."""

    def __init__(self, space: Space):
        self.space = space
        self.trace = Trace()
        self._pending = _PendingSet()

    def best(self) -> Tuple[Config, float]:
        if not self.trace.values:
            raise RuntimeError(f"{type(self).__name__}: no observations yet")
        return self.trace.best

    def _match_pending(self, cfg: Config) -> bool:
        matched, _ = self._pending.pop(cfg)
        return matched


# ---------------------------------------------------------------------------
# GP-BO (paper §3.4, Fig. 4) as an ask/tell strategy
# ---------------------------------------------------------------------------

def _acq(state, cand_u, best_y, cfg: BOConfig) -> np.ndarray:
    if cfg.acquisition == "ei":
        a = gp.expected_improvement(state, cand_u, best_y, cfg.kernel)
    else:
        a = gp.ucb(state, cand_u, cfg.kernel)
    return np.array(a)      # writable copy (jax buffers are read-only)


def _select_batch(state, cand: np.ndarray, best_y: float, q: int,
                  cfg: BOConfig, x: np.ndarray, y: np.ndarray,
                  pad_to: Optional[int]) -> List[np.ndarray]:
    """Fantasized q-EI: argmax over the pool, fantasize the pick's
    outcome, recondition the posterior (fixed hyperparams, one Cholesky),
    repeat.  EI collapses at the fantasized probe — via the variance for
    the Kriging believer, via the mean for the constant liar — so later
    picks spread over the pool instead of stacking on the first argmax.

    LEGACY REFERENCE PATH: q jit dispatches, q host argmax round trips
    and q O(n³) Cholesky rebuilds per batch.  :class:`BOStrategy` now
    selects through the device-resident :func:`repro.core.gp.select_batch`
    (one compiled ``lax.scan``, O(n²) incremental Cholesky appends); this
    loop remains as the oracle the equivalence tests and the
    ``perf_gp_ask`` benchmark compare against."""
    cand32 = cand.astype(np.float32)
    taken = np.zeros(len(cand), bool)
    picks: List[np.ndarray] = []
    x_aug, y_aug = x, y
    for j in range(q):
        a = _acq(state, cand32, best_y, cfg)
        a[taken] = -np.inf
        i = int(np.argmax(a))
        taken[i] = True
        picks.append(cand[i])
        if j < q - 1:
            if cfg.fantasy == "believer":
                mu, _ = gp.predict(state, cand32[i][None], cfg.kernel)
                lie = float(mu[0])
            else:
                lie = best_y
            x_aug = np.vstack([x_aug, cand[i][None]])
            y_aug = np.append(y_aug, lie)
            state = gp.condition(state.params, x_aug, y_aug, cfg.kernel,
                                 pad_to=pad_to)
    return picks


class BOStrategy(_StrategyBase):
    """GP surrogate + dynamic boundaries, inverted into ask/tell.

    ``ask`` serves the initial LHS design first, then per round: fit the
    GP to the whole trace (hyperparameters warm-started when configured),
    select a q-EI batch through the device-resident
    :func:`repro.core.gp.select_batch` (one compiled program: EI scoring,
    masked argmax and O(n²) incremental-Cholesky fantasy appends for all
    q picks), enlarge any ``dynamic_bound`` boundary a probe is near
    (paper Fig. 4, volume-damped when several knobs trigger at once), and
    return the probes.  ``cfg.n_iter`` counts evaluations after the
    design, so the experiment budget is identical for every batch width;
    asked-but-untold probes count against the budget so an async driver
    cannot overshoot it.

    With ``cfg.refit_async`` the marginal-likelihood refit runs on a
    background executor over a snapshot of the trace: ``ask`` selects
    against the last *completed* posterior and never blocks on the Adam
    loop (only the first post-design ask fits synchronously — there is no
    posterior to reuse yet).  The async experiment loop then submits new
    waves at evaluation speed regardless of ``fit_steps``.  Candidates
    are drawn in the *current* space while the posterior may predate a
    boundary expansion — the same approximation the constant liar already
    makes, traded for never idling the cluster.  When a round's own
    expansion fires, the snapshot handed to the background fit is
    re-encoded in the enlarged space first (the trace's unit-cube
    coordinates just moved).  On a multi-device host the background fit
    is pinned to the spare device (``cfg.refit_device`` overrides), so
    its Adam dispatches never contend with selection.  :meth:`close`
    joins the executor (the strategy stays usable afterwards).

    ``cfg.shard_candidates`` scores the candidate pool sharded over the
    host's devices (:func:`repro.core.gp.select_batch_sharded`) — picks
    stay bit-identical to the single-device path at equal pool, so the
    gate only changes wall-clock, never the trace.
    """

    def __init__(self, space: Space, cfg: Optional[BOConfig] = None,
                 init_configs: Optional[List[Config]] = None):
        super().__init__(space)
        self.cfg = cfg or BOConfig()
        self.rng = np.random.default_rng(self.cfg.seed)
        # the base space's numeric bounds, before any dynamic expansion —
        # the identity a state snapshot must match to be loadable here
        self._base_bounds = {k.name: (float(k.lo), float(k.hi))
                             for k in space.knobs
                             if k.kind in ("int", "float")}
        self._init_queue = init_design(space, self.cfg.n_init, self.rng,
                                       init_configs)
        self._n_init = len(self._init_queue)
        self._pending_init = _PendingSet()
        self._params = None                  # warm-start carry
        self._pad_to: Optional[int] = None   # budget-pinned jit shape
        self._evals_done = 0                 # told post-init evaluations
        # refit_async machinery (all driver-thread state except the
        # executor's own worker; the background task is a pure gp.fit)
        self._posterior = None               # (state, x, y) last completed
        self._refit_future = None
        self._refit_snapshot = None          # (x, y) the in-flight fit sees
        self._refit_len = 0                  # trace length it was given
        self._refit_pool = None
        self._space_version = 0              # bumped by boundary expansion
        self._refit_space_version = 0        # space the last fit was given

    @property
    def finished(self) -> bool:
        return (not self._init_queue and not self._pending_init
                and self._evals_done >= self.cfg.n_iter)

    # -- GP fitting (sync + background) ---------------------------------------

    def _fit_args(self):
        cfg = self.cfg
        steps = cfg.fit_steps
        warm = None
        if cfg.warm_start and self._params is not None:
            warm = self._params
            steps = (cfg.fit_steps_warm if cfg.fit_steps_warm is not None
                     else max(cfg.fit_steps // 3, 20))
        return warm, steps

    def _fit_gp(self, x: np.ndarray, y: np.ndarray,
                obs_var: Optional[np.ndarray] = None):
        warm, steps = self._fit_args()
        cfg = self.cfg
        return gp.fit(x, y, cfg.kernel, steps=steps, params=warm,
                      pad_to=self._pad_to, use_pallas=cfg.use_pallas,
                      obs_var=obs_var)

    def _refit(self, x: np.ndarray, y: np.ndarray,
               obs_var: Optional[np.ndarray] = None):
        """refit_async: harvest a landed background fit and return the
        last completed posterior *with the data it was fitted on* —
        fantasy appends must extend the matrix the Cholesky factors.
        The first post-design round fits synchronously (nothing to select
        against yet)."""
        fut = self._refit_future
        if fut is not None and fut.done():
            self._refit_future = None
            state = fut.result()            # a failed fit surfaces here
            self._posterior = (state,) + self._refit_snapshot[:2]
            self._params = state.params
        if self._posterior is None:
            state = self._fit_gp(x, y, obs_var)
            self._params = state.params
            self._posterior = (state, x, y)
            self._refit_len = len(self.trace.values)
            self._refit_space_version = self._space_version
        return self._posterior

    def _refit_device(self):
        """Device the background fit is pinned to: ``cfg.refit_device``
        when set, else the spare device (off the driver's dispatch queue)
        when the host has more than one, else ``None`` (share the single
        device; the fit still only thread-yields, never blocks ask)."""
        import jax
        if self.cfg.refit_device is not None:
            devs = jax.devices()
            return devs[self.cfg.refit_device % len(devs)]
        from repro.parallel.sharding import spare_device
        return spare_device()

    def _fit_background(self, x: np.ndarray, y: np.ndarray, steps: int,
                        warm, obs_var: Optional[np.ndarray] = None):
        """The executor task: a pure gp.fit, pinned via
        ``jax.default_device`` to the spare device so the Adam loop's
        dispatches never queue in front of the driver's selection work,
        with the finished posterior handed back to the driver's device."""
        cfg = self.cfg
        dev = self._refit_device()
        if dev is None:
            return gp.fit(x, y, cfg.kernel, steps=steps, params=warm,
                          pad_to=self._pad_to, use_pallas=cfg.use_pallas,
                          obs_var=obs_var)
        import jax
        with jax.default_device(dev):
            state = gp.fit(x, y, cfg.kernel, steps=steps, params=warm,
                           pad_to=self._pad_to, use_pallas=cfg.use_pallas,
                           obs_var=obs_var)
        home = jax.devices()[0]
        return jax.tree.map(lambda a: jax.device_put(a, home), state)

    def _refit_kick(self, x: np.ndarray, y: np.ndarray,
                    obs_var: Optional[np.ndarray] = None):
        """Kick a background refit on the (x, y) snapshot when fresh
        observations arrived — or when boundary expansion re-encoded the
        trace (same observation count, different inputs).  Called at the
        END of ask — after the selection's device work has completed — so
        on a single shared accelerator the refit's computation queues
        behind this round's selection, never in front of the next one the
        driver is about to dispatch."""
        if self._refit_future is not None:
            return
        if (len(self.trace.values) <= self._refit_len
                and self._refit_space_version == self._space_version):
            return
        warm, steps = self._fit_args()
        self._refit_len = len(self.trace.values)
        self._refit_space_version = self._space_version
        self._refit_snapshot = (x, y, obs_var)
        if self._refit_pool is None:
            from concurrent.futures import ThreadPoolExecutor
            self._refit_pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="gp-refit")
        self._refit_future = self._refit_pool.submit(
            self._fit_background, x, y, steps, warm, obs_var)

    def close(self):
        """Join the background refit executor (refit_async mode).  An
        in-flight fit is waited out and discarded; the strategy remains
        usable — a later ask() restarts the executor."""
        pool, self._refit_pool = self._refit_pool, None
        self._refit_future = None
        if pool is not None:
            pool.shutdown(wait=True)

    # -- dynamic boundary (paper Fig. 4) --------------------------------------

    def _expand_near(self, probes: Sequence[Config]) -> List[str]:
        """Enlarge every dynamic bound a probe is near, once over the
        whole batch.  With ``boundary_damping``, k simultaneous events
        expand each knob by ``factor**(1/k)`` — k knobs at the full
        factor would multiply the domain volume by factor**k in a single
        round, over-inflating it exactly when wide async waves coalesce."""
        cfg = self.cfg
        if not cfg.dynamic_boundary:
            return []
        near: List[str] = []
        for probe in probes:
            for name in self.space.near_boundary(probe, cfg.boundary_tol):
                if name not in near:
                    near.append(name)
        if near:
            factor = cfg.boundary_factor
            if cfg.boundary_damping and len(near) > 1:
                factor = factor ** (1.0 / len(near))
            self.space = self.space.expand_boundaries(near, factor)
            self._space_version += 1
            at = self._evals_done + len(self._pending)
            for name in near:
                self.trace.boundary_events.append((at, name))
        return near

    # -- sharded candidate scoring --------------------------------------------

    def _shard_devices(self):
        """Devices for sharded candidate scoring, or ``None`` for the
        single-device path (gate off, or nothing to shard over)."""
        sc = self.cfg.shard_candidates
        if not sc:
            return None
        from repro.parallel.sharding import pool_devices
        devs = pool_devices(None if sc is True else int(sc))
        return devs if len(devs) > 1 else None

    # -- GP training set (overridable) ----------------------------------------

    def _training_data(self) -> Tuple[List[Config], List[float],
                                      List[float]]:
        """The rows the GP is fitted on: ``(configs, values, variances)``
        in *raw* objective units.  The base strategy trains on exactly the
        trace; :class:`repro.transfer.TransferBOStrategy` overrides this
        to append prior pseudo-observations — rows the GP sees but the
        trace (and therefore :meth:`best` and the budget) never does.
        The default must stay the trace verbatim: equal lists in, equal
        posterior out is what keeps the empty-corpus transfer path
        trace-identical to plain BO."""
        return self.trace.configs, self.trace.values, self.trace.variances

    def ask(self, n: Optional[int] = None) -> List[Config]:
        # -- initial design ---------------------------------------------------
        if self._init_queue:
            k = len(self._init_queue) if n is None \
                else max(min(n, len(self._init_queue)), 1)
            chunk, self._init_queue = (self._init_queue[:k],
                                       self._init_queue[k:])
            out = [dict(c) for c in chunk]
            for c in out:
                self._pending_init.add(c)
            return out
        if not self.trace.values:
            return []                        # blocked: nothing observed yet

        # -- one BO round -----------------------------------------------------
        remaining = self.cfg.n_iter - self._evals_done - len(self._pending)
        if remaining <= 0:
            return []
        q = max(min(n if n is not None else self.cfg.batch_size,
                    remaining), 1)
        if self._pad_to is None:
            # fix the padded GP shape for the whole run: every jit (fit
            # scan, posterior build, select_batch) compiles once, not per
            # size bucket
            self._pad_to = gp._bucket(self._n_init + self.cfg.n_iter)
        cfg = self.cfg
        t_configs, t_values, t_vars = self._training_data()
        x = self.space.encode_batch(t_configs)
        y = np.asarray(t_values, np.float64)
        # heteroscedastic channel: replicated measurements report the
        # variance of their pooled mean; rows without an estimate stay at
        # 0.0 (global-scalar fallback).  All-zero variances pass None so
        # the homoscedastic path stays bit-identical to pre-replication
        # traces.  Under log_objective the delta method maps raw variance
        # onto the log scale: var[log y] ≈ var[y] / y².
        obs = None
        var = np.asarray(t_vars, np.float64)
        if var.size == y.size and np.any(var > 0):
            obs = var / np.maximum(y, 1e-12) ** 2 if cfg.log_objective \
                else var.copy()
        if cfg.log_objective:
            y = np.log(np.maximum(y, 1e-12))
        if cfg.refit_async:
            state, x_fit, y_fit = self._refit(x, y, obs)
        else:
            state = self._fit_gp(x, y, obs)
            self._params = state.params
            self._posterior = (state, x, y)
            x_fit, y_fit = x, y

        # candidates: global LHS + Gaussian ball + per-knob incumbent
        # mutations.  The Gaussian ball almost never crosses a bool /
        # categorical decision boundary (σ=0.08 in unit space), so EI can
        # sit in a basin forever without trying `tensor_parallel=False`;
        # the axis sweeps make every single-knob move visible.
        d = len(self.space)
        cand = lhs_unit(self.rng, cfg.n_candidates, d)
        inc = self.space.to_unit(self.trace.best[0])
        local = np.clip(inc[None] + self.rng.normal(0, cfg.local_sigma,
                                                    (cfg.n_local, d)), 0, 1)
        sweeps = []
        for j in range(d):
            for u in (0.0, 0.25, 0.5, 0.75, 1.0):
                m = inc.copy()
                m[j] = u
                sweeps.append(m)
        cand = np.vstack([cand, local, np.asarray(sweeps)])

        # device-resident q-EI: the whole batch — EI scoring, masked
        # argmax, incremental-Cholesky fantasy appends — is ONE compiled
        # call at the budget-pinned padded shape (the per-pick rebuild
        # loop survives as _select_batch, the reference oracle).  The
        # scan length is bucketed to a multiple of batch_size: an async
        # driver frees rooms of 1..max_in_flight, and q is a static jit
        # shape — without bucketing every distinct width would recompile
        # the scan mid-run.  Greedy selection is prefix-stable, so the
        # first q of a longer scan ARE the q-pick selection.
        n_fit = len(y_fit)
        best_y = float(np.min(y_fit))
        y_raw = np.zeros(int(state.x.shape[0]), np.float32)
        y_raw[:n_fit] = np.asarray(y_fit, np.float32)
        q_sel = cfg.batch_size * -(-q // cfg.batch_size)
        devs = self._shard_devices()
        if devs is not None:
            # candidate pool sharded row-wise over the mesh; picks are
            # bit-identical to select_batch at equal pool, so the gate
            # never changes a trace — only its wall-clock
            idx = np.asarray(gp.select_batch_sharded(
                state, cand.astype(np.float32), y_raw, n_fit, best_y,
                q_sel, kind=cfg.kernel, fantasy=cfg.fantasy,
                acquisition=cfg.acquisition, use_pallas=cfg.use_pallas,
                devices=devs))
        else:
            idx = np.asarray(gp.select_batch(
                state, cand.astype(np.float32), y_raw, n_fit, best_y, q_sel,
                kind=cfg.kernel, fantasy=cfg.fantasy,
                acquisition=cfg.acquisition, use_pallas=cfg.use_pallas))
        picks = [cand[int(i)] for i in idx[:q]]
        probes = self.space.decode_batch(np.stack(picks))
        expanded = self._expand_near(probes)
        if cfg.refit_async:
            # selection has device-synced (np.asarray above): the refit's
            # computation queues strictly after it.  Expansion runs FIRST:
            # when this round enlarged a boundary the trace encoding just
            # changed, so the snapshot is re-encoded in the new space —
            # otherwise the background fit would train on stale unit-cube
            # coordinates for the rest of the run
            if expanded:
                x = self.space.encode_batch(self.trace.configs)
            self._refit_kick(x, y, obs)
        for c in probes:
            self._pending.add(c)
        return probes

    def tell(self, configs: Sequence[Config], values: Sequence[float],
             variances: Optional[Sequence[float]] = None):
        configs = [dict(c) for c in configs]
        self.trace.extend(configs, values, variances)
        for c in configs:
            if self._pending_init.pop(c)[0]:
                continue
            if self._match_pending(c):
                self._evals_done += 1
            # else: injected observation — free information, no budget

    # -- GP-implied measurement noise (the replication racer's prior) ---------

    def measurement_variance(self, config: Config) -> Optional[float]:
        """GP-implied variance of a *single* measurement at ``config``,
        in raw objective units — the fitted observation-noise
        hyperparameter, learned from every config's residuals at once.
        This is the strength a 2-repeat probe borrows across configs:
        its own empirical variance has one degree of freedom, while the
        GP's noise scalar has the whole trace behind it
        (:class:`repro.core.replication.AdaptiveRacer` pools the two).
        Under ``log_objective`` the log-scale noise is mapped back
        through the delta method at the posterior mean.  ``None`` before
        the first fit (the racer then falls back to empirical-only)."""
        post = self._posterior
        if post is None:
            return None
        state = post[0]
        nv = (float(np.exp(state.params.log_noise_var))
              * float(state.y_std) ** 2)
        if not self.cfg.log_objective:
            return nv
        u = np.asarray(self.space.to_unit(config),
                       np.float32)[None]
        mu, _ = gp.predict(state, u, self.cfg.kernel)
        y_hat = float(np.exp(np.clip(float(mu[0]), -50.0, 50.0)))
        return nv * y_hat * y_hat

    # -- serializable hyperparameter state (warm session restarts) -----------

    STATE_VERSION = 1

    def state_dict(self) -> dict:
        """First-class serializable GP state: hyperparameters
        (lengthscales / signal / noise, log domain, f32-exact), dynamic
        boundary state, and a trace snapshot — everything a fresh
        :class:`BOStrategy` over the same base space needs to resume
        this one (:meth:`load_state`).  Asked-but-untold probes are
        deliberately NOT serialized: their results will never arrive in
        the restarted process, so the restart re-asks them (in-flight
        budget is released, told budget is kept).  The tuning service
        snapshots sessions through this."""
        return {
            "version": self.STATE_VERSION,
            "kind": "bo",
            "kernel": self.cfg.kernel,
            "params": (None if self._params is None
                       else gp.params_to_dict(self._params)),
            "knobs": sorted(self.space.names),
            "base_bounds": {n: [lo, hi]
                            for n, (lo, hi) in self._base_bounds.items()},
            "bounds": {k.name: [float(k.lo), float(k.hi)]
                       for k in self.space.knobs
                       if k.kind in ("int", "float")},
            "trace": {
                "configs": [_json_cfg(c) for c in self.trace.configs],
                "values": [float(v) for v in self.trace.values],
                "variances": [float(v) for v in self.trace.variances],
                "boundary_events": [[int(i), str(n)] for i, n
                                    in self.trace.boundary_events],
            },
            "evals_done": int(self._evals_done),
            "init_queue": [_json_cfg(c) for c in self._init_queue],
            "n_init": int(self._n_init),
            "pad_to": self._pad_to,
            "space_version": int(self._space_version),
        }

    def load_state(self, sd: dict) -> None:
        """Restore :meth:`state_dict` output into this (freshly built)
        strategy: re-expands dynamic boundaries to their serialized
        state, reinstates the fitted hyperparameters as the warm-start
        carry, and replays the trace snapshot.  The strategy must have
        been constructed over the same base space (same knob names) and
        config (kernel) the snapshot came from."""
        if sd.get("version") != self.STATE_VERSION:
            raise ValueError(f"BOStrategy.load_state: unsupported state "
                             f"version {sd.get('version')!r} "
                             f"(this build speaks {self.STATE_VERSION})")
        if sd.get("kernel", self.cfg.kernel) != self.cfg.kernel:
            raise ValueError(
                f"BOStrategy.load_state: state was fitted with kernel "
                f"{sd['kernel']!r}, this strategy uses {self.cfg.kernel!r}")
        # Space identity: a snapshot is only loadable over the space it
        # was fitted on.  Loading across workloads whose spaces merely
        # *look* alike would silently hand the GP a permuted / rescaled
        # unit cube, so every mismatch is a hard error, never a warning.
        if "knobs" in sd:
            theirs, ours = set(sd["knobs"]), set(self.space.names)
            if theirs != ours:
                missing = sorted(theirs - ours)
                extra = sorted(ours - theirs)
                raise ValueError(
                    "BOStrategy.load_state: space mismatch — state knobs "
                    f"absent here: {missing[:8]}; knobs the state lacks: "
                    f"{extra[:8]}")
        for name, (lo, hi) in sd.get("base_bounds", {}).items():
            if name not in self._base_bounds:
                raise ValueError("BOStrategy.load_state: state names a "
                                 f"knob this space lacks: {name!r}")
            mine = self._base_bounds[name]
            if (float(lo), float(hi)) != mine:
                raise ValueError(
                    f"BOStrategy.load_state: base bounds differ for "
                    f"{name!r}: state has [{lo}, {hi}], this space has "
                    f"[{mine[0]}, {mine[1]}] — refusing to load a GP "
                    f"fitted on a different unit-cube scaling")
        bounds = sd.get("bounds", {})
        unknown = set(bounds) - set(self.space.names)
        if unknown:
            raise ValueError("BOStrategy.load_state: state names knobs "
                             f"this space lacks: {sorted(unknown)}")
        space = self.space
        for name, (lo, hi) in bounds.items():
            k = space.knob(name)
            if (float(k.lo), float(k.hi)) != (float(lo), float(hi)):
                space = space.with_knob(replace(k, lo=float(lo),
                                                hi=float(hi)))
        self.space = space
        self._params = (None if sd.get("params") is None
                        else gp.params_from_dict(sd["params"]))
        tr = sd.get("trace", {})
        self.trace = Trace()
        self.trace.extend(tr.get("configs", []), tr.get("values", []),
                          tr.get("variances") or None)
        self.trace.boundary_events = [(int(i), str(n)) for i, n
                                      in tr.get("boundary_events", [])]
        self._evals_done = int(sd.get("evals_done", 0))
        self._init_queue = [dict(c) for c in sd.get("init_queue", [])]
        self._n_init = int(sd.get("n_init", self._n_init))
        self._pad_to = sd.get("pad_to")
        self._space_version = int(sd.get("space_version", 0))
        # in-flight state is process-local: pending probes are re-asked,
        # the posterior/refit machinery restarts lazily on the next ask
        self._pending = _PendingSet()
        self._pending_init = _PendingSet()
        self._posterior = None
        self._refit_future = None
        self._refit_snapshot = None
        self._refit_len = 0
        self._refit_space_version = self._space_version


# ---------------------------------------------------------------------------
# baselines (paper §3.4) as ask/tell strategies
# ---------------------------------------------------------------------------

class RandomStrategy(_StrategyBase):
    """LHS design.  With a ``budget`` the whole stratified design is fixed
    up front (identical to ``sampling.latin_hypercube``); with
    ``budget=None`` the strategy is endless — each ask draws a fresh LHS
    chunk, and the driver owns termination (successive-halving screens)."""

    def __init__(self, space: Space, budget: Optional[int] = None,
                 seed: int = 0, batch_size: Optional[int] = None):
        super().__init__(space)
        self.budget = budget
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self._queue: List[Config] = (latin_hypercube(space, budget, seed=seed)
                                     if budget else [])
        self._told = 0

    @property
    def finished(self) -> bool:
        return self.budget is not None and self._told >= self.budget

    def ask(self, n: Optional[int] = None) -> List[Config]:
        if self.budget is not None:
            if not self._queue:
                return []
            k = n if n is not None else (self.batch_size or len(self._queue))
            k = max(min(k, len(self._queue)), 1)
            chunk, self._queue = self._queue[:k], self._queue[k:]
        else:
            k = n if n is not None else (self.batch_size or 1)
            chunk = self.space.decode_batch(
                lhs_unit(self.rng, k, len(self.space)))
        out = [dict(c) for c in chunk]
        for c in out:
            self._pending.add(c)
        return out

    def tell(self, configs: Sequence[Config], values: Sequence[float],
             variances: Optional[Sequence[float]] = None):
        configs = [dict(c) for c in configs]
        self.trace.extend(configs, values, variances)
        for c in configs:
            if self._match_pending(c):
                self._told += 1


class AnnealingStrategy(_StrategyBase):
    """Metropolis walk.  The accept/reject state advances in ``tell``; the
    walk is memoryless (the paper's point about SA's unreliability under
    noise), so ``ask(n > 1)`` simply proposes n independent perturbations
    of the current state."""

    def __init__(self, space: Space, budget: int,
                 cfg: Optional[SAConfig] = None, seed: Optional[int] = None):
        super().__init__(space)
        self.cfg = cfg or SAConfig()
        if cfg is None and seed is not None:
            self.cfg = replace(self.cfg, seed=seed)
        self.budget = budget
        self.rng = np.random.default_rng(self.cfg.seed)
        self._cur: Optional[Config] = None
        self._cur_v: Optional[float] = None
        self._t = self.cfg.t0
        self._asked_start = False
        self._told = 0

    @property
    def finished(self) -> bool:
        return self._told >= self.budget

    def ask(self, n: Optional[int] = None) -> List[Config]:
        remaining = self.budget - self._told - len(self._pending)
        if remaining <= 0:
            return []
        k = min(n if n is not None else 1, remaining)
        out: List[Config] = []
        if not self._asked_start:
            self._asked_start = True
            out.append(self.space.project(self.space.default_config()))
        anchor = self._cur or self.space.project(self.space.default_config())
        d = len(self.space)
        while len(out) < k:
            u = self.space.to_unit(anchor)
            prop_u = np.clip(u + self.rng.normal(0, self.cfg.sigma, d), 0, 1)
            out.append(self.space.from_unit(prop_u))
        out = [dict(c) for c in out]
        for c in out:
            self._pending.add(c)
        return out

    def tell(self, configs: Sequence[Config], values: Sequence[float],
             variances: Optional[Sequence[float]] = None):
        configs = [dict(c) for c in configs]
        self.trace.extend(configs, values, variances)
        for c, v in zip(configs, values):
            if not self._match_pending(c):
                continue                     # injected observation
            v = float(v)
            self._told += 1
            if self._cur is None:            # the starting point
                self._cur, self._cur_v = dict(c), v
                continue
            # Metropolis accept on the *current* state only (no history)
            scale = max(float(np.std(self.trace.values)), 1e-9)
            if (v < self._cur_v
                    or self.rng.random() < np.exp(-(v - self._cur_v)
                                                  / (self._t * scale))):
                self._cur, self._cur_v = dict(c), v
            self._t *= self.cfg.cooling


class GeneticStrategy(_StrategyBase):
    """Population evolution.  ``ask`` hands out the un-scored members of
    the current generation; once the generation is fully told, the next
    one is bred (elitism + tournament + uniform crossover + Gaussian
    mutation).  The measurement cost — a whole population per generation —
    is the paper's critique, visible here as large mandatory asks."""

    def __init__(self, space: Space, budget: int,
                 cfg: Optional[GAConfig] = None, seed: Optional[int] = None):
        super().__init__(space)
        self.cfg = cfg or GAConfig()
        if cfg is None and seed is not None:
            self.cfg = replace(self.cfg, seed=seed)
        self.budget = budget
        self.rng = np.random.default_rng(self.cfg.seed)
        d = len(space)
        pop_u = lhs_unit(self.rng, self.cfg.population, d)
        self._pop: List[Config] = [space.from_unit(u) for u in pop_u]
        self._fit: List[Optional[float]] = [None] * len(self._pop)
        self._queue: List[int] = list(range(len(self._pop)))
        self._pending_idx = _PendingSet()    # payload: population index
        self._init_gen = True
        self._told = 0

    @property
    def finished(self) -> bool:
        return self._told >= self.budget

    def ask(self, n: Optional[int] = None) -> List[Config]:
        if self.finished:
            return []
        self._maybe_evolve()
        if not self._queue:
            return []                        # blocked on tells
        k = len(self._queue) if n is None else max(min(n, len(self._queue)), 1)
        if not self._init_gen:
            # the initial population is always scored in full (as the
            # legacy loop did); later generations respect the budget
            remaining = self.budget - self._told - len(self._pending_idx)
            if remaining <= 0:
                return []
            k = min(k, remaining)
        idxs, self._queue = self._queue[:k], self._queue[k:]
        out: List[Config] = []
        for i in idxs:
            c = dict(self._pop[i])
            self._pending_idx.add(c, i)
            out.append(c)
        return out

    def tell(self, configs: Sequence[Config], values: Sequence[float],
             variances: Optional[Sequence[float]] = None):
        configs = [dict(c) for c in configs]
        self.trace.extend(configs, values, variances)
        for c, v in zip(configs, values):
            matched, i = self._pending_idx.pop(c)
            if matched:
                self._fit[i] = float(v)
                self._told += 1
        self._maybe_evolve()

    def _maybe_evolve(self):
        if (self._queue or self._pending_idx
                or any(f is None for f in self._fit)
                or self._told >= self.budget):
            return
        cfg, rng, pop, fit = self.cfg, self.rng, self._pop, self._fit
        d = len(self.space)
        order = np.argsort(fit)
        new_pop: List[Config] = [pop[i] for i in order[:cfg.elite]]
        while len(new_pop) < cfg.population:
            def pick():
                idx = rng.choice(len(pop), size=cfg.tournament, replace=False)
                return pop[min(idx, key=lambda i: fit[i])]
            a, b = self.space.to_unit(pick()), self.space.to_unit(pick())
            mask = rng.random(d) < cfg.crossover_p
            child = np.where(mask, a, b)
            mut = rng.random(d) < cfg.mutation_p
            child = np.clip(child + mut * rng.normal(0, cfg.mutation_sigma, d),
                            0, 1)
            new_pop.append(self.space.from_unit(child))
        self._pop = new_pop[:cfg.population]
        self._fit = [None] * len(self._pop)
        self._queue = list(range(len(self._pop)))
        self._init_gen = False


# ---------------------------------------------------------------------------
# registry: strategies by name (what Sapphire stages and benchmarks use)
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[..., SearchStrategy]] = {}


def register_strategy(name: str):
    """Register a strategy factory ``f(space, **kwargs) -> SearchStrategy``
    under ``name``.  Factories must tolerate (ignore) the common kwargs
    ``seed``, ``budget`` and ``batch_size`` so callers can stay generic."""
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def strategy_names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_strategy(name: str, space: Space, **kwargs) -> SearchStrategy:
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown strategy {name!r}; "
                       f"registered: {strategy_names()}") from None
    return factory(space, **kwargs)


@register_strategy("bo")
def _make_bo(space: Space, cfg: Optional[BOConfig] = None,
             budget: Optional[int] = None, seed: Optional[int] = None,
             batch_size: Optional[int] = None,
             init_configs: Optional[List[Config]] = None, **_) -> BOStrategy:
    if cfg is None:
        cfg = BOConfig(seed=seed if seed is not None else 0)
    if budget is not None:
        # a budget below the design size shrinks the design too, so the
        # strategy never spends more evaluations than asked for
        n_init = min(cfg.n_init, budget)
        cfg = replace(cfg, n_init=n_init, n_iter=budget - n_init)
    if batch_size is not None:
        cfg = replace(cfg, batch_size=batch_size, warm_start=True)
    return BOStrategy(space, cfg, init_configs=init_configs)


@register_strategy("random")
def _make_random(space: Space, budget: Optional[int] = None, seed: int = 0,
                 batch_size: Optional[int] = None, **_) -> RandomStrategy:
    return RandomStrategy(space, budget,
                          seed=seed if seed is not None else 0,
                          batch_size=batch_size)


@register_strategy("sa")
def _make_sa(space: Space, budget: int = 48,
             cfg: Optional[SAConfig] = None,
             seed: Optional[int] = None, **_) -> AnnealingStrategy:
    return AnnealingStrategy(space, budget, cfg, seed=seed)


@register_strategy("ga")
def _make_ga(space: Space, budget: int = 48,
             cfg: Optional[GAConfig] = None,
             seed: Optional[int] = None, **_) -> GeneticStrategy:
    return GeneticStrategy(space, budget, cfg, seed=seed)

"""Sapphire: the end-to-end configuration recommender (paper Fig. 3).

    result = Sapphire(arch="yi-6b", shape="train_4k").tune()

``tune()`` is three composable stages, each driving a registry strategy
through the experiment-loop Controller:

  1. **rank**   (§3.3) — an LHS design strategy scored on the test-cluster
     evaluator, Lasso-path importance, keep top-K knobs (others pinned);
  2. **search** (§3.4) — any registered strategy (GP-BO with dynamic
     boundaries by default; ``strategy="sa"|"ga"|"random"`` for the
     baselines) over the top-K sub-space, probes expanded to full configs
     by the Controller's ``prepare`` hook;
  3. **validate** — probe the default and "expert manual" baseline configs
     and assemble the report (recommended config, improvement, trace).

The stages are ordinary methods taking a Controller, so callers can rerun
any one of them against a different evaluator or database — e.g. re-rank
an existing EvalDB, or validate on the compiled product cluster.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, Optional, Tuple

from repro.configs import get_config
from repro.core import knobs as knobmod, ranking
from repro.core.controller import Controller, EvalDB
from repro.core.costmodel import MULTI_POD, SINGLE_POD, MeshShape
from repro.core.evaluators import AnalyticEvaluator
from repro.core.space import Config, Space
from repro.core.strategy import BOConfig, Trace, make_strategy
from repro.models.config import SHAPES_BY_NAME


def expert_manual_config(space: Space) -> Config:
    """The 'expert manual tuning' baseline (paper §4.4's Micron guide
    analogue): a sensible hand rule — flash attention with big aligned
    blocks, full remat, biggest microbatch, bf16 grads — applied blindly,
    i.e. without knowing the workload (which is the paper's point about
    why it sometimes loses)."""
    cfg = space.default_config()
    hand = {
        "attention_impl": "flash", "flash_block_q": 1024, "flash_block_k": 1024,
        "remat_policy": "full", "grad_allreduce_dtype": "bfloat16",
        "fsdp_shard_params": True, "tensor_parallel": True,
        "pod_hierarchical_allreduce": True,
    }
    mb = space.knob("microbatch") if "microbatch" in space.names else None
    if mb is not None:
        hand["microbatch"] = int(mb.hi)
    for k, v in hand.items():
        if k in space.names:
            cfg[k] = v
    return space.project(cfg)


@dataclass
class TuneResult:
    arch: str
    shape: str
    mesh: MeshShape
    clean_report: Dict[str, int]
    ranking: ranking.RankingResult
    top_k: int
    best_config: Config            # full config (pins + defaults + tuned)
    best_value: float
    default_value: float
    expert_value: float
    trace: Trace
    final_space: Space             # after dynamic-boundary enlargements
    n_evaluations: int             # tuning evaluations only (rank + search;
                                   # the default/expert baseline probes are
                                   # report overhead, not search budget)

    @property
    def speedup_vs_default(self) -> float:
        return self.default_value / max(self.best_value, 1e-12)

    @property
    def speedup_vs_expert(self) -> float:
        return self.expert_value / max(self.best_value, 1e-12)

    def summary(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape,
            "clean_domain": self.clean_report,
            "top_k": self.top_k,
            "top_knobs": self.ranking.top(self.top_k),
            "best_step_s": self.best_value,
            "default_step_s": self.default_value,
            "expert_step_s": self.expert_value,
            "speedup_vs_default": round(self.speedup_vs_default, 3),
            "speedup_vs_expert": round(self.speedup_vs_expert, 3),
            "n_evaluations": self.n_evaluations,
            "boundary_events": self.trace.boundary_events,
        }


@dataclass
class Sapphire:
    arch: str = "yi-6b"
    shape: str = "train_4k"
    multi_pod: bool = False
    top_k: int = 16
    n_rank_samples: int = 300
    batch_size: int = 1            # q-batch width: probes per GP refit AND
                                   # configs per Experiment-Unit round;
                                   # 1 = the paper's sequential loop
    rank_batch_size: Optional[int] = None  # ranking chunk (None: 64 when
                                           # batching, else sequential)
    strategy: str = "bo"           # search-stage strategy (registry name)
    bo_config: Optional[BOConfig] = None
    pinned: Optional[Dict[str, object]] = None
    noise_sigma: float = 0.025
    seed: int = 0
    db_path: Optional[str] = None
    async_eval: bool = False       # drive rank/search through the
                                   # overlapped Controller.run_async loop
                                   # (same search on the immediate
                                   # analytic service — values equal to
                                   # float ULP; a wall-clock win when the
                                   # service streams out of order)
    async_max_in_flight: Optional[int] = None  # concurrent probes in the
                                   # async loop (None: each stage's batch
                                   # width — sync pacing with streamed
                                   # tells; raise toward workers+min_ask
                                   # on a slow streaming service)
    async_min_ask: int = 1         # coalesce completion waves before the
                                   # next ask (amortizes GP refits)
    evaluator: Optional[Callable[[Config], float]] = None  # override (tests)

    def _setup(self):
        model_cfg = get_config(self.arch)
        cell = SHAPES_BY_NAME[self.shape]
        mesh = MULTI_POD if self.multi_pod else SINGLE_POD
        space, pins, report = knobmod.clean_space(model_cfg, cell, mesh,
                                                  self.pinned)
        ev = self.evaluator or AnalyticEvaluator(
            model_cfg, cell, mesh, noise_sigma=self.noise_sigma,
            seed=self.seed)
        # every request/record carries the cell it was measured on, so a
        # shared evaluation DB can be sliced per workload
        ctrl = Controller(ev, EvalDB(self.db_path),
                          workload=f"{self.arch}:{self.shape}")
        return model_cfg, cell, mesh, space, pins, report, ctrl

    # ---- stage 1: §3.3 ranking over the clean domain ------------------------

    def rank_stage(self, ctrl: Controller, space: Space,
                   strategy: str = "random") -> ranking.RankingResult:
        rank_bs = self.rank_batch_size
        if rank_bs is None:
            rank_bs = 64 if self.batch_size > 1 else 1
        return ranking.rank_with_controller(
            space, ctrl.with_tag("rank"), n_samples=self.n_rank_samples,
            seed=self.seed, batch_size=rank_bs, strategy=strategy,
            async_eval=self.async_eval,
            max_in_flight=self.async_max_in_flight or rank_bs,
            min_ask=self.async_min_ask)

    # ---- stage 2: §3.4 search over the top-K sub-space -----------------------

    def search_stage(self, ctrl: Controller, space: Space,
                     rk: ranking.RankingResult, strategy: Optional[str] = None
                     ) -> Tuple[Config, float, Trace, Space]:
        """Drive the named registry strategy over the top-K sub-space.
        Returns (best full config, best value, trace, final sub-space)."""
        strategy = strategy or self.strategy
        sub = rk.top_space(self.top_k)

        bo_cfg = self.bo_config or BOConfig(seed=self.seed)
        if self.batch_size != 1:
            # batching opts into the full batched redesign: q-EI probes
            # AND warm-started GP hyperparameters across rounds
            bo_cfg = replace(bo_cfg, batch_size=self.batch_size,
                             warm_start=True)
        if strategy == "bo":
            strat = make_strategy("bo", sub, cfg=bo_cfg)
        else:
            # non-BO strategies get the same evaluation budget and the
            # same configs-per-round as the BO loop would
            strat = make_strategy(strategy, sub, seed=self.seed,
                                  budget=bo_cfg.n_init + bo_cfg.n_iter,
                                  batch_size=self.batch_size)

        # non-top knobs are pinned at their defaults inside the evaluator.
        # The completer follows the strategy's live space: when a dynamic
        # boundary is enlarged (paper Fig. 4), the enlarged probes must
        # reach the evaluator unclipped.
        _cache: Dict[str, object] = {}

        def _full(sub_cfg: Config) -> Config:
            if _cache.get("sub") is not strat.space:
                _cache["sub"] = strat.space
                _cache["complete"] = space.overlaid(strat.space).completer()
            return _cache["complete"](sub_cfg)

        search_ctrl = ctrl.with_tag(strategy).with_prepare(_full)
        bs = None if strategy == "bo" else self.batch_size
        if self.async_eval:
            # default depth = the search's actual round width — the BO
            # strategy's own q when a bo_config overrides it, so a
            # q-batch search is not squeezed into 1-probe asks: sync
            # pacing with streamed tells; raise async_max_in_flight to
            # keep a slow streaming service saturated through refits
            width = max(self.batch_size,
                        bo_cfg.batch_size if strategy == "bo" else 1)
            trace = search_ctrl.run_async(
                strat, batch_size=bs,
                max_in_flight=self.async_max_in_flight or width,
                min_ask=self.async_min_ask)
        else:
            trace = search_ctrl.run(strat, batch_size=bs)
        best_sub, best_v = strat.best()
        close = getattr(strat, "close", None)
        if close is not None:
            close()        # join a refit_async background executor, if any
        return _full(best_sub), best_v, trace, strat.space

    # ---- stage 3: baseline probes + report -----------------------------------

    def validate_stage(self, ctrl: Controller,
                       space: Space) -> Tuple[float, float]:
        """Probe the default and expert-manual baselines (tagged, so they
        never count toward the reported tuning budget)."""
        defaults = space.project(space.default_config())
        expert = expert_manual_config(space)
        dv = ctrl.with_tag("default")(defaults)
        ev_ = ctrl.with_tag("expert")(expert)
        return dv, ev_

    # ---- the pipeline --------------------------------------------------------

    def tune(self) -> TuneResult:
        model_cfg, cell, mesh, space, pins, report, ctrl = self._setup()
        n_preexisting = len(ctrl.db)           # warm-started DBs reload here

        rk = self.rank_stage(ctrl, space)
        best_full, best_v, trace, final_sub = self.search_stage(
            ctrl, space, rk)
        best_full = dict(best_full)
        best_full.update(pins)
        n_evals = len(ctrl.db) - n_preexisting  # rank + search only
        dv, ev_ = self.validate_stage(ctrl, space)

        return TuneResult(
            arch=self.arch, shape=self.shape, mesh=mesh,
            clean_report=report, ranking=rk, top_k=self.top_k,
            best_config=best_full, best_value=best_v,
            default_value=dv, expert_value=ev_,
            trace=trace, final_space=final_sub,
            n_evaluations=n_evals,
        )

"""Sapphire: the end-to-end configuration recommender (paper Fig. 3).

    result = Sapphire(arch="yi-6b", shape="train_4k").tune()

runs the full pipeline:

  1. build the raw knob space for (arch × shape × mesh);
  2. §3.2 constraint resolution  -> clean domain;
  3. §3.3 ranking: ~300 LHS samples on the test-cluster evaluator,
     Lasso-path importance, keep top-K knobs (others pinned to default);
  4. §3.4 GP-BO with dynamic boundaries over the top-K sub-space;
  5. report: recommended config (merged with pins/defaults), improvement
     over the default and over an "expert manual" config, the tuning
     trace, and — optionally — the product-cluster (compiled) validation.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Dict, List, Optional, Sequence

from repro.configs import get_config
from repro.core import bo, knobs as knobmod, ranking
from repro.core.bo import BOConfig, BOTrace
from repro.core.controller import Controller, EvalDB
from repro.core.costmodel import MULTI_POD, SINGLE_POD, MeshShape
from repro.core.evaluators import AnalyticEvaluator
from repro.core.space import Config, Space
from repro.models.config import SHAPES_BY_NAME


def expert_manual_config(space: Space) -> Config:
    """The 'expert manual tuning' baseline (paper §4.4's Micron guide
    analogue): a sensible hand rule — flash attention with big aligned
    blocks, full remat, biggest microbatch, bf16 grads — applied blindly,
    i.e. without knowing the workload (which is the paper's point about
    why it sometimes loses)."""
    cfg = space.default_config()
    hand = {
        "attention_impl": "flash", "flash_block_q": 1024, "flash_block_k": 1024,
        "remat_policy": "full", "grad_allreduce_dtype": "bfloat16",
        "fsdp_shard_params": True, "tensor_parallel": True,
        "pod_hierarchical_allreduce": True,
    }
    mb = space.knob("microbatch") if "microbatch" in space.names else None
    if mb is not None:
        hand["microbatch"] = int(mb.hi)
    for k, v in hand.items():
        if k in space.names:
            cfg[k] = v
    return space.project(cfg)


@dataclass
class TuneResult:
    arch: str
    shape: str
    mesh: MeshShape
    clean_report: Dict[str, int]
    ranking: ranking.RankingResult
    top_k: int
    best_config: Config            # full config (pins + defaults + tuned)
    best_value: float
    default_value: float
    expert_value: float
    trace: BOTrace
    final_space: Space             # after dynamic-boundary enlargements
    n_evaluations: int

    @property
    def speedup_vs_default(self) -> float:
        return self.default_value / max(self.best_value, 1e-12)

    @property
    def speedup_vs_expert(self) -> float:
        return self.expert_value / max(self.best_value, 1e-12)

    def summary(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape,
            "clean_domain": self.clean_report,
            "top_k": self.top_k,
            "top_knobs": self.ranking.top(self.top_k),
            "best_step_s": self.best_value,
            "default_step_s": self.default_value,
            "expert_step_s": self.expert_value,
            "speedup_vs_default": round(self.speedup_vs_default, 3),
            "speedup_vs_expert": round(self.speedup_vs_expert, 3),
            "n_evaluations": self.n_evaluations,
            "boundary_events": self.trace.boundary_events,
        }


@dataclass
class Sapphire:
    arch: str = "yi-6b"
    shape: str = "train_4k"
    multi_pod: bool = False
    top_k: int = 16
    n_rank_samples: int = 300
    batch_size: int = 1            # q-batch width: probes per GP refit AND
                                   # configs per Experiment-Unit round;
                                   # 1 = the paper's sequential loop
    rank_batch_size: Optional[int] = None  # ranking chunk (None: 64 when
                                           # batching, else sequential)
    bo_config: Optional[BOConfig] = None
    pinned: Optional[Dict[str, object]] = None
    noise_sigma: float = 0.025
    seed: int = 0
    db_path: Optional[str] = None
    evaluator: Optional[Callable[[Config], float]] = None  # override (tests)

    def _setup(self):
        model_cfg = get_config(self.arch)
        cell = SHAPES_BY_NAME[self.shape]
        mesh = MULTI_POD if self.multi_pod else SINGLE_POD
        space, pins, report = knobmod.clean_space(model_cfg, cell, mesh,
                                                  self.pinned)
        ev = self.evaluator or AnalyticEvaluator(
            model_cfg, cell, mesh, noise_sigma=self.noise_sigma,
            seed=self.seed)
        ctrl = Controller(ev, EvalDB(self.db_path))
        return model_cfg, cell, mesh, space, pins, report, ctrl

    def tune(self) -> TuneResult:
        model_cfg, cell, mesh, space, pins, report, ctrl = self._setup()

        # ---- §3.3 ranking over the clean domain --------------------------
        rank_bs = self.rank_batch_size
        if rank_bs is None:
            rank_bs = 64 if self.batch_size > 1 else 1
        rk = ranking.rank(space, ctrl.with_tag("rank"),
                          n_samples=self.n_rank_samples, seed=self.seed,
                          batch_size=rank_bs)
        sub = rk.top_space(self.top_k)

        # non-top knobs are pinned at their defaults inside the objective
        base = space.default_config()
        bo_ctrl = ctrl.with_tag("bo")

        def _full(sub_cfg: Config) -> Config:
            full = dict(base)
            full.update(sub_cfg)
            return space.project(full)

        def objective(sub_cfg: Config) -> float:
            return bo_ctrl(_full(sub_cfg))

        def objective_batch(sub_cfgs: Sequence[Config]) -> List[float]:
            return bo_ctrl.evaluate_batch([_full(c) for c in sub_cfgs])

        bo_cfg = self.bo_config or BOConfig(seed=self.seed)
        if self.batch_size != 1:
            # batching opts into the full batched redesign: q-EI probes
            # AND warm-started GP hyperparameters across rounds
            bo_cfg = replace(bo_cfg, batch_size=self.batch_size,
                             warm_start=True)
        best_sub, best_v, trace, final_sub = bo.minimize(
            objective, sub, bo_cfg, f_batch=objective_batch)

        best_full = dict(base)
        best_full.update(best_sub)
        best_full = space.project(best_full)
        best_full.update(pins)

        # ---- baselines ----------------------------------------------------
        defaults = space.project(space.default_config())
        expert = expert_manual_config(space)
        dv = ctrl.with_tag("default")(defaults)
        ev_ = ctrl.with_tag("expert")(expert)

        return TuneResult(
            arch=self.arch, shape=self.shape, mesh=mesh,
            clean_report=report, ranking=rk, top_k=self.top_k,
            best_config=best_full, best_value=best_v,
            default_value=dv, expert_value=ev_,
            trace=trace, final_space=final_sub,
            n_evaluations=len(ctrl.db),
        )

"""Custom compute kernels (Pallas) + the autotune dogfood loop.

Each subpackage ships ``kernel.py`` (the Pallas body), ``ops.py`` (the
jit'd model-layout wrapper) and ``ref.py`` (the jnp oracle).  Tiling
parameters (block sizes, chunk widths) are exposed as keyword knobs on
the ops wrappers; :mod:`repro.kernels.autotune` turns each wrapper's
``autotune_space()``/``autotune_bench()`` pair into a Sapphire search
problem, so the tuner tunes its own kernels (ROADMAP's dogfood item).
"""

from __future__ import annotations

from typing import Optional


def tuning_compiler_params(num_warps: Optional[int] = None,
                           pipeline: Optional[int] = None,
                           interpret: bool = False):
    """``pallas_call`` compiler params for the tunable scheduling knobs.

    ``num_warps``/``pipeline`` (pipeline depth → Triton ``num_stages``)
    only exist on the GPU lowering; on TPU the Mosaic pipeline is derived
    from the BlockSpecs and in interpret mode there is no compiler at
    all — those paths get ``None`` (pass nothing), so the knobs are
    *inert* off-GPU and the autotune space stays portable."""
    import jax
    if interpret or jax.default_backend() != "gpu":
        return None
    params = {}
    if num_warps:
        params["num_warps"] = int(num_warps)
    if pipeline:
        params["num_stages"] = int(pipeline)
    return {"triton": params} if params else None


_AUTOTUNE_EXPORTS = ("KernelEvaluator", "kernel_bench", "kernel_space",
                     "tunable_kernels", "tune_kernel")


def __getattr__(name):
    # lazy: autotune imports the ops modules, which import this package
    if name in _AUTOTUNE_EXPORTS:
        from repro.kernels import autotune
        return getattr(autotune, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

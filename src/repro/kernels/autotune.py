"""Kernel autotune: SAPPHIRE tuning its own Pallas kernels (the dogfood).

The tuner's premise — simulation-based search beats hand-picked defaults
when evaluation throughput scales — applies to its *own* compute: the
three shipped kernels run with hand-picked block sizes.  This module
closes the loop:

* :class:`KernelSpace` — a kernel's tunable tiling/scheduling space
  (``block_q``/``block_k``/``block_n``/``block_m``/``chunk``/
  ``num_warps``/``pipeline``), built from each ops module's
  ``autotune_space()`` with real validity constraints (``ProductLeq``
  tile budgets, power-of-two ladders that snap under projection);
* :class:`KernelEvaluator` — an ``EvaluationService`` backend
  (``service_kind="pool"``) that times a kernel config on-device with
  warmup + ``block_until_ready`` best-of-repeats.  A config that fails
  validation or fails to compile raises, which the service layer turns
  into a *failed* EvalResult — the async controller prices it as
  infeasible instead of killing the run;
* :func:`tune_kernel` — the whole loop: BO over the kernel space through
  ``Controller.run_async``, seeded with the hand-picked default so the
  result can always be compared head-to-head against it.

This is a real non-analytic workload for the experiment loop: seconds of
wall-clock per evaluation, failures, and a measurable win over defaults
(asserted in ``benchmarks/perf_multi_device.py``).
"""

from __future__ import annotations

import importlib
import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from repro.core.space import Config, Space

SCREEN_FIDELITY = "screen"


@dataclass(frozen=True)
class KernelSpace:
    """A tunable kernel: its name, knob :class:`Space` (with validity
    constraints) and benchmark factory ``bench(**shape) -> build`` where
    ``build(cfg) -> run`` closes over the input tensors and ``run()``
    executes one kernel call."""
    kernel: str
    space: Space
    bench: Callable[..., Callable[[Config], Callable[[], Any]]]

    def default_config(self) -> Config:
        return self.space.project(self.space.default_config())


_OPS = {
    "gp_gram": "repro.kernels.gp_gram.ops",
    "flash_attention": "repro.kernels.flash_attention.ops",
    "mlstm_chunk": "repro.kernels.mlstm_chunk.ops",
}
_REGISTRY: Dict[str, KernelSpace] = {}


def tunable_kernels() -> tuple:
    return tuple(sorted(_OPS))


def kernel_spec(kernel: str) -> KernelSpace:
    spec = _REGISTRY.get(kernel)
    if spec is None:
        try:
            mod = importlib.import_module(_OPS[kernel])
        except KeyError:
            raise KeyError(f"unknown kernel {kernel!r}; "
                           f"tunable: {tunable_kernels()}") from None
        spec = KernelSpace(kernel, mod.autotune_space(), mod.autotune_bench)
        _REGISTRY[kernel] = spec
    return spec


def kernel_space(kernel: str) -> Space:
    """The tunable knob space of ``kernel`` (validity constraints
    included)."""
    return kernel_spec(kernel).space


def kernel_bench(kernel: str, **shape):
    """``build(cfg) -> run()`` benchmark factory for ``kernel`` at
    ``shape`` (kernel-specific keywords, e.g. ``n=136`` for gp_gram)."""
    return kernel_spec(kernel).bench(**shape)


@dataclass
class KernelEvaluator:
    """On-device kernel timer behind the EvaluationService contract.

    ``service_kind = "pool"`` routes it through a worker pool at the
    Controller boundary (``as_service``); ``max_workers = 1`` keeps
    timing runs serialized — overlapped measurements would contend for
    the device and time each other's noise.  ``wants_request = True``
    lets the service hand the :class:`EvalRequest` through, so a
    ``fidelity="screen"`` request is timed with fewer repeats (the
    successive-halving screen tier).

    A config off the space (validation failure) or one the kernel
    rejects/fails to compile raises — the service layer converts that
    into a failed EvalResult, which ``run_async`` records as infeasible
    and prices past the worst observed value.
    """
    kernel: str = "gp_gram"
    shape: Optional[Dict[str, Any]] = None
    repeats: int = 5
    warmup: int = 2
    screen_repeats: int = 2
    max_workers: int = 1                 # read by as_service
    service_kind = "pool"                # read by as_service
    wants_request = True                 # read by _score_one
    spec: KernelSpace = field(init=False)
    space: Space = field(init=False)

    def __post_init__(self):
        self.spec = kernel_spec(self.kernel)
        self.space = self.spec.space
        self._build = self.spec.bench(**(self.shape or {}))

    def __call__(self, cfg: Config, request=None) -> float:
        errs = self.space.validate(cfg)
        if errs:
            raise ValueError(f"{self.kernel}: invalid config {cfg!r}: "
                             + "; ".join(errs))
        import jax
        run = self._build(cfg)           # a bad tiling raises here or on
        for _ in range(max(self.warmup, 1)):     # first (compiling) call
            jax.block_until_ready(run())
        reps = self.repeats
        if request is not None and request.fidelity == SCREEN_FIDELITY:
            reps = self.screen_repeats
        best = math.inf
        for _ in range(max(reps, 1)):
            t0 = time.perf_counter()
            jax.block_until_ready(run())
            best = min(best, time.perf_counter() - t0)
        return best * 1e3                # milliseconds (minimized)


def tune_kernel(kernel: str = "gp_gram", shape: Optional[Dict] = None,
                budget: int = 20, batch_size: int = 2, seed: int = 0,
                repeats: int = 5, warmup: int = 2, fit_steps: int = 60,
                max_in_flight: Optional[int] = None,
                db_path: Optional[str] = None) -> Dict[str, Any]:
    """Tune ``kernel``'s tiling with BO through the async experiment loop.

    The initial design is seeded with the hand-picked default config
    (``init_design`` puts caller configs first), so every run measures
    the baseline it is trying to beat under identical conditions — the
    returned ``default_value`` is that measurement, not a separate run.

    Returns ``{"best_config", "best_value", "default_config",
    "default_value", "trace", "db"}`` (values in ms).
    """
    from repro.core.controller import Controller, EvalDB
    from repro.core.strategy import BOConfig, BOStrategy

    ev = KernelEvaluator(kernel, shape=shape, repeats=repeats, warmup=warmup)
    space = ev.space
    default = space.project(space.default_config())
    n_init = min(max(budget // 3, 4), budget)
    cfg = BOConfig(n_init=n_init, n_iter=max(budget - n_init, 0),
                   batch_size=batch_size, n_candidates=256, n_local=64,
                   fit_steps=fit_steps, warm_start=True,
                   dynamic_boundary=False, seed=seed)
    strat = BOStrategy(space, cfg, init_configs=[default])
    ctl = Controller(ev, EvalDB(db_path), tag="autotune",
                     workload=f"kernel:{kernel}")
    try:
        trace = ctl.run_async(strat, max_in_flight=max_in_flight)
    finally:
        ctl.service.close()
    best_cfg, best_val = strat.best()

    from repro.core.strategy import _config_key
    dkey = _config_key(default)
    default_value = None
    for c, v in zip(trace.configs, trace.values):
        if _config_key(c) == dkey:
            default_value = float(v)
            break
    return {"best_config": dict(best_cfg), "best_value": float(best_val),
            "default_config": dict(default), "default_value": default_value,
            "trace": trace, "db": ctl.db}

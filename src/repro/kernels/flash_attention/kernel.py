"""Pallas TPU flash-attention forward (causal / windowed / soft-capped GQA).

TPU-native design (not a CUDA port):

* the KV loop is the **last grid dimension** — on TPU the grid is executed
  sequentially per core, so the online-softmax running state (m, l, acc)
  lives in VMEM scratch and survives across KV iterations; there is no
  cross-block shared-memory protocol like on GPU;
* BlockSpecs tile q/k/v/o into VMEM; block sizes are SAPPHIRE knobs
  (``flash_block_q``/``flash_block_k``, C2-aligned to multiples of 128 so
  the [bq, bk] score tile is MXU-shaped);
* fully-masked KV blocks (strictly above the causal diagonal, or outside
  the sliding window) are *skipped* with ``pl.when`` — for causal
  attention this halves the executed MACs, matching the cost model's 0.5
  causal factor;
* GQA is resolved in the index maps: query head h reads KV head
  ``h // (H // Kh)`` — no materialized ``jnp.repeat`` of K/V (the
  reference path pays that HBM cost; the kernel does not).

Validated in interpret mode against ``ref.reference_attention`` over a
shape/dtype/window/softcap sweep (tests/test_kernels.py).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tuning_compiler_params

NEG_INF = -1e30
LANES = 128          # TPU lane width: scratch running stats use a full lane


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], sq_valid: int, sk_valid: int,
            block_q: int, block_k: int, n_kb: int):
    i = pl.program_id(1)          # q block index
    j = pl.program_id(2)          # kv block index (sequential innermost)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * block_q
    k_start = j * block_k

    # Static-shape masks are built from iota; whether the block can be
    # skipped entirely is a *traced* predicate on (i, j).
    never_visible = jnp.logical_and(
        jnp.asarray(causal), k_start > q_start + block_q - 1)
    if window is not None:
        never_visible = jnp.logical_or(
            never_visible, k_start + block_k - 1 <= q_start - window)

    @pl.when(jnp.logical_not(never_visible))
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale            # [bq, d]
        k = k_ref[0].astype(jnp.float32)                    # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qi = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        ki = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.logical_and(qi < sq_valid, ki < sk_valid)
        if causal:
            mask = jnp.logical_and(mask, ki <= qi)
        if window is not None:
            mask = jnp.logical_and(mask, ki > qi - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                               # [bq, 1]
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)           # [bq, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                     # rescale old state
        p = jnp.exp(s - m_new)                              # [bq, bk]
        p = jnp.where(mask, p, 0.0)                         # kill -inf rows
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == n_kb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        block_q: int = 512, block_k: int = 512,
                        num_warps: Optional[int] = None,
                        pipeline: Optional[int] = None,
                        sq_valid: Optional[int] = None,
                        sk_valid: Optional[int] = None,
                        interpret: bool = False):
    """q [BH, Sq, D]; k/v [BKh, Sk, D]; Sq % block_q == Sk % block_k == 0.

    BH = B·H, BKh = B·Kh with H % Kh == 0; returns [BH, Sq, D] in q.dtype.
    ``sq_valid``/``sk_valid`` mark the unpadded lengths.
    """
    BH, Sq, D = q.shape
    BKh, Sk, _ = k.shape
    assert BH % BKh == 0, "GQA: q heads must be a multiple of kv heads"
    assert Sq % block_q == 0 and Sk % block_k == 0
    rep_total = BH // BKh
    n_qb, n_kb = Sq // block_q, Sk // block_k
    sq_valid = Sq if sq_valid is None else sq_valid
    sk_valid = Sk if sk_valid is None else sk_valid

    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(D), causal=causal, window=window,
        softcap=softcap, sq_valid=sq_valid, sk_valid=sk_valid,
        block_q=block_q, block_k=block_k, n_kb=n_kb)

    # GQA in the index map: flat q index b -> flat kv index.  BH is laid
    # out [B, H] and BKh as [B, Kh]; with rep = H // Kh this is
    # (b // H) * Kh + (b % H) // rep == b // rep_total ... only when Kh
    # divides contiguously — we flatten as [B*Kh, rep] on the wrapper side
    # so the map is simply b // rep_total.
    kv_map = lambda b, i, j: (b // rep_total, j, 0)       # noqa: E731

    extra = {}
    cp = tuning_compiler_params(num_warps, pipeline, interpret)
    if cp is not None:
        extra["compiler_params"] = cp
    return pl.pallas_call(
        kernel,
        grid=(BH, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),      # acc
            pltpu.VMEM((block_q, LANES), jnp.float32),  # running max m
            pltpu.VMEM((block_q, LANES), jnp.float32),  # running sum l
        ],
        interpret=interpret,
        **extra,
    )(q, k, v)

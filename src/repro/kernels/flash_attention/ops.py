"""jit'd wrapper: model-layout in/out, padding, backend dispatch.

``flash_attention(q, k, v)`` takes the model-zoo layout [B, S, H, D] /
[B, S, Kh, D], pads sequence lengths up to the block grid, flattens heads,
runs the Pallas kernel (interpret mode off-TPU) and restores the layout.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _pad_to(x, target: int, axis: int):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "num_warps", "pipeline", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 512, block_k: int = 512,
                    num_warps: Optional[int] = None,
                    pipeline: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """q [B,Sq,H,D], k/v [B,Sk,Kh,D] -> [B,Sq,H,D] (q.dtype).

    ``block_q``/``block_k``/``num_warps``/``pipeline`` are SAPPHIRE
    autotune knobs (:func:`autotune_space`); the output is
    tiling-invariant (tests/test_kernels.py guards this).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, H, D = q.shape
    _, Sk, Kh, _ = k.shape

    bq = min(block_q, max(_round_up(Sq, 8), 8))
    bk = min(block_k, max(_round_up(Sk, 8), 8))
    sq_pad = _round_up(Sq, bq)
    sk_pad = _round_up(Sk, bk)

    qf = _pad_to(q, sq_pad, 1).transpose(0, 2, 1, 3).reshape(B * H, sq_pad, D)
    kf = _pad_to(k, sk_pad, 1).transpose(0, 2, 1, 3).reshape(B * Kh, sk_pad, D)
    vf = _pad_to(v, sk_pad, 1).transpose(0, 2, 1, 3).reshape(B * Kh, sk_pad, D)

    o = flash_attention_fwd(qf, kf, vf, causal=causal, window=window,
                            softcap=softcap, block_q=bq, block_k=bk,
                            num_warps=num_warps, pipeline=pipeline,
                            sq_valid=Sq, sk_valid=Sk, interpret=interpret)
    o = o.reshape(B, H, sq_pad, D).transpose(0, 2, 1, 3)
    return o[:, :Sq]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# autotune hooks (repro.kernels.autotune)
# ---------------------------------------------------------------------------

def autotune_space():
    """Tunable tiling/scheduling space of the flash forward."""
    from repro.core.space import Knob, ProductLeq, Space, pow2_knob
    return Space(
        knobs=(
            pow2_knob("block_q", 512, 16, 1024,
                      description="query tile rows"),
            pow2_knob("block_k", 512, 16, 1024,
                      description="kv tile rows"),
            pow2_knob("num_warps", 4, 1, 8, inert=True,
                      description="GPU warps per block (inert off-GPU)"),
            Knob("pipeline", "int", 2, lo=1, hi=4, inert=True,
                 description="GPU pipeline stages (inert off-GPU)"),
        ),
        # the [bq, bk] score tile's VMEM budget
        constraints=(ProductLeq(("block_q", "block_k"), limit=512 * 512),),
    )


def autotune_bench(B: int = 1, S: int = 192, H: int = 4, Kh: int = 2,
                   D: int = 64, causal: bool = True, seed: int = 0):
    """``build(cfg) -> run()`` factory for :class:`KernelEvaluator`."""
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Kh, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Kh, D), jnp.float32)

    def build(cfg):
        bq, bk = int(cfg["block_q"]), int(cfg["block_k"])
        nw = int(cfg.get("num_warps", 0)) or None
        ps = int(cfg.get("pipeline", 0)) or None

        def run():
            return flash_attention(q, k, v, causal=causal, block_q=bq,
                                   block_k=bk, num_warps=nw, pipeline=ps)
        return run
    return build

"""jit'd wrapper: model-layout in/out, padding, backend dispatch.

``flash_attention(q, k, v)`` takes the model-zoo layout [B, S, H, D] /
[B, S, Kh, D], pads sequence lengths up to the block grid, flattens heads,
runs the Pallas kernel (interpret mode off-TPU) and restores the layout.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _pad_to(x, target: int, axis: int):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: Optional[bool] = None):
    """q [B,Sq,H,D], k/v [B,Sk,Kh,D] -> [B,Sq,H,D] (q.dtype)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, H, D = q.shape
    _, Sk, Kh, _ = k.shape

    bq = min(block_q, max(_round_up(Sq, 8), 8))
    bk = min(block_k, max(_round_up(Sk, 8), 8))
    sq_pad = _round_up(Sq, bq)
    sk_pad = _round_up(Sk, bk)

    qf = _pad_to(q, sq_pad, 1).transpose(0, 2, 1, 3).reshape(B * H, sq_pad, D)
    kf = _pad_to(k, sk_pad, 1).transpose(0, 2, 1, 3).reshape(B * Kh, sk_pad, D)
    vf = _pad_to(v, sk_pad, 1).transpose(0, 2, 1, 3).reshape(B * Kh, sk_pad, D)

    o = flash_attention_fwd(qf, kf, vf, causal=causal, window=window,
                            softcap=softcap, block_q=bq, block_k=bk,
                            sq_valid=Sq, sk_valid=Sk, interpret=interpret)
    o = o.reshape(B, H, sq_pad, D).transpose(0, 2, 1, 3)
    return o[:, :Sq]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m

"""Pure-jnp oracle for the flash-attention kernel (materializes scores)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def reference_attention(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None):
    """q [B,Sq,H,D], k/v [B,Sk,Kh,D] -> [B,Sq,H,D] (q.dtype), f32 math."""
    B, Sq, H, D = q.shape
    _, Sk, Kh, _ = k.shape
    rep = H // Kh
    kr = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
    vr = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    qf = q.astype(jnp.float32) / math.sqrt(D)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kr)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vr)
    return o.astype(q.dtype)

"""Pallas TPU Matérn-5/2 Gram kernels for the GP surrogate.

Consumers: ``gp.fit``/``gp.predict`` (posterior builds) and
``gp.select_batch`` (the device-resident q-EI candidate cross-Gram),
all behind ``BOConfig.use_pallas`` with the jnp kernels as fallback.
"""

from repro.kernels.gp_gram.ops import matern52_cross, matern52_gram
from repro.kernels.gp_gram.ref import matern52_cross_ref, matern52_gram_ref

__all__ = ["matern52_gram", "matern52_cross",
           "matern52_gram_ref", "matern52_cross_ref"]

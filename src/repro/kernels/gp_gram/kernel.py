"""Pallas TPU Matérn-5/2 Gram-matrix kernel.

SAPPHIRE's own compute hot-spot: the GP surrogate's O(n²·d) kernel matrix
(gp.py builds it every BO iteration, and every acquisition evaluation
computes an [m, n] cross-Gram against thousands of candidates).  On a
fleet the tuner runs on an accelerator host, so the Gram matrix is a
legitimate TPU kernel target — and it is a textbook BlockSpec exercise:

  tile the [n, m] output into [bn, bm] VMEM blocks; each block needs one
  [bn, d] row-tile and one [bm, d] column-tile; the squared distance is a
  rank-d matmul on the MXU plus elementwise Matérn on the VPU.

Validated in interpret mode against the jnp oracle (gp.matern52).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tuning_compiler_params

SQRT5 = math.sqrt(5.0)


def _kernel(xa_ref, xb_ref, o_ref, *, signal_var: float):
    a = xa_ref[...]                          # [bn, d] pre-scaled by 1/ls
    b = xb_ref[...]                          # [bm, d]
    a2 = jnp.sum(a * a, axis=1, keepdims=True)           # [bn, 1]
    b2 = jnp.sum(b * b, axis=1, keepdims=True).T         # [1, bm]
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = jnp.maximum(a2 + b2 - 2.0 * ab, 0.0)
    safe = jnp.where(d2 > 1e-12, d2, 1.0)
    r = jnp.where(d2 > 1e-12, jnp.sqrt(safe), 0.0)
    s = SQRT5 * r
    o_ref[...] = (signal_var * (1.0 + s + s * s / 3.0) * jnp.exp(-s)
                  ).astype(o_ref.dtype)


def matern52_gram_fwd(xa, xb, *, signal_var: float = 1.0,
                      block_n: int = 128, block_m: int = 128,
                      num_warps=None, pipeline=None,
                      interpret: bool = False):
    """xa [n, d], xb [m, d] — already scaled by 1/lengthscale.

    n % block_n == 0 and m % block_m == 0 (wrapper pads).
    ``num_warps``/``pipeline`` are the GPU scheduling knobs (inert on
    TPU/interpret — see :func:`repro.kernels.tuning_compiler_params`).
    """
    n, d = xa.shape
    m, _ = xb.shape
    assert n % block_n == 0 and m % block_m == 0
    kernel = functools.partial(_kernel, signal_var=signal_var)
    extra = {}
    cp = tuning_compiler_params(num_warps, pipeline, interpret)
    if cp is not None:
        extra["compiler_params"] = cp
    return pl.pallas_call(
        kernel,
        grid=(n // block_n, m // block_m),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
        **extra,
    )(xa, xb)

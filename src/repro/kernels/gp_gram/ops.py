"""jit'd wrapper: lengthscale scaling, padding, backend dispatch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gp_gram.kernel import matern52_gram_fwd


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("block", "block_m",
                                             "num_warps", "pipeline",
                                             "interpret"))
def matern52_gram(x, lengthscale, signal_var, *, block: int = 128,
                  block_m: int = None, num_warps: int = None,
                  pipeline: int = None, interpret: bool = None):
    """x [n, d] -> Matérn-5/2 Gram [n, n] (f32); ARD lengthscale [d].

    ``block``/``block_m`` tile the output rows/columns (``block_m=None``:
    square tiles); ``num_warps``/``pipeline`` are the GPU scheduling
    knobs.  All four are SAPPHIRE autotune knobs (:func:`autotune_space`)
    — the output is tiling-invariant, only the wall-clock moves.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = x.shape
    xs = (x / lengthscale).astype(jnp.float32)
    bn = min(block, _round_up(n, 8))
    bm = min(block_m if block_m else block, _round_up(n, 8))
    npad_r, npad_c = _round_up(n, bn), _round_up(n, bm)
    # pad rows far away (distance huge -> kernel ~0); sliced off below.
    # Rows and columns pad independently: rectangular tiles need the two
    # operands at different multiples.
    xr = (jnp.pad(xs, ((0, npad_r - n), (0, 0)), constant_values=1e4)
          if npad_r > n else xs)
    xc = (jnp.pad(xs, ((0, npad_c - n), (0, 0)), constant_values=1e4)
          if npad_c > n else xs)
    g = matern52_gram_fwd(xr, xc, signal_var=1.0, block_n=bn, block_m=bm,
                          num_warps=num_warps, pipeline=pipeline,
                          interpret=interpret)
    return g[:n, :n] * signal_var


@functools.partial(jax.jit, static_argnames=("block", "block_m",
                                             "num_warps", "pipeline",
                                             "interpret"))
def matern52_cross(xa, xb, lengthscale, signal_var, *, block: int = 128,
                   block_m: int = None, num_warps: int = None,
                   pipeline: int = None, interpret: bool = None):
    """Cross-Gram [n, m] for acquisition batches.

    ``block`` tiles the xa rows, ``block_m`` the xb rows (None: square
    tiles) — the same autotune knobs as :func:`matern52_gram`."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = xa.shape
    m, _ = xb.shape
    a = (xa / lengthscale).astype(jnp.float32)
    b = (xb / lengthscale).astype(jnp.float32)
    bn = min(block, _round_up(n, 8))
    bm = min(block_m if block_m else block, _round_up(m, 8))
    np_, mp = _round_up(n, bn), _round_up(m, bm)
    if np_ > n:
        a = jnp.pad(a, ((0, np_ - n), (0, 0)), constant_values=1e4)
    if mp > m:
        b = jnp.pad(b, ((0, mp - m), (0, 0)), constant_values=-1e4)
    g = matern52_gram_fwd(a, b, signal_var=1.0, block_n=bn, block_m=bm,
                          num_warps=num_warps, pipeline=pipeline,
                          interpret=interpret)
    return g[:n, :m] * signal_var


# ---------------------------------------------------------------------------
# autotune hooks (repro.kernels.autotune)
# ---------------------------------------------------------------------------

def autotune_space():
    """The gram kernel's tunable tiling/scheduling space."""
    from repro.core.space import Knob, ProductLeq, Space, pow2_knob
    return Space(
        knobs=(
            pow2_knob("block_n", 128, 8, 512,
                      description="output row tile"),
            pow2_knob("block_m", 128, 8, 512,
                      description="output column tile"),
            pow2_knob("num_warps", 4, 1, 8, inert=True,
                      description="GPU warps per block (inert off-GPU)"),
            Knob("pipeline", "int", 2, lo=1, hi=4, inert=True,
                 description="GPU pipeline stages (inert off-GPU)"),
        ),
        # VMEM/SMEM budget: the [bn, bm] output tile must fit
        constraints=(ProductLeq(("block_n", "block_m"), limit=256 * 256),),
    )


def autotune_bench(n: int = 136, d: int = 8, seed: int = 0):
    """``build(cfg) -> run()`` factory for :class:`KernelEvaluator`.

    Default shape n=136: off the 128 ladder, so the hand-picked square
    128 tile pads 136→256 and runs a 2×2 grid while a ≥144 tile runs the
    whole Gram in one call — a real tiling decision for the tuner to
    find."""
    key = jax.random.key(seed)
    x = jax.random.uniform(key, (n, d), jnp.float32)
    ls = jnp.full((d,), 0.3, jnp.float32)

    def build(cfg):
        bn, bm = int(cfg["block_n"]), int(cfg["block_m"])
        nw = int(cfg.get("num_warps", 0)) or None
        ps = int(cfg.get("pipeline", 0)) or None

        def run():
            return matern52_gram(x, ls, 1.0, block=bn, block_m=bm,
                                 num_warps=nw, pipeline=ps)
        return run
    return build

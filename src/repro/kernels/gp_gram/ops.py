"""jit'd wrapper: lengthscale scaling, padding, backend dispatch."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gp_gram.kernel import matern52_gram_fwd


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def matern52_gram(x, lengthscale, signal_var, *, block: int = 128,
                  interpret: bool = None):
    """x [n, d] -> Matérn-5/2 Gram [n, n] (f32); ARD lengthscale [d]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = x.shape
    xs = (x / lengthscale).astype(jnp.float32)
    bn = min(block, _round_up(n, 8))
    npad = _round_up(n, bn)
    if npad > n:
        # pad rows far away (distance huge -> kernel ~0); sliced off below
        xs = jnp.pad(xs, ((0, npad - n), (0, 0)), constant_values=1e4)
    g = matern52_gram_fwd(xs, xs, signal_var=1.0, block_n=bn, block_m=bn,
                          interpret=interpret)
    return g[:n, :n] * signal_var


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def matern52_cross(xa, xb, lengthscale, signal_var, *, block: int = 128,
                   interpret: bool = None):
    """Cross-Gram [n, m] for acquisition batches."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, d = xa.shape
    m, _ = xb.shape
    a = (xa / lengthscale).astype(jnp.float32)
    b = (xb / lengthscale).astype(jnp.float32)
    bn = min(block, _round_up(n, 8))
    bm = min(block, _round_up(m, 8))
    np_, mp = _round_up(n, bn), _round_up(m, bm)
    if np_ > n:
        a = jnp.pad(a, ((0, np_ - n), (0, 0)), constant_values=1e4)
    if mp > m:
        b = jnp.pad(b, ((0, mp - m), (0, 0)), constant_values=-1e4)
    g = matern52_gram_fwd(a, b, signal_var=1.0, block_n=bn, block_m=bm,
                          interpret=interpret)
    return g[:n, :m] * signal_var

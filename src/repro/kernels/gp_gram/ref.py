"""Pure-jnp oracle: delegates to gp.matern52 (the production fallback)."""

from __future__ import annotations

from repro.core.gp import matern52


def matern52_gram_ref(x, lengthscale, signal_var):
    return matern52(x, x, lengthscale, signal_var)


def matern52_cross_ref(xa, xb, lengthscale, signal_var):
    return matern52(xa, xb, lengthscale, signal_var)

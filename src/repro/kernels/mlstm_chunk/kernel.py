"""Pallas TPU chunkwise-parallel mLSTM forward.

The mLSTM (xLSTM's matrix-memory cell) is a gated linear-attention
recurrence.  The TPU-native formulation splits the sequence into chunks:
*within* a chunk everything is dense MXU work ([C,C] and [C,P] matmuls);
*across* chunks only the (P×P) matrix memory, the (P,) normalizer and a
scalar stabilizer are carried.  The chunk axis is the **last grid
dimension** (sequential on TPU), so the carry lives in VMEM scratch —
the same state-in-scratch pattern as the flash kernel's online softmax,
which is exactly how a GPU "recurrence" maps onto the TPU grid model.

Stabilization matches the xLSTM paper (max-gate subtraction); numerics are
validated against the sequential oracle (ref.py) and against the model's
chunked jnp path (models/xlstm.py) in tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tuning_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, o_ref,
            c_ref, n_ref, m_ref, *, chunk: int, p_dim: int):
    t = pl.program_id(1)          # chunk index (sequential)

    @pl.when(t == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)

    q = q_ref[0].astype(jnp.float32)          # [C, P]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    li = li_ref[0].astype(jnp.float32)        # [C]
    lf = lf_ref[0].astype(jnp.float32)

    m_prev = m_ref[0, 0]
    c_prev = c_ref[...]                        # [P, P]
    n_prev = n_ref[:, 0]                       # [P]

    cum = jnp.cumsum(lf)                       # [C] inclusive
    # D[i, j] = cum_i - cum_j + li_j  for j <= i
    d_mat = cum[:, None] - cum[None, :] + li[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    d_mat = jnp.where(jj <= ii, d_mat, NEG_INF)

    m_loc = jnp.max(d_mat, axis=1)                            # [C]
    m_comb = jnp.maximum(jnp.maximum(m_loc, cum + m_prev), NEG_INF)
    w = jnp.exp(d_mat - m_comb[:, None])                      # [C, C]

    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    s = qk * w
    h_intra = jnp.dot(s, v, preferred_element_type=jnp.float32)   # [C, P]
    n_intra = jnp.dot(w, k, preferred_element_type=jnp.float32)   # [C, P]

    scale_in = jnp.exp(cum + m_prev - m_comb)                 # [C]
    h_inter = jnp.dot(q, c_prev,
                      preferred_element_type=jnp.float32) * scale_in[:, None]
    n_all = n_intra + n_prev[None, :] * scale_in[:, None]
    denom = jnp.maximum(jnp.abs(jnp.sum(n_all * q, axis=1)),
                        jnp.exp(-m_comb))
    o_ref[0] = ((h_intra + h_inter) / denom[:, None]).astype(o_ref.dtype)

    # ---- carry update ------------------------------------------------------
    total = cum[-1]
    m_new = jnp.maximum(total + m_prev, jnp.max(total - cum + li))
    wk = jnp.exp(total - cum + li - m_new)                    # [C]
    decay = jnp.exp(total + m_prev - m_new)
    kw = k * wk[:, None]
    c_ref[...] = c_prev * decay + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_new = n_prev * decay + jnp.sum(kw, axis=0)
    n_ref[...] = jnp.broadcast_to(n_new[:, None], n_ref.shape)
    m_ref[...] = jnp.full_like(m_ref, m_new)


def mlstm_chunk_fwd(q, k, v, logi, logf, *, chunk: int = 256,
                    num_warps=None, pipeline=None,
                    interpret: bool = False):
    """q/k/v [BH, S, P]; logi/logf [BH, S] (f32); S % chunk == 0.

    k must already carry the 1/sqrt(P) scale.  Returns h [BH, S, P] (q.dtype).
    ``num_warps``/``pipeline`` are the GPU scheduling knobs (inert on
    TPU/interpret).
    """
    BH, S, P = q.shape
    assert S % chunk == 0, "chunk must divide sequence length"
    n_chunks = S // chunk

    kernel = functools.partial(_kernel, chunk=chunk, p_dim=P)
    seq_spec = pl.BlockSpec((1, chunk, P), lambda b, t: (b, t, 0))
    gate_spec = pl.BlockSpec((1, chunk), lambda b, t: (b, t))
    extra = {}
    cp = tuning_compiler_params(num_warps, pipeline, interpret)
    if cp is not None:
        extra["compiler_params"] = cp
    return pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[seq_spec, seq_spec, seq_spec, gate_spec, gate_spec],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct((BH, S, P), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((P, P), jnp.float32),      # matrix memory C
            pltpu.VMEM((P, 128), jnp.float32),    # normalizer n (lane-repl.)
            pltpu.VMEM((8, 128), jnp.float32),    # stabilizer m (scalar)
        ],
        interpret=interpret,
        **extra,
    )(q, k, v, logi, logf)

"""jit'd wrapper for the chunkwise mLSTM kernel (model layout in/out)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mlstm_chunk.kernel import mlstm_chunk_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "num_warps",
                                             "pipeline", "interpret"))
def mlstm_chunk(q, k, v, logi, logf, *, chunk: int = 256,
                num_warps: int = None, pipeline: int = None,
                interpret: bool = None):
    """q/k/v [B,S,H,P], logi/logf [B,S,H] -> h [B,S,H,P].

    k must already carry the 1/sqrt(P) scale (as models/xlstm.py projects).
    ``chunk``/``num_warps``/``pipeline`` are SAPPHIRE autotune knobs
    (:func:`autotune_space`).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, H, P = q.shape
    to_flat = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, P)  # noqa
    gate_flat = lambda t: t.transpose(0, 2, 1).reshape(B * H, S)      # noqa
    h = mlstm_chunk_fwd(to_flat(q), to_flat(k), to_flat(v),
                        gate_flat(logi).astype(jnp.float32),
                        gate_flat(logf).astype(jnp.float32),
                        chunk=min(chunk, S), num_warps=num_warps,
                        pipeline=pipeline, interpret=interpret)
    return h.reshape(B, H, S, P).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# autotune hooks (repro.kernels.autotune)
# ---------------------------------------------------------------------------

def autotune_space():
    """Tunable chunking/scheduling space of the mLSTM forward.

    No cross-knob constraint: the carry scratch is [P, P] regardless of
    chunk, and the [C, C] decay tile grows quadratically but stays within
    budget over the whole ladder."""
    from repro.core.space import Knob, Space, pow2_knob
    return Space(
        knobs=(
            pow2_knob("chunk", 256, 16, 512,
                      description="sequence chunk width"),
            pow2_knob("num_warps", 4, 1, 8, inert=True,
                      description="GPU warps per block (inert off-GPU)"),
            Knob("pipeline", "int", 2, lo=1, hi=4, inert=True,
                 description="GPU pipeline stages (inert off-GPU)"),
        ),
    )


def autotune_bench(B: int = 1, S: int = 256, H: int = 2, P: int = 32,
                   seed: int = 0):
    """``build(cfg) -> run()`` factory for :class:`KernelEvaluator`."""
    ks = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(ks[0], (B, S, H, P), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, P), jnp.float32) * 0.5 / (P ** 0.5)
    v = jax.random.normal(ks[2], (B, S, H, P), jnp.float32) * 0.5
    logi = jax.random.normal(ks[3], (B, S, H), jnp.float32)
    logf = -jax.nn.softplus(-jax.random.normal(ks[4], (B, S, H)) * 2.0)

    def build(cfg):
        c = int(cfg["chunk"])
        nw = int(cfg.get("num_warps", 0)) or None
        ps = int(cfg.get("pipeline", 0)) or None

        def run():
            return mlstm_chunk(q, k, v, logi, logf, chunk=c, num_warps=nw,
                               pipeline=ps)
        return run
    return build

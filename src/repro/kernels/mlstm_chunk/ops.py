"""jit'd wrapper for the chunkwise mLSTM kernel (model layout in/out)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mlstm_chunk.kernel import mlstm_chunk_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk(q, k, v, logi, logf, *, chunk: int = 256,
                interpret: bool = None):
    """q/k/v [B,S,H,P], logi/logf [B,S,H] -> h [B,S,H,P].

    k must already carry the 1/sqrt(P) scale (as models/xlstm.py projects).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, H, P = q.shape
    to_flat = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, P)  # noqa
    gate_flat = lambda t: t.transpose(0, 2, 1).reshape(B * H, S)      # noqa
    h = mlstm_chunk_fwd(to_flat(q), to_flat(k), to_flat(v),
                        gate_flat(logi).astype(jnp.float32),
                        gate_flat(logf).astype(jnp.float32),
                        chunk=min(chunk, S), interpret=interpret)
    return h.reshape(B, H, S, P).transpose(0, 2, 1, 3)

"""Pure-jnp oracle: sequential stabilized mLSTM recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mlstm_sequential(q, k, v, logi, logf):
    """q/k/v [B,S,H,P], logi/logf [B,S,H] (k pre-scaled) -> h [B,S,H,P]."""
    B, S, H, P = q.shape
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    li = logi.astype(jnp.float32)
    lf = logf.astype(jnp.float32)

    def step(state, t):
        c, n, m = state                                     # [B,H,P,P] ...
        m_new = jnp.maximum(lf[:, t] + m, li[:, t])
        fw = jnp.exp(lf[:, t] + m - m_new)
        iw = jnp.exp(li[:, t] - m_new)
        c = c * fw[..., None, None] + iw[..., None, None] * jnp.einsum(
            "bhp,bhr->bhpr", kf[:, t], vf[:, t])
        n = n * fw[..., None] + iw[..., None] * kf[:, t]
        num = jnp.einsum("bhp,bhpr->bhr", qf[:, t], c)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, qf[:, t])),
                          jnp.exp(-m_new))
        return (c, n, m_new), num / den[..., None]

    st = (jnp.zeros((B, H, P, P), jnp.float32),
          jnp.zeros((B, H, P), jnp.float32),
          jnp.full((B, H), -1e30, jnp.float32))
    _, hs = jax.lax.scan(step, st, jnp.arange(S))
    return hs.transpose(1, 0, 2, 3).astype(q.dtype)

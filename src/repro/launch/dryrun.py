import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

THE FIRST TWO LINES of this module — before any other import — force 512
placeholder CPU devices so ``jax.make_mesh`` can build the production mesh
(jax locks the device count at first backend init).  Nothing else in the
repo sets this flag: smoke tests and benchmarks see the host's single
device.

For every cell this driver:
  1. builds the full (paper-exact) ModelConfig and the per-arch default
     RunConfig (configs may override defaults via RUN_OVERRIDES — e.g.
     300B+ models default to Adafactor without f32 masters, as any real
     framework's family defaults would);
  2. constructs ShapeDtypeStruct input specs (no allocation anywhere);
  3. jits the train / prefill / decode step with NamedShardings derived
     from the logical-axis rules, ``.lower()``s and ``.compile()``s it on
     the 16×16 (or 2×16×16) mesh;
  4. prints ``compiled.memory_analysis()`` (proof it fits) and
     ``cost_analysis()``, and extracts the three roofline terms from the
     optimized HLO (launch/roofline.py);
  5. writes the record to ``artifacts/dryrun/<arch>.<shape>.<mesh>.json``.

CLI:
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""

import argparse
import importlib
import json
import time
import traceback
from pathlib import Path
from typing import Dict, Optional

import jax

from repro.configs import ARCH_IDS, canonical, get_config
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models.config import (SHAPES_BY_NAME, ModelConfig, ShapeCell,
                                 applicable_shapes)
from repro.models.model import Model
from repro.parallel.sharding import shardings_for
from repro.runconfig import RunConfig, runconfig_from_knobs
from repro.train.train_loop import init_state, make_train_step, state_axes

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def default_runconfig(cfg: ModelConfig, cell: ShapeCell,
                      knobs: Optional[Dict] = None) -> RunConfig:
    """Per-arch default RunConfig (+ optional SAPPHIRE knob overrides)."""
    # the framework's shipped family defaults: memory-safe but untuned
    # (the SAPPHIRE baseline; the paper's "default configuration")
    over: Dict = {}
    if cell.mode == "train":
        over.update(remat_policy="block", microbatch=4)
    if cell.mode == "decode":
        # serving keeps weights data-replicated: ZeRO-3 storage would
        # re-gather every weight on every token (measured 9.3 GB/step)
        over.update(fsdp_shard_params=False)
    if cfg.has_attention:
        # chunked online-softmax everywhere: never materializes [S, S]
        over.update(attention_impl="chunked", chunk_size_k=2048)
    try:
        mod = importlib.import_module(f"repro.configs.{canonical(cfg.name)}")
        over.update(getattr(mod, "RUN_OVERRIDES", {}))
    except ModuleNotFoundError:
        pass
    if cell.name == "long_500k":
        over.setdefault("shard_kv_seq", True)
    if knobs:
        over.update(knobs)
    rc = runconfig_from_knobs(over)
    # non-shard fields live on the flat RunConfig
    fields = {k: v for k, v in over.items() if hasattr(rc, k)}
    return rc.replace(**fields)


def _batch_shardings(specs, mesh, rules):
    """NamedShardings for the input batch: [B, S, ...] over (batch, seq)."""
    def one(s):
        if len(s.shape) == 3 and s.shape[0] == 3:
            ax = (None, "batch", "seq")   # M-RoPE position ids [3, B, S]
        elif len(s.shape) >= 2:
            ax = ("batch", "seq") + (None,) * (len(s.shape) - 2)
        elif len(s.shape) == 1:
            ax = ("batch",)
        else:
            ax = ()
        return shardings_for(s, ax, rules, mesh)
    return jax.tree.map(one, specs)


def lower_cell(cfg: ModelConfig, cell: ShapeCell, rc: RunConfig, mesh):
    """Build (fn, args_specs, in_shardings, out_shardings) for one cell."""
    model = Model(cfg)
    rules = rc.shard.resolve(mesh)
    specs = model.input_specs(cell)

    if cell.mode == "train":
        step = make_train_step(model, rc)
        st_shapes = jax.eval_shape(
            lambda: init_state(model, jax.random.key(0), rc))
        st_axes = state_axes(model, rc)
        st_sh = shardings_for(st_shapes, st_axes, rules, mesh)
        b_sh = _batch_shardings(specs, mesh, rules)
        fn = jax.jit(step, in_shardings=(st_sh, b_sh),
                     donate_argnums=(0,))
        return fn, (st_shapes, specs)

    p_shapes = model.param_shapes()
    p_sh = shardings_for(p_shapes, model.param_axes(), rules, mesh)

    if cell.mode == "prefill":
        def prefill_fn(params, inputs):
            return model.prefill(params, inputs, cell.seq_len, rc)
        b_sh = _batch_shardings(specs, mesh, rules)
        fn = jax.jit(prefill_fn, in_shardings=(p_sh, b_sh))
        return fn, (p_shapes, specs)

    # decode: one token against an S-long cache
    st_shapes = model.decode_state_shapes(cell.global_batch, cell.seq_len, rc)
    st_axes = model.decode_state_axes(rc)
    st_sh = shardings_for(st_shapes, st_axes, rules, mesh)
    b_sh = _batch_shardings(specs, mesh, rules)

    def decode_fn(params, token, state):
        return model.decode_step(params, token, state, rc)

    fn = jax.jit(decode_fn, in_shardings=(p_sh, b_sh["token"], st_sh),
                 donate_argnums=(2,))
    return fn, (p_shapes, specs["token"], st_shapes)


def compile_cell(cfg: ModelConfig, cell: ShapeCell,
                 knobs: Optional[Dict] = None, *, multi_pod: bool = False,
                 verbose: bool = False) -> Dict:
    """lower + compile one cell; return the dry-run record."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    rc = default_runconfig(cfg, cell, knobs)
    t0 = time.monotonic()
    with mesh:
        fn, args = lower_cell(cfg, cell, rc, mesh)
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    t1 = time.monotonic()

    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_size_gb": mem.argument_size_in_bytes / 2**30,
            "output_size_gb": mem.output_size_in_bytes / 2**30,
            "temp_size_gb": mem.temp_size_in_bytes / 2**30,
            "generated_code_gb": mem.generated_code_size_in_bytes / 2**30,
        }
    except Exception as e:                      # backend without the API
        mem_rec = {"unavailable": repr(e)}
    try:
        cost = dict(compiled.cost_analysis() or {})
    except Exception:
        cost = {}
    hlo = compiled.as_text()
    report = rl.analyze_hlo(hlo, raw_cost=cost)

    train = cell.mode == "train"
    tokens = cell.global_batch * (1 if cell.mode == "decode" else cell.seq_len)
    mflops = rl.model_flops(cfg.active_param_count(), tokens, train)
    chips = 512 if multi_pod else 256
    hlo_flops_global = report.flops * chips

    record = {
        "arch": cfg.name, "shape": cell.name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "mode": cell.mode,
        "compile_s": round(t1 - t0, 2),
        "memory": mem_rec,
        "roofline": {
            "flops_per_device": report.flops,
            "hbm_bytes_per_device": report.bytes_proxy,
            "collective_bytes_per_device": report.collective_bytes,
            "coll_by_kind": report.coll_by_kind,
            "compute_s": report.compute_s,
            "memory_s": report.memory_s,
            "collective_s": report.collective_s,
            "step_s": report.step_s,
            "dominant": report.dominant,
            "trip_counts": report.trip_counts,
        },
        "model_flops_6nd": mflops,
        "useful_flops_ratio": mflops / hlo_flops_global
        if hlo_flops_global else None,
        "raw_cost_analysis_flops": cost.get("flops"),
        "runconfig": {k: getattr(rc, k) for k in
                      ("microbatch", "remat_policy", "attention_impl",
                       "optimizer", "master_weights_f32",
                       "grad_allreduce_dtype")},
    }
    if verbose:
        print(json.dumps(record, indent=1, default=str))
    return record


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             knobs: Optional[Dict] = None, save: bool = True,
             verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    cell = SHAPES_BY_NAME[shape]
    if cell not in applicable_shapes(cfg):
        rec = {"arch": cfg.name, "shape": shape, "skipped": True,
               "reason": "full-attention arch skips long_500k (DESIGN.md §6)"}
        print(f"SKIP {arch} {shape}: {rec['reason']}")
        return rec
    rec = compile_cell(cfg, cell, knobs, multi_pod=multi_pod, verbose=verbose)
    if save:
        ARTIFACTS.mkdir(parents=True, exist_ok=True)
        mesh_tag = "2x16x16" if multi_pod else "16x16"
        out = ARTIFACTS / f"{canonical(arch)}.{shape}.{mesh_tag}.json"
        out.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            cfg = get_config(a)
            for cell in applicable_shapes(cfg):
                cells.append((a, cell.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = []
    for mp in meshes:
        for arch, shape in cells:
            tag = f"{arch} {shape} {'2x16x16' if mp else '16x16'}"
            out = ARTIFACTS / (f"{canonical(arch)}.{shape}."
                               f"{'2x16x16' if mp else '16x16'}.json")
            if args.skip_existing and out.exists():
                print(f"SKIP (cached) {tag}")
                continue
            print(f"=== {tag} ===", flush=True)
            try:
                rec = run_cell(arch, shape, multi_pod=mp, verbose=False)
                if not rec.get("skipped"):
                    r = rec["roofline"]
                    print(f"  ok compile={rec['compile_s']}s "
                          f"step={r['step_s']:.4f}s dominant={r['dominant']} "
                          f"(c={r['compute_s']:.4f} m={r['memory_s']:.4f} "
                          f"x={r['collective_s']:.4f})", flush=True)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"  FAIL: {e}\n{traceback.format_exc()}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall cells compiled")


if __name__ == "__main__":
    main()

"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set
XLA_FLAGS before the first jax device query, and smoke tests must see the
real single CPU device.

  single-pod : (16, 16)        axes ("data", "model")      — 256 chips
  multi-pod  : (2, 16, 16)     axes ("pod", "data", "model") — 512 chips
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (smoke tests / examples): 1 device."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))

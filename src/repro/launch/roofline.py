"""Roofline extraction from compiled HLO (§Roofline of EXPERIMENTS.md).

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, not
×trip-count (verified empirically on this container), so a scanned 48-layer
model would look 48× too cheap.  This module re-derives the three roofline
terms by parsing the post-SPMD optimized HLO text:

  * split the module into computations;
  * per computation: dot/convolution FLOPs from operand shapes, an HBM
    traffic proxy (op output bytes + parameter bytes), and collective bytes
    (all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute — sum of result-shape bytes, the per-device proxy);
  * build the call graph (while body/cond with parsed trip counts, fusion
    ``calls=``, ``to_apply=``, conditional branches) and accumulate costs
    ×multiplier from ENTRY.

Post-SPMD shapes are PER-DEVICE, so terms divide by per-chip peak rates:

    compute_s    = flops_per_device   / peak_flops
    memory_s     = hbm_bytes_proxy    / hbm_bw
    collective_s = collective_bytes   / ici_bw
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.costmodel import Hardware, V5E

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|bf16|f8e4m3fn|f8e5m2|[fsuc]\d+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_CALL_ATTRS = ("to_apply=", "condition=", "body=", "calls=")
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _first_shape(line: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(line)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d.strip()]
    return m.group(1), dims


@dataclass
class CompCost:
    flops: float = 0.0
    bytes_proxy: float = 0.0
    collective_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    # (callee, mult, include_bytes): fusion bodies contribute flops but not
    # HBM bytes (their intermediates live in registers/VMEM)
    calls: List[Tuple[str, float, bool]] = field(default_factory=list)
    whiles: List[Tuple[str, str]] = field(default_factory=list)  # (body, cond)
    trip_hint: Optional[int] = None          # if this is a while condition


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    buf: List[str] = []
    for line in hlo.splitlines():
        stripped = line.strip()
        hdr = _COMP_HDR.match(line) or _COMP_HDR.match(stripped)
        if hdr and "{" in line:
            cur = hdr.group(1)
            buf = []
            comps[cur] = buf
            continue
        if stripped == "}" or stripped.startswith("} //"):
            cur = None
            continue
        if cur is not None:
            buf.append(stripped)
    return comps


_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?)")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")


def _line_shapes_bytes(line: str, upto: Optional[int] = None) -> int:
    seg = line if upto is None else line[:upto]
    return sum(_shape_bytes(m.group(1), m.group(2))
               for m in _SHAPE_RE.finditer(seg))


def _def_shape_dims(line: str) -> Optional[List[List[int]]]:
    """Result shape(s) of an op-definition line (list per tuple element)."""
    eq = line.find("=")
    if eq < 0:
        return None
    # shapes between '=' and the op name '(': first '(' after a word char
    m_op = re.search(r"=\s*(\(?[^=]*?)\s[a-z][\w\-]*\(", line)
    seg = line[eq:m_op.end()] if m_op else line[eq:]
    out = [[int(d) for d in m.group(2).split(",") if d.strip()]
           for m in _SHAPE_RE.finditer(seg)]
    return out or None


def _dot_flops(line: str, defs: Dict[str, List[int]]) -> float:
    """FLOPs of a dot op: 2 × prod(output) × prod(contracted lhs dims).

    Post-optimization HLO omits operand shapes inline, so the lhs shape is
    looked up from the computation/module symbol table ``defs``.
    """
    shapes = _def_shape_dims(line)
    if not shapes:
        return 0.0
    out = 1
    for d in shapes[0]:
        out *= d
    dot_at = line.find(" dot(")
    ops = _OPERANDS_RE.findall(line[dot_at:])
    lhs_dims = defs.get(ops[0]) if ops else None
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    contract = [int(i) for i in m.group(1).split(",")] if m and m.group(1) \
        else []
    c = 1
    if lhs_dims:
        for i in contract:
            if i < len(lhs_dims):
                c *= lhs_dims[i]
    # TPU bf16 precision passes: default 1, high ~3, highest ~6 — shapes
    # don't change, so the multiplier must come from the attribute
    mult = 1.0
    if "operand_precision={high," in line:
        mult = 3.0
    elif "operand_precision={highest," in line:
        mult = 6.0
    return 2.0 * out * c * mult


def _conv_flops(line: str, defs: Dict[str, List[int]]) -> float:
    """Convolution FLOPs ≈ 2 × prod(output) × (kernel taps × in-ch)."""
    shapes = _def_shape_dims(line)
    if not shapes:
        return 0.0
    out = 1
    for d in shapes[0]:
        out *= d
    conv_at = line.find(" convolution(")
    ops = _OPERANDS_RE.findall(line[conv_at:])
    ker = defs.get(ops[1]) if len(ops) > 1 else None
    if not ker:
        return 2.0 * out
    kprod = 1
    for d in ker:
        kprod *= d
    out_ch = shapes[0][-1] if shapes[0] else 1
    return 2.0 * out * max(kprod // max(out_ch, 1), 1)


def _build_defs(lines: List[str]) -> Dict[str, List[int]]:
    """Symbol table: op name -> result dims (first tuple element)."""
    defs: Dict[str, List[int]] = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        dims = _def_shape_dims(line)
        if dims:
            defs[m.group(1)] = dims[0]
    return defs


def _analyze_comp(lines: List[str], defs: Dict[str, List[int]]) -> CompCost:
    c = CompCost()
    max_const = 0
    has_lt = False
    for line in lines:
        is_def = _DEF_RE.match(line) is not None
        res_dims = _def_shape_dims(line) if is_def else None
        # HBM proxy: result bytes of *top-level* ops (fusion internals are
        # excluded by not traversing fusion bodies for bytes).  Metadata
        # ops move no bytes; dynamic-update-slice writes only its update.
        skip = any(f" {op}(" in line for op in
                   ("tuple", "get-tuple-element", "bitcast", "constant",
                    "after-all", "partition-id", "iota", "parameter",
                    "while", "conditional"))
        if res_dims is not None and not skip:
            if " dynamic-update-slice(" in line:
                at = line.find(" dynamic-update-slice(")
                ops_ = _OPERANDS_RE.findall(line[at:])
                upd = defs.get(ops_[1]) if len(ops_) > 1 else None
                if upd is not None:
                    n = 1
                    for d in upd:
                        n *= d
                    c.bytes_proxy += n * 4        # update write (+read)
                    continue
            if "dynamic-update-slice" in line.split("=")[0]:
                # fusion whose root is a DUS into a scan-stacked buffer:
                # one iteration writes ONE slice, not the whole stack
                lead = res_dims[0][0] if res_dims[0] else 1
                n = 1
                for d in res_dims[0]:
                    n *= d
                mdt = _SHAPE_RE.search(line[line.find("="):])
                bpe = DTYPE_BYTES.get(mdt.group(1), 4) if mdt else 4
                c.bytes_proxy += n * bpe / max(lead, 1)
                continue
            for dims in res_dims:
                n = 1
                for d in dims:
                    n *= d
                mdt = _SHAPE_RE.search(line[line.find("="):])
                bpe = DTYPE_BYTES.get(mdt.group(1), 4) if mdt else 4
                c.bytes_proxy += n * bpe
        if " dot(" in line:
            c.flops += _dot_flops(line, defs)
        elif " convolution(" in line:
            c.flops += _conv_flops(line, defs)
        for kind in COLLECTIVES:
            if f" {kind}(" in line or f" {kind}-start(" in line:
                op_at = line.find(f" {kind}")
                b = _line_shapes_bytes(line, op_at)
                # The CPU backend PROMOTES bf16 reductions to f32 (its
                # reducers lack native bf16); TPU reduces bf16 natively.
                # Promoted all-reduces are tagged `to_apply=%..promoted`
                # — halve their bytes to model the TPU target.
                if kind == "all-reduce" and "promot" in line:
                    b *= 0.5
                c.collective_bytes += b
                c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0.0) + b
                break
        m = re.search(r"constant\((\d+)\)", line)
        if m:
            max_const = max(max_const, int(m.group(1)))
        if "direction=LT" in line or "direction=GT" in line:
            has_lt = True
        # call edges
        if " while(" in line:
            mb = re.search(r"body=%?([\w\.\-]+)", line)
            mc = re.search(r"condition=%?([\w\.\-]+)", line)
            if mb and mc:
                c.whiles.append((mb.group(1), mc.group(1)))  # paired!
        else:
            include_bytes = " fusion(" not in line
            for attr in ("calls=", "to_apply=", "condition=", "body="):
                for m2 in re.finditer(attr + r"%?([\w\.\-]+)", line):
                    c.calls.append((m2.group(1), 1.0, include_bytes))
            m3 = re.search(r"branch_computations=\{([^}]*)\}", line)
            if m3:
                for name in m3.group(1).split(","):
                    c.calls.append((name.strip().lstrip("%"), 1.0, True))
    # trip hint: the largest scalar constant in the computation.  Only
    # consulted for computations referenced via ``condition=`` (where the
    # loop bound constant lives; the LT compare itself may sit in a fused
    # callee), so body-side constants never masquerade as trip counts.
    if max_const > 0:
        c.trip_hint = max_const
    return c


@dataclass
class RooflineReport:
    flops: float                 # per-device, trip-corrected
    bytes_proxy: float           # per-device HBM traffic proxy
    collective_bytes: float      # per-device
    coll_by_kind: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    step_s: float
    dominant: str
    raw_cost_analysis: Dict[str, float]
    trip_counts: Dict[str, int]

    def terms(self) -> Dict[str, float]:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s, "step_s": self.step_s,
                "dominant": self.dominant}


def analyze_hlo(hlo_text: str, hw: Hardware = V5E,
                raw_cost: Optional[Dict[str, float]] = None) -> RooflineReport:
    comps = _split_computations(hlo_text)
    # module-wide symbol table (HLO op names are unique per module)
    defs: Dict[str, List[int]] = {}
    for lines in comps.values():
        defs.update(_build_defs(lines))
    costs = {name: _analyze_comp(lines, defs) for name, lines in comps.items()}

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line)
            if m:
                entry = m.group(1)
    if entry is None:
        # fall back: the computation named main-ish or the largest
        entry = max(costs, key=lambda n: len(comps[n])) if costs else None

    total = CompCost()
    trip_counts: Dict[str, int] = {}
    visiting: set = set()

    def accumulate(name: str, mult: float, include_bytes: bool = True):
        if name not in costs or mult <= 0 or name in visiting:
            return
        visiting.add(name)
        c = costs[name]
        total.flops += c.flops * mult
        if include_bytes:
            total.bytes_proxy += c.bytes_proxy * mult
        total.collective_bytes += c.collective_bytes * mult
        for k, v in c.coll_by_kind.items():
            total.coll_by_kind[k] = total.coll_by_kind.get(k, 0.0) + v * mult
        for body, cond in c.whiles:
            trip = 1
            if cond in costs and costs[cond].trip_hint:
                trip = costs[cond].trip_hint
            trip_counts[body] = max(trip_counts.get(body, 0), trip)
            accumulate(cond, mult, include_bytes)
            accumulate(body, mult * trip, include_bytes)
        for callee, m, inc_b in c.calls:
            accumulate(callee, mult * m, include_bytes and inc_b)
        visiting.discard(name)

    if entry:
        accumulate(entry, 1.0)

    compute_s = total.flops / hw.peak_flops
    memory_s = total.bytes_proxy / hw.hbm_bw
    collective_s = total.collective_bytes / hw.ici_bw
    step = max(compute_s, memory_s, collective_s)
    dominant = ("compute" if step == compute_s else
                "memory" if step == memory_s else "collective")
    step += 0.15 * (compute_s + memory_s + collective_s - step)
    return RooflineReport(
        flops=total.flops, bytes_proxy=total.bytes_proxy,
        collective_bytes=total.collective_bytes,
        coll_by_kind=dict(total.coll_by_kind),
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        step_s=step, dominant=dominant,
        raw_cost_analysis=raw_cost or {}, trip_counts=trip_counts,
    )


def model_flops(n_params_active: int, tokens: int, train: bool) -> float:
    """The 6·N·D (train) / 2·N·D (inference) reference quantity."""
    return (6.0 if train else 2.0) * n_params_active * tokens

"""Serving driver: continuous-batching engine on a (smoke) config.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 12
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.model import Model
from repro.runconfig import runconfig_from_knobs
from repro.serve.engine import Engine
from repro.launch.train import parse_knobs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--knob", action="append", default=[])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_smoke_config(args.arch)
    rc = runconfig_from_knobs(parse_knobs(args.knob))
    model = Model(cfg)
    params = model.init(jax.random.key(args.seed))
    eng = Engine(model, params, rc, slots=args.slots, s_max=args.s_max)

    rng = np.random.default_rng(args.seed)
    t0 = time.monotonic()
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        prompt = rng.integers(1, cfg.vocab_size, size=plen)
        eng.submit(prompt, max_new_tokens=args.max_new)
    done = eng.run()
    dt = time.monotonic() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s, {eng.step_count} engine steps)")
    for r in done[:4]:
        print(f"  rid {r.rid}: prompt {len(r.prompt)} -> {r.out_tokens[:8]}…")


if __name__ == "__main__":
    main()

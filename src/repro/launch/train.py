"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 200 \
        --smoke --ckpt-dir /tmp/ckpt [--resume] [--knob microbatch=4 ...]

On this container it drives the reduced (--smoke) configs on the host
mesh; on a fleet the same driver runs the full config on the production
mesh (launch/mesh.py).  Integrates the whole runtime: RunConfig knobs,
sharded train step, stateless data stream, checkpoint/auto-resume, and
the step-time watchdog feeding the elastic policy.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.runconfig import runconfig_from_knobs
from repro.train import elastic
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticDataset
from repro.train.train_loop import init_state, make_train_step


def parse_knobs(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("true", "True"):
            v = True
        if v in ("false", "False"):
            v = False
        out[k] = v
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--knob", action="append", default=[],
                    help="RunConfig override, e.g. --knob microbatch=2")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    rc = runconfig_from_knobs(parse_knobs(args.knob))
    model = Model(cfg)
    mesh = make_host_mesh()

    cm = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    watchdog = elastic.StepWatchdog()

    with mesh:
        state = init_state(model, jax.random.key(args.seed), rc)
        start = 0
        if cm and args.resume and cm.latest_step() is not None:
            state, start = cm.restore(state)
            print(f"resumed from step {start}")
        step_fn = jax.jit(make_train_step(
            model, rc, lr_schedule=lambda s: args.lr))
        data = SyntheticDataset(args.seed, args.global_batch, args.seq_len,
                                cfg.vocab_size, start_step=start)
        t_last = time.monotonic()
        for i in range(start, args.steps):
            batch = next(data)
            state, mets = step_fn(state, batch)
            now = time.monotonic()
            watchdog.observe(0, now - t_last)
            t_last = now
            if (i + 1) % 10 == 0 or i == start:
                print(f"step {i+1:5d} loss {float(mets['loss']):.4f} "
                      f"gnorm {float(mets['grad_norm']):.3f} "
                      f"lr {float(mets['lr']):.2e}")
            if cm and (i + 1) % args.ckpt_every == 0:
                cm.save(i + 1, state, blocking=False)
        if cm:
            cm.save(args.steps, state, blocking=True)
            print(f"final checkpoint at step {args.steps} -> {cm.root}")
    print("done")


if __name__ == "__main__":
    main()

from repro.models.config import (  # noqa: F401
    ATTN, MAMBA, MLSTM, SLSTM, MLP_DENSE, MLP_MOE, MLP_NONE,
    LayerSpec, ModelConfig, ShapeCell, SHAPES, SHAPES_BY_NAME,
    applicable_shapes,
)
from repro.models.model import Model, make_model  # noqa: F401

"""GQA attention: reference, chunked (memory-efficient), flash, and decode.

Three selectable implementations (the ``attention_impl`` knob — a C3
module-selector in SAPPHIRE's space):

* ``reference`` — plain einsum softmax attention; materializes the [S, S]
  score matrix.  The pure-jnp oracle for everything else.
* ``chunked``   — online-softmax over KV chunks via ``lax.scan``; never
  materializes [S, S].  Same memory asymptotics as flash attention and
  compilable on any backend — this is what the dry-run lowers when the
  flash kernel is selected (the Pallas kernel itself targets TPU and is
  validated in interpret mode; see kernels/flash_attention.py).
* ``flash``     — Pallas TPU kernel (kernels/flash_attention.py) with
  BlockSpec VMEM tiling; block sizes are tuned knobs.

Decode attends a 1-token query against a KV cache (layout knob bshd/bhsd,
dtype knob bf16/int8-sim).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import dense_apply, dense_axes, dense_init
from repro.models.config import ModelConfig
from repro.models.rotary import apply_rope
from repro.runconfig import RunConfig

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init(rng, cfg: ModelConfig, dtype=jnp.bfloat16):
    kq, kk, kv, ko = jax.random.split(rng, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return {
        "q": dense_init(kq, d, qd, bias=cfg.qkv_bias, dtype=dtype),
        "k": dense_init(kk, d, kvd, bias=cfg.qkv_bias, dtype=dtype),
        "v": dense_init(kv, d, kvd, bias=cfg.qkv_bias, dtype=dtype),
        "o": dense_init(ko, qd, d, dtype=dtype, scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }


def axes(cfg: ModelConfig):
    b = cfg.qkv_bias
    return {
        "q": dense_axes("qkv_in", "heads", bias=b),
        "k": dense_axes("qkv_in", "kv_heads", bias=b),
        "v": dense_axes("qkv_in", "kv_heads", bias=b),
        "o": dense_axes("heads", "o_out"),
    }


# ---------------------------------------------------------------------------
# core softmax attention paths
# ---------------------------------------------------------------------------

def _causal_mask(sq: int, sk: int, offset: int, window: Optional[int]):
    """[sq, sk] boolean mask.  offset = absolute position of query row 0
    minus that of key column 0 (0 for self-attention over same range)."""
    qi = jnp.arange(sq)[:, None] + offset
    ki = jnp.arange(sk)[None, :]
    m = ki <= qi
    if window is not None:
        m &= ki > (qi - window)
    return m


def reference_attention(q, k, v, *, causal: bool, window: Optional[int],
                        softcap: Optional[float], offset: int = 0):
    """q [B,Sq,H,D], k/v [B,Sk,Kh,D] -> [B,Sq,H,D].  Materializes scores."""
    B, Sq, H, D = q.shape
    Kh = k.shape[2]
    rep = H // Kh
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(D)
    scores = common.softcap(scores, softcap)
    if causal or window is not None:
        # All assigned archs use causal (optionally windowed) masks; a window
        # without causal still masks causally (sliding windows are causal).
        m = _causal_mask(Sq, k.shape[1], offset, window)
        scores = jnp.where(m[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vr.dtype), vr,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool, window: Optional[int],
                      softcap: Optional[float], chunk: int, offset: int = 0):
    """Online-softmax over KV chunks; O(Sq·chunk) live memory.

    Equivalent to reference_attention (tests assert allclose); this is the
    compilable stand-in for the flash Pallas kernel.
    """
    B, Sq, H, D = q.shape
    Sk, Kh = k.shape[1], k.shape[2]
    rep = H // Kh
    chunk = min(chunk, Sk)
    n_chunks = (Sk + chunk - 1) // chunk
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, Kh, D)
    vc = v.reshape(B, n_chunks, chunk, Kh, D)

    qf = q.astype(jnp.float32) / math.sqrt(D)

    def body(carry, xs):
        m_prev, l_prev, acc = carry          # [B,H,Sq], [B,H,Sq], [B,Sq,H,D]
        ci, kci, vci = xs                    # kci/vci [B,chunk,Kh,D]
        kr = jnp.repeat(kci, rep, axis=2)
        vr = jnp.repeat(vci, rep, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kr.astype(jnp.float32))
        s = common.softcap(s, softcap)
        # ADDITIVE 2-D mask [Sq, chunk]: a boolean `where` broadcast to
        # [B,H,Sq,chunk] gets hoisted out of the scan by XLA and
        # materialized for every chunk (512 MB-scale buffers per layer);
        # the additive form stays 2-D and fuses into the einsum epilogue.
        kidx = ci * chunk + jnp.arange(chunk)
        qidx = jnp.arange(Sq) + offset
        neg = jnp.where(kidx[None, :] < Sk, 0.0, NEG_INF)       # pad
        if causal:
            neg = neg + jnp.where(kidx[None, :] <= qidx[:, None], 0.0,
                                  NEG_INF)
        if window is not None:
            neg = neg + jnp.where(kidx[None, :] > (qidx[:, None] - window),
                                  0.0, NEG_INF)
        neg = jnp.maximum(neg, NEG_INF)      # avoid -inf arithmetic
        s = s + neg[None, None]
        m_cur = jnp.max(s, axis=-1)                     # [B,H,Sq]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                 # rescale old
        p = jnp.exp(s - m_new[..., None])               # [B,H,Sq,K]
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p, vr.astype(jnp.float32))
        acc = acc * alpha.transpose(0, 2, 1)[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, H, D), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.arange(n_chunks), kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4)),
    )
    l_f = jnp.maximum(l_f, 1e-30)
    out = acc / l_f.transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def flash_attention_dispatch(q, k, v, *, causal, window, softcap, rc: RunConfig):
    """Route to the Pallas kernel on TPU; chunked equivalent elsewhere."""
    backend = jax.default_backend()
    if backend == "tpu":
        from repro.kernels.flash_attention import ops as flash_ops
        return flash_ops.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            block_q=rc.flash_block_q, block_k=rc.flash_block_k)
    # CPU/GPU dry-run: same memory asymptotics via the chunked path.
    return chunked_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, chunk=rc.flash_block_k)


# ---------------------------------------------------------------------------
# layer-level apply (projections + rope + attention + output proj)
# ---------------------------------------------------------------------------

def apply(params, x, positions, cfg: ModelConfig, rc: RunConfig, *,
          causal: bool = True, window: Optional[int] = None,
          kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
          use_rope: bool = True):
    """Full-sequence attention (train / prefill).

    x [B, S, d_model]; positions [B, S] (or [3,B,S] for M-RoPE).
    kv_override: (k, v) already-projected tensors for cross-attention.
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    prec = jax.lax.Precision(rc.matmul_precision) \
        if rc.matmul_precision != "default" else None

    # q/k/v have no fwd AR (contraction is replicated) but their BWD
    # dgrad contracts the TP-sharded head dim -> partial sums; the bf16
    # reduce path halves those too
    red = common.reduce_dtype(rc)
    q = dense_apply(params["q"], x, precision=prec,
                    preferred=red).reshape(B, S, cfg.n_heads, hd)
    if kv_override is None:
        k = dense_apply(params["k"], x, precision=prec,
                        preferred=red).reshape(B, S, cfg.n_kv_heads, hd)
        v = dense_apply(params["v"], x, precision=prec,
                        preferred=red).reshape(B, S, cfg.n_kv_heads, hd)
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        k, v = kv_override
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)

    impl = rc.attention_impl
    if impl == "reference":
        out = reference_attention(q, k, v, causal=causal, window=window,
                                  softcap=cfg.logit_softcap)
    elif impl == "chunked":
        out = chunked_attention(q, k, v, causal=causal, window=window,
                                softcap=cfg.logit_softcap, chunk=rc.chunk_size_k)
    elif impl == "flash":
        out = flash_attention_dispatch(q, k, v, causal=causal, window=window,
                                       softcap=cfg.logit_softcap, rc=rc)
    else:
        raise ValueError(f"unknown attention_impl {impl!r}")

    out = out.reshape(B, S, cfg.q_dim)
    # o-proj contracts the TP-sharded heads dim -> partial sums cross
    # shards; rc.tp_reduce_dtype picks the reduction dtype
    return dense_apply(params["o"], out, precision=prec,
                       preferred=common.reduce_dtype(rc))


def project_kv(params, x, positions, cfg: ModelConfig, *, use_rope: bool = True):
    """Project (and rotate) K/V for cache fill / cross-attention memory."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    k = dense_apply(params["k"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = dense_apply(params["v"], x).reshape(B, S, cfg.n_kv_heads, hd)
    if use_rope:
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return k, v


# ---------------------------------------------------------------------------
# decode step with KV cache
# ---------------------------------------------------------------------------

def decode_apply(params, x, cache_k, cache_v, pos, cfg: ModelConfig,
                 rc: RunConfig, *, window: Optional[int] = None,
                 cross: bool = False, cross_len: Optional[int] = None,
                 use_rope: bool = True):
    """One-token decode.

    x        [B, 1, d_model]
    cache_k/v: layout per rc.kv_layout —
               bshd: [B, S_max, Kh, D]; bhsd: [B, Kh, S_max, D]
    pos      int32 scalar OR [B] vector — tokens already in each cache
             row.  The vector form is what continuous batching needs:
             every slot decodes at its own position (serve/engine.py).
    cross    : cross-attention (cache holds encoder memory; no update).
    Returns (out [B,1,d_model], new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = jnp.asarray(pos, jnp.int32)
    pos_vec = jnp.broadcast_to(pos.reshape(-1), (B,)) if pos.ndim <= 1 \
        else pos
    q = dense_apply(params["q"], x).reshape(B, 1, cfg.n_heads, hd)
    positions = pos_vec[:, None]
    if not cross:
        if use_rope:
            q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k_new = dense_apply(params["k"], x).reshape(B, 1, cfg.n_kv_heads, hd)
        v_new = dense_apply(params["v"], x).reshape(B, 1, cfg.n_kv_heads, hd)
        if use_rope:
            k_new = apply_rope(k_new, positions, cfg.rope_theta, cfg.mrope_sections)
        cache_k = _cache_insert(cache_k, k_new, pos_vec, rc)
        cache_v = _cache_insert(cache_v, v_new, pos_vec, rc)
        kv_len = pos_vec + 1                              # [B]
    else:
        kv_len = jnp.broadcast_to(jnp.asarray(cross_len, jnp.int32), (B,))

    k = _cache_read(cache_k, rc)           # [B, S_max, Kh, D] bf16/f32
    v = _cache_read(cache_v, rc)
    S_max = k.shape[1]

    rep = cfg.n_heads // cfg.n_kv_heads
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    s = common.softcap(s, cfg.logit_softcap)
    kidx = jnp.arange(S_max)
    m = kidx[None, :] < kv_len[:, None]                   # [B, S_max]
    if window is not None and not cross:
        m &= kidx[None, :] > (kv_len[:, None] - 1 - window)
    s = jnp.where(m[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(vr.dtype), vr,
                     preferred_element_type=jnp.float32)
    out = out.astype(x.dtype).reshape(B, 1, cfg.q_dim)
    out = dense_apply(params["o"], out, preferred=common.reduce_dtype(rc))
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# KV cache helpers (layout + dtype knobs)
# ---------------------------------------------------------------------------

def init_cache(batch: int, s_max: int, cfg: ModelConfig, rc: RunConfig):
    """One layer's (k, v) cache buffers."""
    shape_bshd = (batch, s_max, cfg.n_kv_heads, cfg.resolved_head_dim)
    if rc.kv_layout == "bhsd":
        shape = (batch, cfg.n_kv_heads, s_max, cfg.resolved_head_dim)
    else:
        shape = shape_bshd
    if rc.kv_cache_dtype == "int8":
        k = jnp.zeros(shape, jnp.int8)
        v = jnp.zeros(shape, jnp.int8)
    else:
        k = jnp.zeros(shape, common.dtype_of(rc.kv_cache_dtype))
        v = jnp.zeros(shape, common.dtype_of(rc.kv_cache_dtype))
    return k, v


def cache_axes(rc: RunConfig):
    if rc.kv_layout == "bhsd":
        ax = ("batch", "kv_heads", "kv_seq", "head_dim")
    else:
        ax = ("batch", "kv_seq", "kv_heads", "head_dim")
    return ax, ax


_INT8_SCALE = 127.0 / 8.0   # static symmetric scale for simulated int8 KV


def _quantize(x):
    return jnp.clip(jnp.round(x.astype(jnp.float32) * _INT8_SCALE),
                    -127, 127).astype(jnp.int8)


def _dequantize(x):
    return (x.astype(jnp.float32) / _INT8_SCALE).astype(jnp.bfloat16)


def _cache_insert(cache, new, pos, rc: RunConfig):
    """Insert new [B,1,Kh,D] at per-row position pos [B] (layout-aware)."""
    if cache.dtype == jnp.int8:
        new = _quantize(new)
    else:
        new = new.astype(cache.dtype)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1),
                           (cache.shape[0],))
    if rc.kv_layout == "bhsd":
        new = new.transpose(0, 2, 1, 3)    # [B,Kh,1,D]
        return jax.vmap(
            lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (0, p, 0))
        )(cache, new, pos)
    return jax.vmap(
        lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (p, 0, 0))
    )(cache, new, pos)


def _cache_read(cache, rc: RunConfig):
    """Return cache as [B, S_max, Kh, D] in a compute dtype."""
    x = cache
    if rc.kv_layout == "bhsd":
        x = x.transpose(0, 2, 1, 3)
    if x.dtype == jnp.int8:
        x = _dequantize(x)
    return x


def read_cache_full(cache, rc: RunConfig):
    """Whole cache as [B, S, Kh, D] in compute dtype (cross-attn memory)."""
    return _cache_read(cache, rc)


def fill_cache(cache, kv, rc: RunConfig):
    """Bulk-fill a cache prefix with prefill K/V [B, S, Kh, D]."""
    if cache.dtype == jnp.int8:
        kv = _quantize(kv)
    else:
        kv = kv.astype(cache.dtype)
    if rc.kv_layout == "bhsd":
        kv = kv.transpose(0, 2, 1, 3)
        return jax.lax.dynamic_update_slice(cache, kv, (0, 0, 0, 0))
    return jax.lax.dynamic_update_slice(cache, kv, (0, 0, 0, 0))

"""Parameter helpers, norms and activations shared by the model zoo.

Convention: every layer module exposes
    init(rng, cfg, ...)  -> params  (pytree of arrays)
    axes(cfg, ...)       -> pytree of logical-axis tuples, same structure
    apply(params, x, ...)-> output
Parameters are created in ``param_dtype`` (bf16 by default) with f32 master
copies owned by the optimizer (train/optimizer.py).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16, "int8": jnp.int8}[name]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def trunc_normal(rng, shape, scale: float, dtype=jnp.bfloat16):
    """Truncated-normal init with fan-in style scale."""
    std = scale / math.sqrt(max(shape[0], 1)) if len(shape) >= 2 else scale
    x = jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32) * std
    return x.astype(dtype)


def dense_init(rng, in_dim: int, out_dim: int, *, bias: bool = False,
               dtype=jnp.bfloat16, scale: float = 1.0):
    kw, kb = jax.random.split(rng)
    p = {"w": trunc_normal(kw, (in_dim, out_dim), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense_axes(in_axis: Optional[str], out_axis: Optional[str], *, bias: bool = False):
    ax = {"w": (in_axis, out_axis)}
    if bias:
        ax["b"] = (out_axis,)
    return ax


@jax.custom_vjp
def _mm_bf16_reduce(x, w):
    """Matmul whose cross-shard partial sums (fwd AND bwd dgrad) combine
    in bf16 — halves every TP activation all-reduce.  The MXU still
    accumulates f32 internally; only the inter-chip combine narrows
    (Megatron's standard trade).  Weight grads stay f32-accumulated."""
    return jnp.matmul(x, w, preferred_element_type=jnp.bfloat16)


def _mm_bf16_fwd(x, w):
    return _mm_bf16_reduce(x, w), (x, w)


def _mm_bf16_bwd(res, g):
    x, w = res
    gb = g.astype(jnp.bfloat16)             # cotangent in bf16: dgrad AR halves
    dx = jnp.matmul(gb, w.T, preferred_element_type=jnp.bfloat16)
    dw = jnp.matmul(x.reshape(-1, x.shape[-1]).T,
                    gb.reshape(-1, gb.shape[-1]),
                    preferred_element_type=jnp.float32)
    return dx.astype(x.dtype), dw.astype(w.dtype)


_mm_bf16_reduce.defvjp(_mm_bf16_fwd, _mm_bf16_bwd)


def dense_apply(p, x, *, precision=None, preferred=None):
    """preferred: accumulation/partial-sum dtype.  For matmuls whose
    contraction dim is TP-sharded, bf16 halves the all-reduce bytes (the
    MXU still accumulates f32 internally; only the cross-shard combine is
    reduced precision — Megatron's standard trade)."""
    if preferred == jnp.bfloat16:
        y = _mm_bf16_reduce(x, p["w"])
        if "b" in p:
            y = y + p["b"]
        return y.astype(x.dtype)
    y = jnp.matmul(x, p["w"], precision=precision,
                   preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


def reduce_dtype(rc) -> "jnp.dtype":
    return jnp.bfloat16 if getattr(rc, "tp_reduce_dtype", "float32")         == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(d: int, kind: str = "rmsnorm", dtype=jnp.bfloat16):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_axes(kind: str = "rmsnorm"):
    ax = {"scale": ("embed",)}
    if kind == "layernorm":
        ax["bias"] = ("embed",)
    return ax


def norm_apply(p, x, *, kind: str = "rmsnorm", eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def softcap(x, cap: Optional[float]):
    """Grok-style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# pytree helpers
# ---------------------------------------------------------------------------

def stack_init(rng, n: int, init_fn):
    """Initialize ``n`` copies of a layer and stack each leaf on axis 0.

    Used to build scan-over-groups parameter stacks; the stacked axis gets
    logical name None (never sharded).
    """
    rngs = jax.random.split(rng, n)
    ps = [init_fn(r) for r in rngs]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *ps)


def stack_axes(axes_tree):
    """Prepend the (unsharded) stack axis to every logical-axes tuple."""
    return jax.tree.map(
        lambda ax: (None,) + tuple(ax),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def cast_tree(params, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, params)

"""Model configuration dataclasses for the architecture zoo.

Every assigned architecture is expressed as a :class:`ModelConfig`.  The
layer stack is described by a *repeating block pattern* so that hybrid
architectures (jamba's 1:7 attn:mamba interleave, xLSTM's 7:1 mLSTM:sLSTM)
compile as a ``lax.scan`` over pattern *groups* rather than an unrolled
stack — compile time scales with the pattern length, not ``n_layers``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# Layer kinds understood by the transformer stack.
ATTN = "attn"
MAMBA = "mamba"
MLSTM = "mlstm"
SLSTM = "slstm"

# MLP kinds.
MLP_DENSE = "dense"
MLP_MOE = "moe"
MLP_NONE = "none"


@dataclass(frozen=True)
class LayerSpec:
    """One position inside the repeating block pattern."""

    kind: str = ATTN           # attn | mamba | mlstm | slstm
    mlp: str = MLP_DENSE       # dense | moe | none
    sliding_window: Optional[int] = None  # tokens; None = full attention


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None          # default: d_model // n_heads
    qkv_bias: bool = False
    mlp_bias: bool = False
    act: str = "silu"                       # silu (SwiGLU) | gelu
    norm: str = "rmsnorm"                   # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # Rotary embedding.
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE

    # Attention extras.
    logit_softcap: Optional[float] = None   # grok-1 attn soft-cap
    embedding_multiplier: Optional[float] = None  # grok-1 input scale

    # Repeating layer pattern.  n_layers must be divisible by len(pattern).
    pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)

    # MoE.
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: Optional[int] = None          # per-expert hidden dim
    router_aux_coef: float = 0.001

    # SSM (mamba) dims.
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2

    # xLSTM dims.
    mlstm_expand: float = 2.0
    slstm_proj: float = 4.0 / 3.0

    # Encoder-decoder (whisper).
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500                 # whisper 30 s @ 50 Hz (post-conv)
    frontend: Optional[str] = None          # "audio_stub" | "vision_stub"

    # Long-context capability: True when the stack is sub-quadratic
    # (SSM / linear-attention / hybrid), enabling the long_500k shape.
    sub_quadratic: bool = False

    # Max position for RoPE tables at decode time (long_500k needs 524288).
    max_position: int = 1 << 20

    def __post_init__(self):
        if self.n_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern length {len(self.pattern)}"
            )
        if self.n_heads % self.n_kv_heads != 0:
            raise ValueError(f"{self.name}: n_kv_heads must divide n_heads")

    # ---- derived quantities -------------------------------------------------

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    @property
    def has_attention(self) -> bool:
        return any(s.kind == ATTN for s in self.pattern)

    @property
    def has_moe(self) -> bool:
        return any(s.mlp == MLP_MOE for s in self.pattern)

    @property
    def attn_layer_count(self) -> int:
        per_group = sum(1 for s in self.pattern if s.kind == ATTN)
        return per_group * self.n_groups

    def param_count(self) -> int:
        """Total parameter count (analytic; used for 6·N·D model FLOPs)."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        """Active-per-token parameters (MoE: only routed-in experts)."""
        return _param_count(self, active_only=True)

    def scaled(self, **overrides) -> "ModelConfig":
        """Return a reduced/altered copy (used for smoke configs)."""
        return dataclasses.replace(self, **overrides)


def _mlp_params(cfg: ModelConfig, spec: LayerSpec, active_only: bool) -> int:
    d = cfg.d_model
    if spec.mlp == MLP_NONE:
        return 0
    if spec.mlp == MLP_DENSE:
        f = cfg.d_ff
        n_mat = 3 if cfg.act == "silu" else 2  # SwiGLU has gate+up+down
        return n_mat * d * f
    # MoE: routed experts + shared experts + router.
    f = cfg.moe_d_ff if cfg.moe_d_ff is not None else cfg.d_ff
    n_mat = 3 if cfg.act == "silu" else 2
    per_expert = n_mat * d * f
    n_routed = cfg.n_experts_per_tok if active_only else cfg.n_experts
    shared = cfg.n_shared_experts * per_expert
    router = d * cfg.n_experts
    return n_routed * per_expert + shared + router


def _layer_params(cfg: ModelConfig, spec: LayerSpec, active_only: bool) -> int:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    if spec.kind == ATTN:
        core = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
    elif spec.kind == MAMBA:
        di = cfg.d_inner
        core = (
            d * 2 * di            # in_proj (x and z branches)
            + di * cfg.ssm_conv_width
            + di * (2 * cfg.ssm_state_dim + 1)  # x -> (B, C, dt)
            + di * cfg.ssm_state_dim            # A (log) parameter
            + di * d              # out_proj
        )
    elif spec.kind == MLSTM:
        di = int(cfg.mlstm_expand * d)
        core = (
            d * 2 * di            # up-proj (x, z)
            + 3 * di * di         # q, k, v projections (full width)
            + 3 * di              # input/forget/output gate vectors (per-dim)
            + di * d              # down-proj
        )
    elif spec.kind == SLSTM:
        dp = int(cfg.slstm_proj * d)
        core = 4 * d * d + 4 * d * d + 2 * d * dp  # recurrent + input gates + ffn
    else:
        raise ValueError(spec.kind)
    return core + _mlp_params(cfg, spec, active_only)


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    per_group = sum(_layer_params(cfg, s, active_only) for s in cfg.pattern)
    total = per_group * cfg.n_groups
    emb = cfg.vocab_size * cfg.d_model
    total += emb if cfg.tie_embeddings else 2 * emb
    if cfg.is_encoder_decoder:
        # Encoder self-attn + mlp, plus decoder cross-attention blocks.
        enc_spec = LayerSpec(kind=ATTN, mlp=MLP_DENSE)
        total += cfg.n_encoder_layers * _layer_params(cfg, enc_spec, active_only)
        d = cfg.d_model
        cross = cfg.n_layers * (d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d)
        total += cross
    return total


# ---------------------------------------------------------------------------
# Input shape cells (assigned shapes; identical for every LM arch).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def applicable_shapes(cfg: ModelConfig) -> Tuple[ShapeCell, ...]:
    """Shapes that apply to an architecture (long_500k needs sub-quadratic)."""
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue  # full-attention archs skip 500k decode (DESIGN.md §6)
        out.append(s)
    return tuple(out)

"""Dense MLP: SwiGLU (silu) or plain GeLU variants."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (activation, dense_apply, dense_axes,
                                 dense_init, reduce_dtype)
from repro.models.config import ModelConfig
from repro.runconfig import RunConfig


def init(rng, cfg: ModelConfig, d_ff=None, dtype=jnp.bfloat16):
    f = d_ff if d_ff is not None else cfg.d_ff
    d = cfg.d_model
    if cfg.act == "silu":
        kg, ku, kd = jax.random.split(rng, 3)
        return {
            "gate": dense_init(kg, d, f, bias=cfg.mlp_bias, dtype=dtype),
            "up": dense_init(ku, d, f, bias=cfg.mlp_bias, dtype=dtype),
            "down": dense_init(kd, f, d, bias=cfg.mlp_bias, dtype=dtype),
        }
    ku, kd = jax.random.split(rng)
    return {
        "up": dense_init(ku, d, f, bias=cfg.mlp_bias, dtype=dtype),
        "down": dense_init(kd, f, d, bias=cfg.mlp_bias, dtype=dtype),
    }


def axes(cfg: ModelConfig):
    b = cfg.mlp_bias
    if cfg.act == "silu":
        return {
            "gate": dense_axes("ff_in", "ff", bias=b),
            "up": dense_axes("ff_in", "ff", bias=b),
            "down": dense_axes("ff", "o_out", bias=b),
        }
    return {
        "up": dense_axes("ff_in", "ff", bias=b),
        "down": dense_axes("ff", "o_out", bias=b),
    }


def apply(params, x, cfg: ModelConfig, rc: RunConfig):
    prec = jax.lax.Precision(rc.matmul_precision) \
        if rc.matmul_precision != "default" else None
    act = activation(cfg.act)
    red = reduce_dtype(rc)
    if "gate" in params:
        h = act(dense_apply(params["gate"], x, precision=prec,
                            preferred=red)) \
            * dense_apply(params["up"], x, precision=prec, preferred=red)
    else:
        h = act(dense_apply(params["up"], x, precision=prec, preferred=red))
    # down-proj contracts the TP-sharded ff dim -> cross-shard partial sums
    return dense_apply(params["down"], h, precision=prec,
                       preferred=reduce_dtype(rc))

"""Unified Model API over decoder-only LMs and the enc-dec (whisper) family.

Everything downstream (train loop, serving engine, dry-run, SAPPHIRE's
compiled evaluator) talks to this facade:

    m = Model(cfg)
    params            = m.init(rng)
    ax                = m.param_axes()
    loss, metrics     = m.loss(params, batch, rc)
    logits, state     = m.prefill(params, inputs, s_max, rc)
    logits, state     = m.decode_step(params, token, state, rc)
    m.input_specs(shape_cell, mode)   # ShapeDtypeStruct stand-ins (no alloc)
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import transformer, whisper
from repro.models.config import ModelConfig, ShapeCell
from repro.runconfig import RunConfig


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._ed = cfg.is_encoder_decoder

    # ---- parameters -------------------------------------------------------

    def init(self, rng, dtype=jnp.bfloat16):
        mod = whisper if self._ed else transformer
        return mod.init(rng, self.cfg, dtype)

    def param_axes(self):
        mod = whisper if self._ed else transformer
        return mod.axes(self.cfg)

    def param_shapes(self, dtype=jnp.bfloat16):
        """ShapeDtypeStructs for every parameter (no allocation)."""
        return jax.eval_shape(lambda: self.init(jax.random.key(0), dtype))

    # ---- training ---------------------------------------------------------

    def loss(self, params, batch: Dict[str, jnp.ndarray], rc: RunConfig):
        mod = whisper if self._ed else transformer
        return mod.loss_fn(params, batch, self.cfg, rc)

    # ---- serving ----------------------------------------------------------

    def prefill(self, params, inputs: Dict[str, jnp.ndarray], s_max: int,
                rc: RunConfig):
        if self._ed:
            # encode + teacher-forced full-sequence decoder pass, caches
            # filled — the honest prefill computation for the 32k cell
            return whisper.prefill(params, inputs["tokens"],
                                   inputs["frames"], s_max, self.cfg, rc)
        return transformer.prefill(params, inputs["tokens"], s_max,
                                   self.cfg, rc)

    def init_decode_state(self, inputs, batch: int, s_max: int, rc: RunConfig,
                          params=None):
        if self._ed:
            return whisper.init_decode_state(params, inputs["frames"], batch,
                                             s_max, self.cfg, rc)
        return transformer.init_decode_state(batch, s_max, self.cfg, rc)

    def decode_state_shapes(self, batch: int, s_max: int, rc: RunConfig):
        """ShapeDtypeStructs for the decode state (no allocation)."""
        cfg = self.cfg
        if self._ed:
            def mk():
                params = self.init(jax.random.key(0))
                frames = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model),
                                   jnp.bfloat16)
                return whisper.init_decode_state(params, frames, batch, s_max,
                                                 cfg, RunConfig() if rc is None else rc)
            return jax.eval_shape(mk)
        return jax.eval_shape(
            lambda: transformer.init_decode_state(batch, s_max, cfg, rc))

    def decode_state_axes(self, rc: RunConfig):
        if self._ed:
            ax = attention_cache_axes_ed(rc)
            return ax
        return transformer.decode_state_axes(self.cfg, rc)

    def decode_step(self, params, token, state, rc: RunConfig):
        if self._ed:
            return whisper.decode_step(params, token, state, self.cfg, rc)
        return transformer.decode_step(params, token, state, self.cfg, rc)

    # ---- input specs (dry-run stand-ins; weak-type-correct, no alloc) -----

    def input_specs(self, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        B, S = cell.global_batch, cell.seq_len
        i32 = jnp.int32
        if cell.mode == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
            if self._ed:
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
            if cfg.mrope_sections is not None:
                # the vision-frontend stub supplies (t,h,w) position ids
                specs["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
            return specs
        if cell.mode == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
            if self._ed:
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
            return specs
        if cell.mode == "decode":
            # one new token against an S-long cache
            specs = {"token": jax.ShapeDtypeStruct((B, 1), i32)}
            return specs
        raise ValueError(cell.mode)


def attention_cache_axes_ed(rc: RunConfig):
    """Whisper decode-state logical axes."""
    from repro.models import attention
    ax, _ = attention.cache_axes(rc)
    stacked = (None,) + tuple(ax)
    return whisper.WhisperDecodeState(
        self_k=stacked, self_v=stacked, cross_k=stacked, cross_v=stacked,
        pos=())


def make_model(cfg: ModelConfig) -> Model:
    return Model(cfg)

"""Mixture-of-Experts MLP with shared experts and top-k routing.

Covers grok-1 (8e top-2, gelu), qwen2-moe (60e top-4 + 4 shared, silu) and
jamba (16e top-2).  Expert weights carry the "experts" logical axis so the
layout knob can place them on the model mesh axis (expert parallelism).

Two implementations (``moe_impl`` knob, C3-gated):

* ``dense``    — einsum over *all* experts with routing weights masked to the
  top-k.  No token dropping, deterministic, SPMD-friendly; compute scales
  with n_experts (the faithful-but-expensive baseline; fine for dry-run
  cost attribution since routed FLOPs are what the roofline counts).
* ``dropping`` — capacity-factor dispatch (one-hot scatter into
  [experts, capacity] buffers) — the classic Switch-style implementation
  whose FLOPs scale with top-k only.  Capacity factor is a tuned knob.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import mlp
from repro.models.common import activation, trunc_normal
from repro.models.config import ModelConfig
from repro.runconfig import RunConfig


def _expert_ff(cfg: ModelConfig) -> int:
    return cfg.moe_d_ff if cfg.moe_d_ff is not None else cfg.d_ff


def init(rng, cfg: ModelConfig, dtype=jnp.bfloat16):
    kr, ke, ks = jax.random.split(rng, 3)
    d, f, e = cfg.d_model, _expert_ff(cfg), cfg.n_experts
    n_mat = 3 if cfg.act == "silu" else 2
    keys = jax.random.split(ke, n_mat)
    p = {
        "router": {"w": trunc_normal(kr, (d, e), 1.0, jnp.float32)},
        "experts": {},
    }
    if cfg.act == "silu":
        p["experts"] = {
            "gate": trunc_normal(keys[0], (e, d, f), 1.0, dtype),
            "up": trunc_normal(keys[1], (e, d, f), 1.0, dtype),
            "down": trunc_normal(keys[2], (e, f, d), 1.0, dtype),
        }
    else:
        p["experts"] = {
            "up": trunc_normal(keys[0], (e, d, f), 1.0, dtype),
            "down": trunc_normal(keys[1], (e, f, d), 1.0, dtype),
        }
    if cfg.n_shared_experts:
        # Shared experts act as one dense MLP of width n_shared * f.
        p["shared"] = mlp.init(ks, cfg, d_ff=cfg.n_shared_experts * f, dtype=dtype)
    return p


def axes(cfg: ModelConfig):
    ax = {
        "router": {"w": ("embed", "experts")},
        "experts": {},
    }
    if cfg.act == "silu":
        ax["experts"] = {
            "gate": ("experts", "expert_in", "expert_ff"),
            "up": ("experts", "expert_in", "expert_ff"),
            "down": ("experts", "expert_ff", "expert_in"),
        }
    else:
        ax["experts"] = {
            "up": ("experts", "expert_in", "expert_ff"),
            "down": ("experts", "expert_ff", "expert_in"),
        }
    if cfg.n_shared_experts:
        ax["shared"] = mlp.axes(cfg)
    return ax


def _routing(params, x, cfg: ModelConfig):
    """Return (weights [T, E] with only top-k nonzero, aux_loss scalar)."""
    T = x.shape[0]
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), params["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, cfg.n_experts_per_tok)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)      # renormalize
    weights = jnp.zeros_like(probs)
    weights = jnp.put_along_axis(weights, topi, topv, axis=-1, inplace=False)
    # Switch-style load-balancing auxiliary loss.
    frac_tokens = jnp.mean((weights > 0).astype(jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = cfg.n_experts * jnp.sum(frac_tokens * frac_probs)
    return weights, aux, topi, topv


def _expert_hidden(ep, h, act_name: str):
    """h [E, C, d] -> activated hidden z [E, C, f] (per-expert up/gate)."""
    act = activation(act_name)
    if "gate" in ep:
        g = jnp.einsum("ecd,edf->ecf", h, ep["gate"],
                       preferred_element_type=jnp.float32).astype(h.dtype)
        u = jnp.einsum("ecd,edf->ecf", h, ep["up"],
                       preferred_element_type=jnp.float32).astype(h.dtype)
        return act(g) * u
    u = jnp.einsum("ecd,edf->ecf", h, ep["up"],
                   preferred_element_type=jnp.float32).astype(h.dtype)
    return act(u)


def _expert_mlp(ep, h, act_name: str):
    """h [E, C, d] through per-expert weights [E, d, f] / [E, f, d]."""
    z = _expert_hidden(ep, h, act_name)
    return jnp.einsum("ecf,efd->ecd", z, ep["down"],
                      preferred_element_type=jnp.float32).astype(h.dtype)


def apply(params, x, cfg: ModelConfig, rc: RunConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, d] -> (y [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    weights, aux, topi, topv = _routing(params, xt, cfg)

    if rc.moe_impl == "dense":
        # All experts on all tokens, masked combine — WITHOUT materializing
        # the [E, T, d] token broadcast (qwen2-moe: 60 experts x 15.7 GB
        # per layer); the einsum broadcasts inside the dot for free.
        ep = params["experts"]
        act = activation(cfg.act)
        if "gate" in ep:
            g = jnp.einsum("td,edf->etf", xt, ep["gate"],
                           preferred_element_type=jnp.float32).astype(xt.dtype)
            u = jnp.einsum("td,edf->etf", xt, ep["up"],
                           preferred_element_type=jnp.float32).astype(xt.dtype)
            z = act(g) * u
        else:
            u = jnp.einsum("td,edf->etf", xt, ep["up"],
                           preferred_element_type=jnp.float32).astype(xt.dtype)
            z = act(u)
        # Routing combine BEFORE the down-proj contraction: scaling z by
        # the routing weights is local/elementwise, and the (e, f) joint
        # contraction then emits ONE [T, d] partial sum per shard instead
        # of per-expert [E, T, d] partials (8x the all-reduce bytes —
        # measured 12.75 GiB/layer vs 0.8).  A 3-operand einsum does NOT
        # guarantee this order (opt_einsum picked the bad one).
        from repro.models.common import reduce_dtype
        zs = z * weights.T[:, :, None].astype(z.dtype)        # [E, T, f]
        y = jnp.einsum("etf,efd->td", zs, params["experts"]["down"],
                       preferred_element_type=reduce_dtype(rc)
                       ).astype(xt.dtype)
    elif rc.moe_impl == "dropping":
        y = _capacity_dispatch(params, xt, weights, topi, topv, cfg, rc)
    else:
        raise ValueError(rc.moe_impl)

    if cfg.n_shared_experts:
        y = y + mlp.apply(params["shared"], xt, cfg, rc)
    return y.reshape(B, S, d), aux * cfg.router_aux_coef


def _capacity_dispatch(params, xt, weights, topi, topv, cfg: ModelConfig,
                       rc: RunConfig):
    """Switch-style capacity-factor dispatch (token dropping)."""
    T, d = xt.shape
    E, K = cfg.n_experts, cfg.n_experts_per_tok
    capacity = max(1, int(rc.moe_capacity_factor * T * K / E))
    capacity = min(capacity, T)

    # position of each (token, k) inside its expert's buffer
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.int32)        # [T, K, E]
    flat = onehot.reshape(T * K, E)
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat           # [T*K, E]
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(T, K)
    keep = pos < capacity                                     # [T, K]

    # scatter tokens into [E, capacity, d]
    eidx = topi.reshape(-1)                                   # [T*K]
    cidx = jnp.where(keep.reshape(-1), pos.reshape(-1), capacity)  # drop->cap
    buf = jnp.zeros((E, capacity + 1, d), xt.dtype)
    tok = jnp.repeat(xt, K, axis=0)                           # [T*K, d]
    buf = buf.at[eidx, cidx].add(tok)
    buf = buf[:, :capacity]                                   # [E, C, d]

    y_buf = _expert_mlp(params["experts"], buf, cfg.act)      # [E, C, d]

    # gather back with routing weights
    safe_c = jnp.minimum(cidx, capacity - 1)
    gathered = y_buf[eidx, safe_c]                            # [T*K, d]
    w = (topv.reshape(-1, 1) * keep.reshape(-1, 1)).astype(xt.dtype)
    y = jnp.sum((gathered * w).reshape(T, K, d), axis=1)
    return y

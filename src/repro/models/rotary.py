"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE (multimodal rotary, arXiv:2409.12191) splits the head dim into three
sections rotated by (temporal, height, width) position ids.  For the text
backbone (vision frontend is a stub) the three ids coincide, which reduces
to standard RoPE — but the section machinery is implemented and exercised so
the VLM config is faithful.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape [head_dim // 2] (f32)."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> jnp.ndarray:
    """positions [...,] -> angles [..., head_dim//2] (f32)."""
    inv = rope_freqs(head_dim, theta)
    return positions[..., None].astype(jnp.float32) * inv


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mrope_sections: Optional[Tuple[int, int, int]] = None) -> jnp.ndarray:
    """Apply rotary embedding.

    x         : [B, S, H, D] (D even)
    positions : [B, S] int32 for RoPE, or [3, B, S] for M-RoPE (t/h/w ids).
    """
    d = x.shape[-1]
    if mrope_sections is None:
        ang = rope_angles(positions, d, theta)          # [B, S, D/2]
    else:
        if positions.ndim == 2:                          # text-only: t=h=w
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        ang_full = rope_angles(positions, d, theta)      # [3, B, S, D/2]
        # Interleaved section split over frequency index (HF convention):
        # freqs [0:s0) from t, [s0:s0+s1) from h, [s0+s1:) from w.
        s0, s1, s2 = mrope_sections
        assert (s0 + s1 + s2) == d // 2, "mrope sections must sum to head_dim/2"
        parts, off = [], 0
        for sec_i, sec in enumerate((s0, s1, s2)):
            parts.append(ang_full[sec_i][..., off:off + sec])
            off += sec
        ang = jnp.concatenate(parts, axis=-1)            # [B, S, D/2]

    sin = jnp.sin(ang)[:, :, None, :]                    # [B, S, 1, D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return rotated.astype(x.dtype)

"""Mamba block, TPU-adapted (chunked SSD form).

HARDWARE ADAPTATION (DESIGN.md §8): Mamba-1's selective-scan CUDA kernel
keeps a per-channel [d_inner, N] recurrent state in GPU shared memory and
walks time sequentially per thread-block.  TPUs want matmul-shaped work on
the MXU and chunk-bounded working sets in VMEM, so we implement the
*chunked state-space dual* form (Mamba-2 / SSD, arXiv:2405.21060): per-head
scalar decay, intra-chunk attention-like matmuls with a decay mask, and an
inter-chunk carried state of shape [heads, N, P].  ``ssm_chunk`` (the chunk
length) is a SAPPHIRE knob.  The sequential recurrence is kept as the
reference oracle (``ssd_reference``) and for single-token decode.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (dense_apply, dense_axes, dense_init,
    norm_apply, norm_init, trunc_normal)
from repro.models.config import ModelConfig
from repro.runconfig import RunConfig

HEAD_P = 64          # per-head channel width (mamba-2 default)


def dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(d_inner, n_heads, state N)."""
    di = cfg.d_inner
    nh = max(1, di // HEAD_P)
    return di, nh, cfg.ssm_state_dim


def init(rng, cfg: ModelConfig, dtype=jnp.bfloat16):
    di, nh, N = dims(cfg)
    d = cfg.d_model
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(rng, 7)
    return {
        "in_proj": dense_init(k1, d, 2 * di, dtype=dtype),       # x, z
        "conv_w": trunc_normal(k2, (cfg.ssm_conv_width, di), 1.0, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "bc_proj": dense_init(k3, di, 2 * N, dtype=dtype),       # B, C
        "dt_proj": dense_init(k4, di, nh, bias=True, dtype=dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),                  # A = -exp(a_log)
        "d_skip": jnp.ones((nh,), jnp.float32),
        "out_norm": norm_init(di, "rmsnorm", dtype),
        "out_proj": dense_init(k5, di, d, dtype=dtype,
                               scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }


def axes(cfg: ModelConfig):
    return {
        "in_proj": dense_axes("ssm_in", "ssm_inner"),
        "conv_w": (None, "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "bc_proj": dense_axes("ssm_inner", None),
        "dt_proj": dense_axes("ssm_inner", None, bias=True),
        "a_log": (None,),
        "d_skip": (None,),
        "out_norm": {"scale": ("ssm_inner",)},
        "out_proj": dense_axes("ssm_inner", "o_out"),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv1d.  x [B,S,di], w [W,di]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):   # W is tiny (4); unrolled adds, no conv primitive
        out = out + xp[:, i: i + x.shape[1]].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


class SsmState(NamedTuple):
    s: jnp.ndarray        # [B, H, N, P] carried SSD state
    conv: jnp.ndarray     # [B, W-1, di] conv tail


def init_state(batch: int, cfg: ModelConfig, dtype=jnp.float32) -> SsmState:
    di, nh, N = dims(cfg)
    return SsmState(
        s=jnp.zeros((batch, nh, N, di // nh), dtype),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, di), jnp.bfloat16),
    )


def state_axes(cfg: ModelConfig):
    return SsmState(
        s=("batch", None, "ssm_state", None),
        conv=("batch", None, "ssm_inner"),
    )


def _project(params, u, cfg: ModelConfig):
    """Shared front half: in_proj, conv, gates.  u [B,S,d]."""
    di, nh, N = dims(cfg)
    P = di // nh
    xz = dense_apply(params["in_proj"], u)
    x, z = jnp.split(xz, 2, axis=-1)
    return x, z, di, nh, N, P


def _post(params, y, z, cfg: ModelConfig):
    y = norm_apply(params["out_norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                   kind="rmsnorm", eps=cfg.norm_eps)
    return dense_apply(params["out_proj"], y)


def _gates(params, xc, nh):
    """dt (softplus) and per-head log-decay from conv'd activations."""
    dt = jax.nn.softplus(dense_apply(params["dt_proj"], xc).astype(jnp.float32))  # [B,S,H]
    a = -jnp.exp(params["a_log"])                                 # [H] negative
    log_decay = dt * a[None, None, :]                             # [B,S,H] <= 0
    return dt, log_decay


def apply(params, u, cfg: ModelConfig, rc: RunConfig):
    """Full-sequence chunked SSD.  u [B,S,d] -> [B,S,d]."""
    B, S, _ = u.shape
    x, z, di, nh, N, P = _project(params, u, cfg)
    xc = jax.nn.silu(_causal_conv(x, params["conv_w"], params["conv_b"])
                     .astype(jnp.float32)).astype(x.dtype)
    bc = dense_apply(params["bc_proj"], xc)
    Bm, Cm = jnp.split(bc, 2, axis=-1)                            # [B,S,N]
    dt, log_decay = _gates(params, xc, nh)

    xh = xc.reshape(B, S, nh, P)
    # discretized input: dt-scaled
    xin = xh * dt[..., None].astype(xh.dtype)

    c = min(rc.ssm_chunk, S)
    n_chunks = (S + c - 1) // c
    assert S % c == 0, "ssm_chunk must divide seq len (padded by caller)"

    def chunkify(t, shape):
        return t.reshape((B, n_chunks, c) + shape)

    xin_c = chunkify(xin, (nh, P)).transpose(1, 0, 2, 3, 4)       # [nc,B,c,H,P]
    B_c = chunkify(Bm, (N,)).transpose(1, 0, 2, 3)                # [nc,B,c,N]
    C_c = chunkify(Cm, (N,)).transpose(1, 0, 2, 3)
    ld_c = chunkify(log_decay, (nh,)).transpose(1, 0, 2, 3)       # [nc,B,c,H]

    def body(s_prev, xs):
        xin_i, B_i, C_i, ld_i = xs
        # cumulative log decay within chunk, inclusive: [B,c,H]
        cum = jnp.cumsum(ld_i, axis=1)
        # intra-chunk: scores[b,h,i,j] = exp(cum_i - cum_j) * (C_i . B_j), j<=i
        diff = cum[:, :, None, :] - cum[:, None, :, :]            # [B,i,j,H]
        mask = jnp.tril(jnp.ones((c, c), bool))
        L = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)  # [B,i,j,H]
        cb = jnp.einsum("bin,bjn->bij", C_i.astype(jnp.float32),
                        B_i.astype(jnp.float32))                  # [B,i,j]
        sc = cb[..., None] * L                                     # [B,i,j,H]
        y_intra = jnp.einsum("bijh,bjhp->bihp", sc, xin_i.astype(jnp.float32))
        # inter-chunk: y += exp(cum_i) * C_i @ s_prev
        y_inter = jnp.einsum("bin,bhnp->bihp", C_i.astype(jnp.float32), s_prev) \
            * jnp.exp(cum)[..., None]
        # state update: s = exp(total) * s_prev + sum_j exp(total - cum_j) B_j x_j
        total = cum[:, -1:, :]                                     # [B,1,H]
        w = jnp.exp(total - cum)                                   # [B,c,H]
        s_new = s_prev * jnp.exp(total)[:, 0, :, None, None] + jnp.einsum(
            "bjn,bjhp->bhnp", B_i.astype(jnp.float32),
            (xin_i.astype(jnp.float32) * w[..., None]))
        return s_new, (y_intra + y_inter)

    s0 = jnp.zeros((B, nh, N, P), jnp.float32)
    _, ys = jax.lax.scan(body, s0, (xin_c, B_c, C_c, ld_c))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, nh, P)
    y = y + xh.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.astype(u.dtype).reshape(B, S, di)
    return _post(params, y, z, cfg)


def ssd_reference(params, u, cfg: ModelConfig):
    """Sequential-recurrence oracle (slow; tests only)."""
    B, S, _ = u.shape
    x, z, di, nh, N, P = _project(params, u, cfg)
    xc = jax.nn.silu(_causal_conv(x, params["conv_w"], params["conv_b"])
                     .astype(jnp.float32)).astype(x.dtype)
    bc = dense_apply(params["bc_proj"], xc)
    Bm, Cm = jnp.split(bc, 2, axis=-1)
    dt, log_decay = _gates(params, xc, nh)
    xh = (xc.reshape(B, S, nh, P) * dt[..., None].astype(xc.dtype)).astype(jnp.float32)

    def step(s, t):
        a = jnp.exp(log_decay[:, t])                               # [B,H]
        s = s * a[:, :, None, None] + jnp.einsum(
            "bn,bhp->bhnp", Bm[:, t].astype(jnp.float32), xh[:, t])
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, t].astype(jnp.float32), s)
        return s, y

    s0 = jnp.zeros((B, nh, N, P), jnp.float32)
    _, ys = jax.lax.scan(step, s0, jnp.arange(S))
    y = ys.transpose(1, 0, 2, 3)                                   # [B,S,H,P]
    # D-skip, same convention as the chunked path (on the head view of xc)
    y = y + xc.reshape(B, S, nh, P).astype(jnp.float32) \
        * params["d_skip"][None, None, :, None]
    y = y.astype(u.dtype).reshape(B, S, di)
    return _post(params, y, z, cfg)


def decode_step(params, u, state: SsmState, cfg: ModelConfig, rc: RunConfig):
    """One-token decode.  u [B,1,d] -> (y [B,1,d], new_state)."""
    B = u.shape[0]
    x, z, di, nh, N, P = _project(params, u, cfg)
    # conv over (tail ++ current)
    W = cfg.ssm_conv_width
    window = jnp.concatenate([state.conv.astype(x.dtype), x], axis=1)  # [B,W,di]
    xc = jnp.einsum("bwd,wd->bd", window.astype(jnp.float32),
                    params["conv_w"].astype(jnp.float32)) \
        + params["conv_b"].astype(jnp.float32)
    xc = jax.nn.silu(xc).astype(x.dtype)[:, None, :]              # [B,1,di]
    new_conv = window[:, 1:, :].astype(jnp.bfloat16)

    bc = dense_apply(params["bc_proj"], xc)
    Bm, Cm = jnp.split(bc[:, 0], 2, axis=-1)                       # [B,N]
    dt, log_decay = _gates(params, xc, nh)                         # [B,1,H]
    a = jnp.exp(log_decay[:, 0])                                   # [B,H]
    xh = xc.reshape(B, nh, P).astype(jnp.float32) * dt[:, 0, :, None]
    s = state.s * a[:, :, None, None] + jnp.einsum("bn,bhp->bhnp",
                                                   Bm.astype(jnp.float32), xh)
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), s)
    y = y + xc.reshape(B, nh, P).astype(jnp.float32) * params["d_skip"][None, :, None]
    y = y.astype(u.dtype).reshape(B, 1, di)
    out = _post(params, y, z, cfg)
    return out, SsmState(s=s, conv=new_conv)

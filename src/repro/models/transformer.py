"""Composable decoder LM over the repeating block pattern.

The layer stack is ``lax.scan`` over *pattern groups*: parameters for each
position in the repeating pattern are stacked over groups, so an 80-layer
homogeneous model compiles one layer body, and jamba's 72 layers compile
one 8-layer group body.  Remat policy (SAPPHIRE knob) wraps the group body.

Exposes:
    init / axes            — parameters and logical sharding axes
    forward                — full-sequence logits (train / prefill)
    loss_fn                — next-token cross entropy (+ MoE aux)
    init_decode_state      — per-position stacked caches / states
    prefill                — fill caches from a prompt, return state
    decode_step            — one-token step through the whole stack
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, mlp, moe, ssm, xlstm
from repro.models.common import (dense_apply, norm_apply, norm_axes,
                                 norm_init, stack_axes, stack_init, trunc_normal)
from repro.models.config import (ATTN, MAMBA, MLP_DENSE, MLP_MOE, MLSTM,
    SLSTM, LayerSpec, ModelConfig)
from jax.ad_checkpoint import checkpoint_name

from repro.runconfig import RunConfig


# ---------------------------------------------------------------------------
# per-position init / axes
# ---------------------------------------------------------------------------

def _pos_init(rng, spec: LayerSpec, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    p: Dict[str, Any] = {}
    if spec.kind == ATTN:
        p["norm1"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["attn"] = attention.init(k1, cfg, dtype)
    elif spec.kind == MAMBA:
        p["norm1"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["mamba"] = ssm.init(k1, cfg, dtype)
    elif spec.kind == MLSTM:
        p["norm1"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["mlstm"] = xlstm.mlstm_init(k1, cfg, dtype)
    elif spec.kind == SLSTM:
        p["norm1"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["slstm"] = xlstm.slstm_init(k1, cfg, dtype)
    else:
        raise ValueError(spec.kind)
    if spec.mlp == MLP_DENSE:
        p["norm2"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["mlp"] = mlp.init(k2, cfg, dtype=dtype)
    elif spec.mlp == MLP_MOE:
        p["norm2"] = norm_init(cfg.d_model, cfg.norm, dtype)
        p["moe"] = moe.init(k2, cfg, dtype)
    return p


def _pos_axes(spec: LayerSpec, cfg: ModelConfig):
    ax: Dict[str, Any] = {}
    if spec.kind == ATTN:
        ax["norm1"] = norm_axes(cfg.norm)
        ax["attn"] = attention.axes(cfg)
    elif spec.kind == MAMBA:
        ax["norm1"] = norm_axes(cfg.norm)
        ax["mamba"] = ssm.axes(cfg)
    elif spec.kind == MLSTM:
        ax["norm1"] = norm_axes(cfg.norm)
        ax["mlstm"] = xlstm.mlstm_axes(cfg)
    elif spec.kind == SLSTM:
        ax["norm1"] = norm_axes(cfg.norm)
        ax["slstm"] = xlstm.slstm_axes(cfg)
    if spec.mlp == MLP_DENSE:
        ax["norm2"] = norm_axes(cfg.norm)
        ax["mlp"] = mlp.axes(cfg)
    elif spec.mlp == MLP_MOE:
        ax["norm2"] = norm_axes(cfg.norm)
        ax["moe"] = moe.axes(cfg)
    return ax


def init(rng, cfg: ModelConfig, dtype=jnp.bfloat16):
    k_emb, k_layers, k_head = jax.random.split(rng, 3)
    params: Dict[str, Any] = {
        "embed": {"tok": trunc_normal(k_emb, (cfg.vocab_size, cfg.d_model),
                                      1.0, dtype)},
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "layers": [],
    }
    pk = jax.random.split(k_layers, len(cfg.pattern))
    for p_i, spec in enumerate(cfg.pattern):
        params["layers"].append(
            stack_init(pk[p_i], cfg.n_groups,
                       lambda r, s=spec: _pos_init(r, s, cfg, dtype)))
    if not cfg.tie_embeddings:
        params["head"] = {"w": trunc_normal(
            k_head, (cfg.d_model, cfg.vocab_size), 1.0, dtype)}
    return params


def axes(cfg: ModelConfig):
    ax: Dict[str, Any] = {
        "embed": {"tok": ("vocab", "emb_embed")},
        "final_norm": norm_axes(cfg.norm),
        "layers": [stack_axes(_pos_axes(spec, cfg)) for spec in cfg.pattern],
    }
    if not cfg.tie_embeddings:
        ax["head"] = {"w": ("emb_embed", "vocab")}
    return ax


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _block_forward(spec: LayerSpec, p, x, positions, cfg: ModelConfig,
                   rc: RunConfig):
    """One block (pre-norm residual).  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(p["norm1"], x, kind=cfg.norm, eps=cfg.norm_eps)
    if spec.kind == ATTN:
        h = attention.apply(p["attn"], h, positions, cfg, rc,
                            causal=True, window=spec.sliding_window)
    elif spec.kind == MAMBA:
        h = ssm.apply(p["mamba"], h, cfg, rc)
    elif spec.kind == MLSTM:
        h = xlstm.mlstm_apply(p["mlstm"], h, cfg, rc)
    elif spec.kind == SLSTM:
        h = xlstm.slstm_apply(p["slstm"], h, cfg, rc)
    x = x + h
    if spec.mlp == MLP_DENSE:
        h = norm_apply(p["norm2"], x, kind=cfg.norm, eps=cfg.norm_eps)
        x = x + mlp.apply(p["mlp"], h, cfg, rc)
    elif spec.mlp == MLP_MOE:
        h = norm_apply(p["norm2"], x, kind=cfg.norm, eps=cfg.norm_eps)
        y, aux = moe.apply(p["moe"], h, cfg, rc)
        x = x + y
    return x, aux


def _remat_wrap(fn, rc: RunConfig):
    if rc.remat_policy == "none":
        return fn
    if rc.remat_policy == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if rc.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    if rc.remat_policy == "block":
        # save ONLY the named bf16 carry: without the explicit name, the
        # partial-eval saves the f32 *convert* of x (first reuse site is
        # the f32 norm), doubling the residual stack and forcing
        # whole-stack convert round-trips every scan iteration
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.save_only_these_names(
                "block_input"))
    raise ValueError(rc.remat_policy)


def backbone(params, x, positions, cfg: ModelConfig, rc: RunConfig):
    """Embedded activations -> final hidden states.  x [B,S,d]."""
    from repro.parallel.sharding import (gather_weights_for_compute,
                                         shard_activation)
    # axes of ONE scan slice (scan strips the stacked group dim)
    pattern_axes = [_pos_axes(spec, cfg) for spec in cfg.pattern]

    act_dtype = jnp.bfloat16 if rc.activation_dtype == "bfloat16" \
        else jnp.float32

    def group_body(carry, layer_slice):
        x, aux = carry
        # pin BOTH layout and dtype of the carried activation: the layout
        # pin stops SPMD replicating the batch inside the scan (dp×
        # redundant attention); the dtype pin keeps the remat-saved
        # residual stack in bf16 (a single f32 slice forces XLA to
        # convert the WHOLE [L,B,S,d] stack round-trip every iteration)
        x = x.astype(act_dtype)
        x = shard_activation(x, ("batch", "seq", "embed"), rc.shard)
        x = checkpoint_name(x, "block_input")
        for p_i, spec in enumerate(cfg.pattern):
            # ZeRO-3: stream this position's weights in (all-gather over
            # data) instead of partial-sum matmuls + activation all-reduce
            p = gather_weights_for_compute(layer_slice[p_i],
                                           pattern_axes[p_i], rc.shard)
            x, a = _block_forward(spec, p, x, positions, cfg, rc)
            aux = aux + a
        return (x.astype(act_dtype), aux), None

    body = _remat_wrap(group_body, rc)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    x = norm_apply(params["final_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)
    return x, aux


def embed(params, tokens, cfg: ModelConfig):
    x = params["embed"]["tok"][tokens]
    if cfg.embedding_multiplier:
        x = x * jnp.asarray(cfg.embedding_multiplier, x.dtype)
    return x


def unembed(params, x, cfg: ModelConfig, rc: Optional[RunConfig] = None):
    if cfg.tie_embeddings:
        w = params["embed"]["tok"].T
    else:
        w = params["head"]["w"]
    from repro.models.common import dense_apply, reduce_dtype
    if rc is not None and reduce_dtype(rc) == jnp.bfloat16:
        # vocab-sharded head: bwd dgrad AR in bf16
        return dense_apply({"w": w}, x, preferred=jnp.bfloat16) \
            .astype(jnp.float32)
    logits = jnp.einsum("bsd,dv->bsv", x, w,
                        preferred_element_type=jnp.float32)
    return logits


def forward(params, tokens, cfg: ModelConfig, rc: RunConfig,
            positions: Optional[jnp.ndarray] = None):
    """tokens [B,S] int32 -> logits [B,S,V] f32."""
    from repro.parallel.sharding import shard_activation
    B, S = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = embed(params, tokens, cfg)
    x = shard_activation(x, ("batch", "seq", "embed"), rc.shard)
    x, aux = backbone(params, x, positions, cfg, rc)
    logits = unembed(params, x, cfg, rc)
    return shard_activation(logits, ("batch", "seq", "vocab"), rc.shard), aux


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            rc: RunConfig):
    """Next-token cross-entropy.  batch: tokens [B,S], labels [B,S]."""
    logits, aux = forward(params, batch["tokens"], cfg, rc,
                          positions=batch.get("positions"))
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of gather: partial-sums cleanly over a
    # vocab-sharded (model-axis) logits tensor in SPMD.
    onehot = jax.nn.one_hot(labels, cfg.vocab_size, dtype=logits.dtype)
    ll = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = jnp.mean(logz - ll)
    return nll + aux, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# decode state
# ---------------------------------------------------------------------------

class DecodeState(NamedTuple):
    """Per-pattern-position stacked caches/states + current length."""
    slots: Tuple[Any, ...]      # one entry per pattern position
    pos: jnp.ndarray            # [B] int32: tokens consumed per slot
                                # (vector so continuous batching can run
                                # every slot at its own position)


def _pos_state(spec: LayerSpec, batch: int, s_max: int, cfg: ModelConfig,
               rc: RunConfig):
    if spec.kind == ATTN:
        return attention.init_cache(batch, s_max, cfg, rc)
    if spec.kind == MAMBA:
        return ssm.init_state(batch, cfg)
    if spec.kind == MLSTM:
        return xlstm.mlstm_init_state(batch, cfg)
    if spec.kind == SLSTM:
        return xlstm.slstm_init_state(batch, cfg)
    raise ValueError(spec.kind)


def _pos_state_axes(spec: LayerSpec, cfg: ModelConfig, rc: RunConfig):
    if spec.kind == ATTN:
        return attention.cache_axes(rc)
    if spec.kind == MAMBA:
        return ssm.state_axes(cfg)
    if spec.kind == MLSTM:
        return xlstm.mlstm_state_axes(cfg)
    if spec.kind == SLSTM:
        return xlstm.slstm_state_axes(cfg)
    raise ValueError(spec.kind)


def init_decode_state(batch: int, s_max: int, cfg: ModelConfig,
                      rc: RunConfig) -> DecodeState:
    slots = []
    for spec in cfg.pattern:
        one = _pos_state(spec, batch, s_max, cfg, rc)
        stacked = jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (cfg.n_groups,) + t.shape)
            if cfg.n_groups > 1 else t[None], one)
        slots.append(stacked)
    return DecodeState(slots=tuple(slots),
                       pos=jnp.zeros((batch,), jnp.int32))


def decode_state_axes(cfg: ModelConfig, rc: RunConfig) -> DecodeState:
    slots = []
    for spec in cfg.pattern:
        ax = _pos_state_axes(spec, cfg, rc)
        slots.append(stack_axes(ax))
    return DecodeState(slots=tuple(slots), pos=("batch",))


# ---------------------------------------------------------------------------
# decode step (and prefill)
# ---------------------------------------------------------------------------

def _block_decode(spec: LayerSpec, p, x, slot, pos, cfg: ModelConfig,
                  rc: RunConfig):
    h = norm_apply(p["norm1"], x, kind=cfg.norm, eps=cfg.norm_eps)
    if spec.kind == ATTN:
        ck, cv = slot
        h, ck, cv = attention.decode_apply(p["attn"], h, ck, cv, pos, cfg, rc,
                                           window=spec.sliding_window)
        slot = (ck, cv)
    elif spec.kind == MAMBA:
        h, slot = ssm.decode_step(p["mamba"], h, slot, cfg, rc)
    elif spec.kind == MLSTM:
        h, slot = xlstm.mlstm_decode_step(p["mlstm"], h, slot, cfg, rc)
    elif spec.kind == SLSTM:
        h, slot = xlstm.slstm_decode_step(p["slstm"], h, slot, cfg, rc)
    x = x + h
    if spec.mlp == MLP_DENSE:
        h = norm_apply(p["norm2"], x, kind=cfg.norm, eps=cfg.norm_eps)
        x = x + mlp.apply(p["mlp"], h, cfg, rc)
    elif spec.mlp == MLP_MOE:
        h = norm_apply(p["norm2"], x, kind=cfg.norm, eps=cfg.norm_eps)
        y, _ = moe.apply(p["moe"], h, cfg, rc)
        x = x + y
    return x, slot


def decode_step(params, token, state: DecodeState, cfg: ModelConfig,
                rc: RunConfig):
    """token [B,1] int32 -> (logits [B,1,V], new state)."""
    x = embed(params, token, cfg)
    pos = state.pos

    from repro.parallel.sharding import shard_activation

    def group_body(x, xs):
        layer_slice, slot_slice = xs
        new_slots = []
        x = shard_activation(x, ("batch", "seq", "embed"), rc.shard)
        for p_i, spec in enumerate(cfg.pattern):
            x, s = _block_decode(spec, layer_slice[p_i], x, slot_slice[p_i],
                                 pos, cfg, rc)
            new_slots.append(s)
        return x, tuple(new_slots)

    x, new_slots = jax.lax.scan(group_body, x,
                                (params["layers"], state.slots))
    x = norm_apply(params["final_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)
    logits = unembed(params, x, cfg)
    return logits, DecodeState(slots=new_slots, pos=pos + 1)


def prefill(params, tokens, s_max: int, cfg: ModelConfig, rc: RunConfig):
    """Run the prompt through the stack, filling caches.

    Returns (last-token logits [B,1,V], DecodeState at pos=S).
    Implemented as full-sequence forward + per-layer cache fill; SSM-family
    states are produced by a chunked pass (scan body reuses apply()).
    """
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = embed(params, tokens, cfg)
    state = init_decode_state(B, s_max, cfg, rc)

    from repro.parallel.sharding import (gather_weights_for_compute,
                                         shard_activation)
    pattern_axes = [_pos_axes(spec, cfg) for spec in cfg.pattern]

    def group_body(carry, xs):
        x = carry
        layer_slice, slot_slice = xs
        new_slots = []
        # same pins as the train backbone: batch sharding would otherwise
        # be dropped inside the scan (measured: fully replicated [B,S,d]
        # tiles in prefill)
        x = shard_activation(x, ("batch", "seq", "embed"), rc.shard)
        for p_i, spec in enumerate(cfg.pattern):
            p = gather_weights_for_compute(layer_slice[p_i],
                                           pattern_axes[p_i], rc.shard)
            slot = slot_slice[p_i]
            h = norm_apply(p["norm1"], x, kind=cfg.norm, eps=cfg.norm_eps)
            if spec.kind == ATTN:
                k, v = attention.project_kv(p["attn"], h, positions, cfg)
                ck = attention.fill_cache(slot[0], k, rc)
                cv = attention.fill_cache(slot[1], v, rc)
                a = attention.apply(p["attn"], h, positions, cfg, rc,
                                    causal=True, window=spec.sliding_window)
                x = x + a
                slot = (ck, cv)
            elif spec.kind == MAMBA:
                y, slot = _ssm_prefill(p["mamba"], h, slot, cfg, rc)
                x = x + y
            elif spec.kind == MLSTM:
                y, slot = _mlstm_prefill(p["mlstm"], h, cfg, rc)
                x = x + y
            elif spec.kind == SLSTM:
                y, slot = _slstm_prefill(p["slstm"], h, cfg, rc)
                x = x + y
            if spec.mlp == MLP_DENSE:
                h = norm_apply(p["norm2"], x, kind=cfg.norm, eps=cfg.norm_eps)
                x = x + mlp.apply(p["mlp"], h, cfg, rc)
            elif spec.mlp == MLP_MOE:
                h = norm_apply(p["norm2"], x, kind=cfg.norm, eps=cfg.norm_eps)
                y, _ = moe.apply(p["moe"], h, cfg, rc)
                x = x + y
            new_slots.append(slot)
        return x, tuple(new_slots)

    x, new_slots = jax.lax.scan(group_body, x,
                                (params["layers"], state.slots))
    x = norm_apply(params["final_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)
    logits = unembed(params, x[:, -1:], cfg)
    return logits, DecodeState(slots=new_slots,
                               pos=jnp.full((B,), S, jnp.int32))


def _ssm_prefill(p, h, slot, cfg, rc):
    """Sequence pass that also returns the final SSM state."""
    y = ssm.apply(p, h, cfg, rc)
    # final state: run the last conv window + rebuild carried state cheaply
    # via a dedicated scan (full fidelity; reuses decode_step over the tail
    # would be O(S) — instead recompute the state from the chunked pass).
    state = _ssm_final_state(p, h, cfg, rc)
    return y, state


def _ssm_final_state(p, h, cfg, rc) -> ssm.SsmState:
    B, S, _ = h.shape
    x, z, di, nh, N, P = ssm._project(p, h, cfg)
    xc = jax.nn.silu(ssm._causal_conv(x, p["conv_w"], p["conv_b"])
                     .astype(jnp.float32)).astype(x.dtype)
    bc = dense_apply(p["bc_proj"], xc)
    Bm, _ = jnp.split(bc, 2, axis=-1)
    dt, log_decay = ssm._gates(p, xc, nh)
    xh = xc.reshape(B, S, nh, P).astype(jnp.float32) * dt[..., None]
    cum = jnp.cumsum(log_decay, axis=1)                     # [B,S,H]
    total = cum[:, -1:, :]
    w = jnp.exp(total - cum)                                # [B,S,H]
    s = jnp.einsum("bsn,bshp->bhnp", Bm.astype(jnp.float32), xh * w[..., None])
    conv_tail = x[:, S - (cfg.ssm_conv_width - 1):, :].astype(jnp.bfloat16)
    return ssm.SsmState(s=s, conv=conv_tail)


def _mlstm_prefill(p, h, cfg, rc):
    y = xlstm.mlstm_apply(p, h, cfg, rc)
    state = _mlstm_final_state(p, h, cfg)
    return y, state


def _mlstm_final_state(p, h, cfg) -> xlstm.MlstmState:
    B, S, _ = h.shape
    di, nh, P = xlstm.mlstm_dims(cfg)
    q, k, v, logi, logf, z = xlstm._mlstm_qkvg(p, h, cfg)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    cum = jnp.cumsum(logf, axis=1)                          # [B,S,H]
    total = cum[:, -1, :]                                   # [B,H]
    scores = total[:, None, :] - cum + logi                 # [B,S,H]
    m = jnp.max(scores, axis=1)                             # [B,H]
    wk = jnp.exp(scores - m[:, None, :])
    c = jnp.einsum("bshp,bshr->bhpr", kf * wk[..., None], vf)
    n = jnp.einsum("bshp,bsh->bhp", kf, wk)
    return xlstm.MlstmState(c=c, n=n, m=m)


def _slstm_prefill(p, h, cfg, rc):
    B, S, d = h.shape
    x_gates = dense_apply({"w": p["w_in"]}, h)

    def step(state, t):
        state = xlstm._slstm_cell(p, x_gates[:, t], state, cfg)
        return state, state.h

    st0 = xlstm.slstm_init_state(B, cfg)
    st, hs = jax.lax.scan(step, st0, jnp.arange(S))
    hh = hs.transpose(1, 0, 2).astype(h.dtype)
    hh = norm_apply(p["out_norm"], hh, kind=cfg.norm, eps=cfg.norm_eps)
    y = dense_apply(p["ffn_down"],
                    jax.nn.gelu(dense_apply(p["ffn_up"], hh)
                                .astype(jnp.float32)).astype(hh.dtype))
    return y, st

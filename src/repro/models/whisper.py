"""Whisper-tiny backbone: encoder-decoder transformer.

Per the assignment, the conv/mel frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings [B, 1500, d_model] (the output the
two conv layers would produce from an 30 s mel spectrogram).  Positions are
sinusoidal (computed on the fly, so the decoder backbone can be exercised
at the assigned 32k shapes even though the speech product caps at 448 —
noted as an adaptation in DESIGN.md §6).  Whisper-tiny is 4+4 layers, so
the stacks are scanned with pattern length 1 like the other archs.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention, mlp
from repro.models.common import (norm_apply, norm_axes, norm_init,
    stack_axes, stack_init, trunc_normal)
from repro.models.config import ModelConfig
from repro.runconfig import RunConfig


def sinusoid_positions(length: int, d: int, offset=0) -> jnp.ndarray:
    """[length, d] sinusoidal embedding (f32)."""
    pos = jnp.arange(length, dtype=jnp.float32) + offset
    return sinusoid_at(pos, d)


def sinusoid_at(pos, d: int) -> jnp.ndarray:
    """pos [...] -> sinusoidal embedding [..., d] (f32)."""
    pos = jnp.asarray(pos, jnp.float32)
    div = jnp.exp(-math.log(10000.0) * jnp.arange(0, d, 2, jnp.float32) / d)
    ang = pos[..., None] * div
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_layer_init(rng, cfg, dtype):
    k1, k2 = jax.random.split(rng)
    return {
        "norm1": norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": attention.init(k1, cfg, dtype),
        "norm2": norm_init(cfg.d_model, cfg.norm, dtype),
        "mlp": mlp.init(k2, cfg, dtype=dtype),
    }


def _dec_layer_init(rng, cfg, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "norm1": norm_init(cfg.d_model, cfg.norm, dtype),
        "attn": attention.init(k1, cfg, dtype),
        "norm_x": norm_init(cfg.d_model, cfg.norm, dtype),
        "xattn": attention.init(k2, cfg, dtype),
        "norm2": norm_init(cfg.d_model, cfg.norm, dtype),
        "mlp": mlp.init(k3, cfg, dtype=dtype),
    }


def _enc_layer_axes(cfg):
    return {
        "norm1": norm_axes(cfg.norm), "attn": attention.axes(cfg),
        "norm2": norm_axes(cfg.norm), "mlp": mlp.axes(cfg),
    }


def _dec_layer_axes(cfg):
    return {
        "norm1": norm_axes(cfg.norm), "attn": attention.axes(cfg),
        "norm_x": norm_axes(cfg.norm), "xattn": attention.axes(cfg),
        "norm2": norm_axes(cfg.norm), "mlp": mlp.axes(cfg),
    }


def init(rng, cfg: ModelConfig, dtype=jnp.bfloat16):
    ke, kd, kt = jax.random.split(rng, 3)
    return {
        "embed": {"tok": trunc_normal(kt, (cfg.vocab_size, cfg.d_model),
                                      1.0, dtype)},
        "encoder": stack_init(ke, cfg.n_encoder_layers,
                              lambda r: _enc_layer_init(r, cfg, dtype)),
        "enc_norm": norm_init(cfg.d_model, cfg.norm, dtype),
        "decoder": stack_init(kd, cfg.n_layers,
                              lambda r: _dec_layer_init(r, cfg, dtype)),
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
    }


def axes(cfg: ModelConfig):
    return {
        "embed": {"tok": ("vocab", "emb_embed")},
        "encoder": stack_axes(_enc_layer_axes(cfg)),
        "enc_norm": norm_axes(cfg.norm),
        "decoder": stack_axes(_dec_layer_axes(cfg)),
        "final_norm": norm_axes(cfg.norm),
    }


def encode(params, frames, cfg: ModelConfig, rc: RunConfig):
    """frames [B, T_enc, d] (stub conv output) -> encoder memory."""
    B, T, d = frames.shape
    x = frames + sinusoid_positions(T, d).astype(frames.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(x, layer):
        h = norm_apply(layer["norm1"], x, kind=cfg.norm, eps=cfg.norm_eps)
        h = attention.apply(layer["attn"], h, positions, cfg, rc,
                            causal=False, use_rope=False)
        x = x + h
        h = norm_apply(layer["norm2"], x, kind=cfg.norm, eps=cfg.norm_eps)
        x = x + mlp.apply(layer["mlp"], h, cfg, rc)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return norm_apply(params["enc_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)


def decode_train(params, tokens, memory, cfg: ModelConfig, rc: RunConfig):
    """Teacher-forced decoder pass.  tokens [B,S] -> logits [B,S,V]."""
    B, S = tokens.shape
    d = cfg.d_model
    x = params["embed"]["tok"][tokens]
    x = x + sinusoid_positions(S, d).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    mem_pos = jnp.broadcast_to(
        jnp.arange(memory.shape[1], dtype=jnp.int32)[None], memory.shape[:2])

    def body(x, layer):
        h = norm_apply(layer["norm1"], x, kind=cfg.norm, eps=cfg.norm_eps)
        h = attention.apply(layer["attn"], h, positions, cfg, rc,
                            causal=True, use_rope=False)
        x = x + h
        h = norm_apply(layer["norm_x"], x, kind=cfg.norm, eps=cfg.norm_eps)
        k, v = attention.project_kv(layer["xattn"], memory, mem_pos, cfg,
                                    use_rope=False)
        h = attention.apply(layer["xattn"], h, positions, cfg, rc,
                            causal=False, kv_override=(k, v), use_rope=False)
        x = x + h
        h = norm_apply(layer["norm2"], x, kind=cfg.norm, eps=cfg.norm_eps)
        x = x + mlp.apply(layer["mlp"], h, cfg, rc)
        return x, None

    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = norm_apply(params["final_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["tok"],
                        preferred_element_type=jnp.float32)
    return logits


def loss_fn(params, batch, cfg: ModelConfig, rc: RunConfig):
    memory = encode(params, batch["frames"], cfg, rc)
    logits = decode_train(params, batch["tokens"], memory, cfg, rc)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, cfg.vocab_size, dtype=logits.dtype)
    ll = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = jnp.mean(logz - ll)
    return nll, {"nll": nll, "aux": jnp.zeros((), jnp.float32)}


class WhisperDecodeState(NamedTuple):
    self_k: jnp.ndarray    # [L, B, S_max, Kh, D] (layout per rc)
    self_v: jnp.ndarray
    cross_k: jnp.ndarray   # [L, B, T_enc, Kh, D]
    cross_v: jnp.ndarray
    pos: jnp.ndarray


def init_decode_state(params, frames, batch: int, s_max: int,
                      cfg: ModelConfig, rc: RunConfig) -> WhisperDecodeState:
    """Encode once, pre-project cross K/V for every decoder layer."""
    memory = encode(params, frames, cfg, rc)
    mem_pos = jnp.broadcast_to(
        jnp.arange(memory.shape[1], dtype=jnp.int32)[None], memory.shape[:2])

    T_enc = memory.shape[1]

    def per_layer(layer):
        k, v = attention.project_kv(layer["xattn"], memory, mem_pos, cfg,
                                    use_rope=False)
        # store in the cache layout/dtype the decode path expects
        ck, cv = attention.init_cache(batch, T_enc, cfg, rc)
        return attention.fill_cache(ck, k, rc), attention.fill_cache(cv, v, rc)

    cross_k, cross_v = jax.vmap(per_layer)(params["decoder"])
    ck0, cv0 = attention.init_cache(batch, s_max, cfg, rc)
    L = cfg.n_layers
    self_k = jnp.broadcast_to(ck0[None], (L,) + ck0.shape)
    self_v = jnp.broadcast_to(cv0[None], (L,) + cv0.shape)
    return WhisperDecodeState(self_k=self_k, self_v=self_v,
                              cross_k=cross_k, cross_v=cross_v,
                              pos=jnp.zeros((batch,), jnp.int32))


def prefill(params, tokens, frames, s_max: int, cfg: ModelConfig,
            rc: RunConfig):
    """Encode + teacher-forced full-sequence decoder pass, filling the
    self-attention caches — the representative prefill computation (one
    full 32k decoder forward), not just a BOS step.

    Returns (last-token logits [B,1,V], WhisperDecodeState at pos=S).
    """
    from repro.parallel.sharding import shard_activation
    B, S = tokens.shape
    d = cfg.d_model
    state = init_decode_state(params, frames, B, s_max, cfg, rc)
    x = params["embed"]["tok"][tokens]
    x = x + sinusoid_positions(S, d).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    T_enc = state.cross_k.shape[2]

    def body(x, xs):
        layer, sk, sv, xk, xv = xs
        x = shard_activation(x, ("batch", "seq", "embed"), rc.shard)
        h = norm_apply(layer["norm1"], x, kind=cfg.norm, eps=cfg.norm_eps)
        k, v = attention.project_kv(layer["attn"], h, positions, cfg,
                                    use_rope=False)
        sk = attention.fill_cache(sk, k, rc)
        sv = attention.fill_cache(sv, v, rc)
        h = attention.apply(layer["attn"], h, positions, cfg, rc,
                            causal=True, use_rope=False)
        x = x + h
        h = norm_apply(layer["norm_x"], x, kind=cfg.norm, eps=cfg.norm_eps)
        xkr = attention.read_cache_full(xk, rc)
        xvr = attention.read_cache_full(xv, rc)
        h = attention.apply(layer["xattn"], h, positions, cfg, rc,
                            causal=False, kv_override=(xkr, xvr),
                            use_rope=False)
        x = x + h
        h = norm_apply(layer["norm2"], x, kind=cfg.norm, eps=cfg.norm_eps)
        x = x + mlp.apply(layer["mlp"], h, cfg, rc)
        return x, (sk, sv)

    x, (self_k, self_v) = jax.lax.scan(
        body, x, (params["decoder"], state.self_k, state.self_v,
                  state.cross_k, state.cross_v))
    x = norm_apply(params["final_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x[:, -1:], params["embed"]["tok"],
                        preferred_element_type=jnp.float32)
    return logits, WhisperDecodeState(self_k=self_k, self_v=self_v,
                                      cross_k=state.cross_k,
                                      cross_v=state.cross_v,
                                      pos=jnp.full((B,), S, jnp.int32))


def decode_step(params, token, state: WhisperDecodeState, cfg: ModelConfig,
                rc: RunConfig):
    """token [B,1] -> (logits [B,1,V], new state)."""
    B = token.shape[0]
    d = cfg.d_model
    x = params["embed"]["tok"][token]
    # per-slot sinusoidal position (vector pos -> one PE row per slot)
    x = x + sinusoid_at(state.pos, d).astype(x.dtype)[:, None, :]
    pos = state.pos
    T_enc = state.cross_k.shape[2]

    def body(x, xs):
        layer, sk, sv, xk, xv = xs
        h = norm_apply(layer["norm1"], x, kind=cfg.norm, eps=cfg.norm_eps)
        h, sk, sv = attention.decode_apply(layer["attn"], h, sk, sv, pos,
                                           cfg, rc, use_rope=False)
        x = x + h
        h = norm_apply(layer["norm_x"], x, kind=cfg.norm, eps=cfg.norm_eps)
        h, _, _ = attention.decode_apply(layer["xattn"], h, xk, xv, pos, cfg,
                                         rc, cross=True, cross_len=T_enc,
                                         use_rope=False)
        x = x + h
        h = norm_apply(layer["norm2"], x, kind=cfg.norm, eps=cfg.norm_eps)
        x = x + mlp.apply(layer["mlp"], h, cfg, rc)
        return x, (sk, sv)

    x, (self_k, self_v) = jax.lax.scan(
        body, x, (params["decoder"], state.self_k, state.self_v,
                  state.cross_k, state.cross_v))
    x = norm_apply(params["final_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["tok"],
                        preferred_element_type=jnp.float32)
    return logits, WhisperDecodeState(self_k=self_k, self_v=self_v,
                                      cross_k=state.cross_k,
                                      cross_v=state.cross_v, pos=pos + 1)

"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

arXiv:2405.04517.  The 1.3B config interleaves 7 mLSTM : 1 sLSTM.

TPU adaptation (DESIGN.md §8): the paper's CUDA mLSTM walks time
sequentially per thread-block; here the mLSTM runs in the *stabilized
chunkwise* form — intra-chunk attention-shaped matmuls with a log-space
gate mask plus an inter-chunk carried matrix memory (C, n, m) — which is
MXU/VMEM-shaped.  ``mlstm_chunk`` is a SAPPHIRE knob.  A sequential oracle
(``mlstm_reference``) backs the tests and single-token decode.  sLSTM is
inherently sequential (hidden-state recurrence); it runs as a time scan
with block-diagonal recurrent weights (4 blocks), exactly as the paper
describes for its own kernels.

Recurrence (per head, stabilized):
    C_t = f_t C_{t-1} + i_t v_t k_t^T        (matrix memory  [P, P_k])
    n_t = f_t n_{t-1} + i_t k_t              (normalizer     [P_k])
    m_t = max(log f_t + m_{t-1}, log i_t)    (stabilizer)
    h_t = (C_t q_t) / max(|n_t . q_t|, exp(-m_t))
with f = sigmoid(f̃) (log f = -softplus(-f̃)) and i = exp(ĩ) folded into the
stabilized weights.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (dense_apply, dense_axes, dense_init,
    norm_apply, norm_init, trunc_normal)
from repro.models.config import ModelConfig
from repro.runconfig import RunConfig


def mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    di = int(cfg.mlstm_expand * cfg.d_model)
    nh = cfg.n_heads
    return di, nh, di // nh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(rng, cfg: ModelConfig, dtype=jnp.bfloat16):
    di, nh, P = mlstm_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(rng, 8)
    return {
        "up": dense_init(ks[0], d, 2 * di, dtype=dtype),          # x, z
        "q": dense_init(ks[1], di, di, dtype=dtype),
        "k": dense_init(ks[2], di, di, dtype=dtype),
        "v": dense_init(ks[3], di, di, dtype=dtype),
        "igate": dense_init(ks[4], di, nh, bias=True, dtype=jnp.float32),
        "fgate": dense_init(ks[5], di, nh, bias=True, dtype=jnp.float32),
        "out_norm": norm_init(di, "rmsnorm", dtype),
        "down": dense_init(ks[6], di, d, dtype=dtype,
                           scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }


def mlstm_axes(cfg: ModelConfig):
    return {
        "up": dense_axes("ssm_in", "ssm_inner"),
        "q": dense_axes("ssm_inner", "ssm_inner"),
        "k": dense_axes("ssm_inner", "ssm_inner"),
        "v": dense_axes("ssm_inner", "ssm_inner"),
        "igate": dense_axes("ssm_inner", None, bias=True),
        "fgate": dense_axes("ssm_inner", None, bias=True),
        "out_norm": {"scale": ("ssm_inner",)},
        "down": dense_axes("ssm_inner", "o_out"),
    }


class MlstmState(NamedTuple):
    c: jnp.ndarray    # [B, H, P, P]
    n: jnp.ndarray    # [B, H, P]
    m: jnp.ndarray    # [B, H]


def mlstm_init_state(batch: int, cfg: ModelConfig) -> MlstmState:
    _, nh, P = mlstm_dims(cfg)
    return MlstmState(
        c=jnp.zeros((batch, nh, P, P), jnp.float32),
        n=jnp.zeros((batch, nh, P), jnp.float32),
        m=jnp.full((batch, nh), -1e30, jnp.float32),
    )


def mlstm_state_axes(cfg: ModelConfig):
    return MlstmState(c=("batch", None, None, None),
                      n=("batch", None, None),
                      m=("batch", None))


def _mlstm_qkvg(params, u, cfg):
    """Project inputs.  u [B,S,d] -> q,k,v [B,S,H,P], logi/logf [B,S,H], z."""
    di, nh, P = mlstm_dims(cfg)
    B, S, _ = u.shape
    xz = dense_apply(params["up"], u)
    x, z = jnp.split(xz, 2, axis=-1)
    q = dense_apply(params["q"], x).reshape(B, S, nh, P)
    k = dense_apply(params["k"], x).reshape(B, S, nh, P) / math.sqrt(P)
    v = dense_apply(params["v"], x).reshape(B, S, nh, P)
    logi = dense_apply(params["igate"], x).astype(jnp.float32)     # ĩ
    logf = -jax.nn.softplus(-dense_apply(params["fgate"], x).astype(jnp.float32))
    return q, k, v, logi, logf, z


def mlstm_apply(params, u, cfg: ModelConfig, rc: RunConfig):
    """Chunkwise-parallel stabilized mLSTM.  u [B,S,d] -> [B,S,d].

    On TPU the chunk recurrence dispatches to the Pallas kernel
    (kernels/mlstm_chunk); elsewhere the jnp scan below is the compiled
    path and the kernel's oracle.
    """
    B, S, _ = u.shape
    di, nh, P = mlstm_dims(cfg)
    q, k, v, logi, logf, z = _mlstm_qkvg(params, u, cfg)

    if jax.default_backend() == "tpu" and S % rc.mlstm_chunk == 0:
        from repro.kernels.mlstm_chunk.ops import mlstm_chunk as _kernel
        h = _kernel(q, k, v, logi, logf, chunk=rc.mlstm_chunk)
        h = h.reshape(B, S, di)
        h = norm_apply(params["out_norm"],
                       h.astype(u.dtype)
                       * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype),
                       kind="rmsnorm", eps=cfg.norm_eps)
        return dense_apply(params["down"], h)

    c = min(rc.mlstm_chunk, S)
    assert S % c == 0, "mlstm_chunk must divide seq len"
    n_chunks = S // c

    def csh(t, tail):      # chunk + move chunk axis first
        return t.reshape((B, n_chunks, c) + tail).transpose(
            (1, 0, 2) + tuple(range(3, 3 + len(tail))))

    qc, kc, vc = csh(q, (nh, P)), csh(k, (nh, P)), csh(v, (nh, P))
    lic, lfc = csh(logi, (nh,)), csh(logf, (nh,))

    def body(state, xs):
        c_prev, n_prev, m_prev = state
        qi, ki, vi, li, lf = xs
        qf = qi.astype(jnp.float32)
        kf = ki.astype(jnp.float32)
        vf = vi.astype(jnp.float32)
        cum = jnp.cumsum(lf, axis=1)                       # [B,c,H] inclusive
        # D[b,i,j,h] = cum_i - cum_j + li_j   for j <= i
        D = cum[:, :, None, :] - cum[:, None, :, :] + li[:, None, :, :]
        mask = jnp.tril(jnp.ones((c, c), bool))
        D = jnp.where(mask[None, :, :, None], D, -jnp.inf)
        # stabilizer: max over history (carried m enters via cum + m_prev)
        m_loc = jnp.max(D, axis=2)                          # [B,i,H]
        m_comb = jnp.maximum(m_loc, cum + m_prev[:, None, :])
        m_comb = jnp.maximum(m_comb, -1e30)                 # avoid -inf
        w = jnp.exp(D - m_comb[:, :, None, :])              # [B,i,j,H]
        qk = jnp.einsum("bihp,bjhp->bijh", qf, kf)
        s = qk * w
        h_intra = jnp.einsum("bijh,bjhp->bihp", s, vf)
        n_intra = jnp.einsum("bijh,bjhp->bihp", w, kf)
        # inter-chunk contribution
        scale_in = jnp.exp(cum + m_prev[:, None, :] - m_comb)   # [B,i,H]
        h_inter = jnp.einsum("bihp,bhpr->bihr", qf, c_prev) * scale_in[..., None]
        n_inter = n_prev[:, None] * scale_in[..., None]
        h_num = h_intra + h_inter
        n_all = n_intra + n_inter
        denom = jnp.maximum(jnp.abs(jnp.einsum("bihp,bihp->bih", n_all, qf)),
                            jnp.exp(-m_comb))
        h = h_num / denom[..., None]
        # carry update
        total = cum[:, -1, :]                               # [B,H]
        m_new = jnp.maximum(total + m_prev, jnp.max(
            total[:, None, :] - cum + li, axis=1))
        wk = jnp.exp(total[:, None, :] - cum + li - m_new[:, None, :])  # [B,c,H]
        c_new = c_prev * jnp.exp(total + m_prev - m_new)[..., None, None] \
            + jnp.einsum("bjhp,bjhr->bhpr", kf * wk[..., None], vf)
        n_new = n_prev * jnp.exp(total + m_prev - m_new)[..., None] \
            + jnp.einsum("bjhp,bjh->bhp", kf, wk)
        return (c_new, n_new, m_new), h

    st0 = (jnp.zeros((B, nh, P, P), jnp.float32),
           jnp.zeros((B, nh, P), jnp.float32),
           jnp.full((B, nh), -1e30, jnp.float32))
    _, hs = jax.lax.scan(body, st0, (qc, kc, vc, lic, lfc))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, di)
    h = norm_apply(params["out_norm"],
                   h.astype(u.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype),
                   kind="rmsnorm", eps=cfg.norm_eps)
    return dense_apply(params["down"], h)


def mlstm_reference(params, u, cfg: ModelConfig):
    """Sequential stabilized recurrence (oracle)."""
    B, S, _ = u.shape
    di, nh, P = mlstm_dims(cfg)
    q, k, v, logi, logf, z = _mlstm_qkvg(params, u, cfg)
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))

    def step(state, t):
        c, n, m = state
        lf_t, li_t = logf[:, t], logi[:, t]                 # [B,H]
        m_new = jnp.maximum(lf_t + m, li_t)
        fw = jnp.exp(lf_t + m - m_new)
        iw = jnp.exp(li_t - m_new)
        c = c * fw[..., None, None] + iw[..., None, None] * jnp.einsum(
            "bhp,bhr->bhpr", kf[:, t], vf[:, t])
        n = n * fw[..., None] + iw[..., None] * kf[:, t]
        num = jnp.einsum("bhp,bhpr->bhr", qf[:, t], c)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, qf[:, t])),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (c, n, m_new), h

    st = (jnp.zeros((B, nh, P, P), jnp.float32),
          jnp.zeros((B, nh, P), jnp.float32),
          jnp.full((B, nh), -1e30, jnp.float32))
    _, hs = jax.lax.scan(step, st, jnp.arange(S))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, di)
    h = norm_apply(params["out_norm"],
                   h.astype(u.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype),
                   kind="rmsnorm", eps=cfg.norm_eps)
    return dense_apply(params["down"], h)


def mlstm_decode_step(params, u, state: MlstmState, cfg: ModelConfig,
                      rc: RunConfig):
    """One-token mLSTM decode.  u [B,1,d]."""
    B = u.shape[0]
    di, nh, P = mlstm_dims(cfg)
    q, k, v, logi, logf, z = _mlstm_qkvg(params, u, cfg)
    qf, kf, vf = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    lf_t, li_t = logf[:, 0], logi[:, 0]
    m_new = jnp.maximum(lf_t + state.m, li_t)
    fw = jnp.exp(lf_t + state.m - m_new)
    iw = jnp.exp(li_t - m_new)
    c = state.c * fw[..., None, None] + iw[..., None, None] * jnp.einsum(
        "bhp,bhr->bhpr", kf, vf)
    n = state.n * fw[..., None] + iw[..., None] * kf
    num = jnp.einsum("bhp,bhpr->bhr", qf, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, qf)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, di)
    h = norm_apply(params["out_norm"],
                   h.astype(u.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype),
                   kind="rmsnorm", eps=cfg.norm_eps)
    return dense_apply(params["down"], h), MlstmState(c=c, n=n, m=m_new)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

N_SLSTM_BLOCKS = 4


def slstm_init(rng, cfg: ModelConfig, dtype=jnp.bfloat16):
    d = cfg.d_model
    dp = int(cfg.slstm_proj * d)
    nb = N_SLSTM_BLOCKS
    bs = d // nb
    ks = jax.random.split(rng, 6)
    return {
        # input weights for 4 gates (i, f, z, o) at once
        "w_in": trunc_normal(ks[0], (d, 4 * d), 1.0, dtype),
        # block-diagonal recurrent weights [4, nb, bs, bs]
        "w_rec": trunc_normal(ks[1], (4, nb, bs, bs), 1.0, dtype),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "out_norm": norm_init(d, "rmsnorm", dtype),
        "ffn_up": dense_init(ks[2], d, dp, dtype=dtype),
        "ffn_down": dense_init(ks[3], dp, d, dtype=dtype,
                               scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }


def slstm_axes(cfg: ModelConfig):
    return {
        "w_in": ("ssm_in", None),
        "w_rec": (None, None, None, None),
        "bias": (None,),
        "out_norm": {"scale": ("embed",)},
        "ffn_up": dense_axes("ff_in", "ff"),
        "ffn_down": dense_axes("ff", "o_out"),
    }


class SlstmState(NamedTuple):
    c: jnp.ndarray    # [B, d]
    n: jnp.ndarray    # [B, d]
    h: jnp.ndarray    # [B, d]
    m: jnp.ndarray    # [B, d]


def slstm_init_state(batch: int, cfg: ModelConfig) -> SlstmState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SlstmState(c=z, n=z, h=z, m=jnp.full((batch, d), -1e30, jnp.float32))


def slstm_state_axes(cfg: ModelConfig):
    return SlstmState(c=("batch", "embed"), n=("batch", "embed"),
                      h=("batch", "embed"), m=("batch", "embed"))


def _slstm_cell(params, x_t, state: SlstmState, cfg: ModelConfig):
    """One sLSTM step.  x_t [B, 4d] (pre-projected input gates)."""
    d = cfg.d_model
    nb = N_SLSTM_BLOCKS
    bs = d // nb
    B = state.h.shape[0]
    hb = state.h.reshape(B, nb, bs)
    rec = jnp.einsum("bnk,gnkl->bgnl", hb.astype(jnp.float32),
                     params["w_rec"].astype(jnp.float32)).reshape(B, 4 * d)
    g = x_t.astype(jnp.float32) + rec + params["bias"]
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    log_f = -jax.nn.softplus(-gf)                       # log sigmoid(f)
    m_new = jnp.maximum(log_f + state.m, gi)
    i_w = jnp.exp(gi - m_new)
    f_w = jnp.exp(log_f + state.m - m_new)
    c = f_w * state.c + i_w * jnp.tanh(gz)
    n = f_w * state.n + i_w
    h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1.0)
    return SlstmState(c=c, n=n, h=h, m=m_new)


def slstm_apply(params, u, cfg: ModelConfig, rc: RunConfig):
    """Sequence sLSTM via time scan.  u [B,S,d] -> [B,S,d]."""
    B, S, d = u.shape
    x_gates = dense_apply({"w": params["w_in"]}, u)      # [B,S,4d]

    def step(state, t):
        state = _slstm_cell(params, x_gates[:, t], state, cfg)
        return state, state.h

    st0 = slstm_init_state(B, cfg)
    _, hs = jax.lax.scan(step, st0, jnp.arange(S))
    h = hs.transpose(1, 0, 2).astype(u.dtype)            # [B,S,d]
    h = norm_apply(params["out_norm"], h, kind="rmsnorm", eps=cfg.norm_eps)
    # gelu FFN (proj factor 4/3)
    y = dense_apply(params["ffn_down"],
                    jax.nn.gelu(dense_apply(params["ffn_up"], h)
                                .astype(jnp.float32)).astype(h.dtype))
    return y


def slstm_decode_step(params, u, state: SlstmState, cfg: ModelConfig,
                      rc: RunConfig):
    B = u.shape[0]
    x_gates = dense_apply({"w": params["w_in"]}, u)[:, 0]
    state = _slstm_cell(params, x_gates, state, cfg)
    h = state.h[:, None, :].astype(u.dtype)
    h = norm_apply(params["out_norm"], h, kind="rmsnorm", eps=cfg.norm_eps)
    y = dense_apply(params["ffn_down"],
                    jax.nn.gelu(dense_apply(params["ffn_up"], h)
                                .astype(jnp.float32)).astype(h.dtype))
    return y, state

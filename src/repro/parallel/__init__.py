from repro.parallel.sharding import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    logical_to_spec,
    param_shardings,
    shard_config_from_knobs,
)

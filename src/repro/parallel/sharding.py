"""Logical-axis sharding system.

Every parameter / activation in the model zoo is annotated with *logical*
axis names (``"embed"``, ``"ff"``, ``"heads"``, ``"batch"``, ``"seq"``,
``"experts"``, ...).  An :class:`AxisRules` table maps logical names to mesh
axis names.  The mapping itself is **part of the tunable configuration
space** — SAPPHIRE's knobs select between FSDP/TP/EP/SP layouts by rewriting
this table, the TPU analogue of Ceph's module-selector parameters
(``osd_objectstore``): one knob decides the layout *module*, gating which
sub-knobs take effect (DESIGN.md §5).

Mesh axes (launch/mesh.py):
  single-pod : ("data", "model")                       16 × 16 = 256 chips
  multi-pod  : ("pod", "data", "model")            2 × 16 × 16 = 512 chips

Besides the model-zoo layouts this module also owns the *proposer-side*
mesh: the tuner itself runs on an accelerator host, and its candidate
pool shards over a 1-D ``("pool",)`` mesh (:func:`pool_mesh`) — each
device scores a shard of the acquisition pool against a replicated GP
posterior (``gp.select_batch_sharded``).  :func:`spare_device` picks the
device background work (the marginal-likelihood refit) is pinned to.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

POOL_AXIS = "pool"


def pool_devices(n: Optional[int] = None) -> Tuple:
    """The devices the proposer's candidate pool shards over: the first
    ``n`` host devices (all of them when ``n`` is None or exceeds the
    host).  Deterministic order — shard k owns pool rows
    ``[k·M/nd, (k+1)·M/nd)``, so the device tuple is part of the
    pick-reproducibility contract."""
    devs = jax.devices()
    if n is not None:
        devs = devs[:max(int(n), 1)]
    return tuple(devs)


def pool_mesh(n: Optional[int] = None,
              devices: Optional[Sequence] = None) -> Mesh:
    """1-D ``("pool",)`` mesh over host devices for proposer fan-out
    (candidate scoring, kernel-autotune sweeps)."""
    devs = tuple(devices) if devices is not None else pool_devices(n)
    return Mesh(np.array(devs), (POOL_AXIS,))


def spare_device(avoid_index: int = 0):
    """A device for background work (the GP refit executor): the *last*
    host device when more than one exists — off the driver's dispatch
    queue, which stays on device ``avoid_index`` — else ``None`` (single
    device: background work shares the queue and only thread-yields)."""
    devs = jax.devices()
    if len(devs) <= 1:
        return None
    for d in reversed(devs):
        if devs.index(d) != avoid_index:
            return d
    return None


@dataclass(frozen=True)
class AxisRules:
    """Mapping from logical axis names to mesh axes (None = replicate)."""

    rules: Tuple[Tuple[str, MeshAxes], ...]

    def to_dict(self) -> Dict[str, MeshAxes]:
        return dict(self.rules)

    def with_rule(self, logical: str, mesh_axes: MeshAxes) -> "AxisRules":
        d = self.to_dict()
        d[logical] = mesh_axes
        return AxisRules(tuple(d.items()))

    def mesh_axes_for(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        return self.to_dict().get(logical, None)


# The "megatron + fsdp" default layout on the fixed (data, model) mesh.
# "batch" covers the data-parallel axes (and "pod" when present — the caller
# appends it, see `with_pod_axis`).
DEFAULT_RULES = AxisRules(
    (
        ("batch", ("data",)),          # activation batch
        ("seq", None),                 # sequence (SP off by default)
        ("embed", None),               # d_model dim of activations
        ("vocab", "model"),            # embedding table vocab dim
        ("emb_embed", None),           # embedding table d_model dim
        ("heads", "model"),            # attention heads (TP)
        ("kv_heads", "model"),         # kv heads (TP; requires kv>=tp or repl)
        ("head_dim", None),
        ("qkv_in", "fsdp"),            # contraction dim of qkv proj (FSDP)
        ("o_out", "fsdp"),             # output dim of o proj (FSDP)
        ("ff", "model"),               # MLP hidden (TP)
        ("ff_in", "fsdp"),             # MLP input dim (FSDP)
        ("experts", "model"),          # MoE expert dim (EP over model axis)
        ("expert_ff", "model"),        # fallback: TP inside experts — used
                                       # when n_experts doesn't divide the
                                       # model axis (grok: 8e on 16-way),
                                       # where the guard replicates the
                                       # expert dim and this one takes over
        ("expert_in", "fsdp"),
        ("kv_seq", None),              # KV-cache sequence dim
        ("ssm_inner", "model"),        # mamba/xlstm inner width (TP)
        ("ssm_in", "fsdp"),
        ("ssm_state", None),
        ("fsdp", None),                # placeholder resolved below
    )
)


@dataclass(frozen=True)
class ShardConfig:
    """Resolved distribution layout — the output of the layout knobs."""

    fsdp: bool = True                    # shard param "fsdp" dims over data axis
    tensor_parallel: bool = True         # map "model"-tagged dims to mesh model
    expert_parallel: bool = True         # shard experts over model axis
    sequence_parallel: bool = False      # shard activation seq over model axis
    shard_kv_seq_for_decode: bool = False  # flash-decode style KV seq sharding
    pod_in_batch: bool = True            # multi-pod: pod axis joins batch
    rules: AxisRules = DEFAULT_RULES

    def resolve(self, mesh: Mesh) -> AxisRules:
        """Produce final rules for a concrete mesh."""
        axis_names = set(mesh.axis_names)
        d = self.rules.to_dict()

        # FSDP placeholder: "fsdp"-tagged dims shard over the data axis (and
        # pod axis — ZeRO-3 across the full DP world) when fsdp is on.
        fsdp_axes: MeshAxes = None
        if self.fsdp:
            fsdp_axes = ("pod", "data") if "pod" in axis_names else ("data",)
        for k, v in list(d.items()):
            if v == "fsdp" or v == ("fsdp",):
                d[k] = fsdp_axes

        # Batch axis: include pod for multi-pod DP.
        if "pod" in axis_names and self.pod_in_batch:
            d["batch"] = ("pod", "data")
        else:
            d["batch"] = ("data",)

        if not self.tensor_parallel:
            for k in ("heads", "kv_heads", "ff", "vocab", "ssm_inner"):
                d[k] = None
        if not self.expert_parallel:
            d["experts"] = None
        if self.sequence_parallel:
            d["seq"] = ("model",)
        if self.shard_kv_seq_for_decode:
            d["kv_seq"] = ("data",)
        d.pop("fsdp", None)
        return AxisRules(tuple(d.items()))


def shard_config_from_knobs(knobs: Dict[str, object]) -> ShardConfig:
    """Translate SAPPHIRE layout knobs into a ShardConfig (module selection)."""
    return ShardConfig(
        fsdp=bool(knobs.get("fsdp_shard_params", True)),
        tensor_parallel=bool(knobs.get("tensor_parallel", True)),
        expert_parallel=bool(knobs.get("expert_parallel", True)),
        sequence_parallel=bool(knobs.get("sequence_parallel", False)),
        shard_kv_seq_for_decode=bool(knobs.get("shard_kv_seq", False)),
        pod_in_batch=bool(knobs.get("pod_in_batch", True)),
    )


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    rules: AxisRules,
    mesh: Mesh,
    shape: Optional[Sequence[int]] = None,
) -> P:
    """Convert a tuple of logical axis names into a PartitionSpec.

    Guards against (a) mesh axes the mesh doesn't have, (b) using the same
    mesh axis twice in one spec (illegal), and — when ``shape`` is given —
    (c) dims not divisible by their mesh-axis product.  The divisibility
    check runs BEFORE an axis is marked used, so a non-divisible dim
    releases its mesh axis to later dims (grok-1: 8 experts can't take the
    16-way model axis, so expert_ff picks it up — TP inside experts).
    """
    axis_names = set(mesh.axis_names)
    dims = list(shape) + [None] * len(logical_axes) if shape is not None \
        else [None] * len(logical_axes)
    used: set = set()
    out = []
    for i, name in enumerate(logical_axes):
        mesh_axes = rules.mesh_axes_for(name)
        if mesh_axes is None:
            out.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        ok = tuple(a for a in mesh_axes if a in axis_names and a not in used)
        if not ok:
            out.append(None)
            continue
        if dims[i] is not None:
            size = 1
            for a in ok:
                size *= mesh.shape[a]
            if size <= 1 or dims[i] % size != 0:
                out.append(None)          # axis NOT consumed: stays free
                continue
        used.update(ok)
        out.append(ok if len(ok) > 1 else ok[0])
    # Trim trailing Nones (cosmetic; P() pads automatically).
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def spec_tree(axes_tree, rules: AxisRules, mesh: Mesh):
    """Map a pytree of logical-axes tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda ax: logical_to_spec(ax, rules, mesh),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def param_shardings(axes_tree, rules: AxisRules, mesh: Mesh):
    """Pytree of NamedShardings for a pytree of logical-axes tuples."""
    specs = spec_tree(axes_tree, rules, mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def data_parallel_size(shard_cfg: "ShardConfig") -> int:
    """Total DP world size implied by the ambient mesh (1 off-mesh)."""
    mesh = _ambient_mesh()
    if mesh is None:
        return 1
    dp = mesh.shape.get("data", 1)
    if "pod" in mesh.axis_names and shard_cfg.pod_in_batch:
        dp *= mesh.shape["pod"]
    return dp


def _ambient_mesh() -> Optional[Mesh]:
    """The mesh installed by ``with mesh:`` (None outside any mesh)."""
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def shard_activation(x, logical_axes, shard_cfg: "ShardConfig"):
    """``with_sharding_constraint`` on an activation, by logical axes.

    Without this, XLA's SPMD partitioner may resolve the FSDP(weights-over-
    data) vs DP(batch-over-data) axis conflict by *replicating the batch*
    inside the layer scan — attention einsums then run dp-times redundant
    (measured 16× on the 16×16 mesh).  Pinning the batch/seq sharding on
    the layer inputs forces the all-gather onto the weights instead — the
    ZeRO-3 schedule.  No-op outside a mesh context (CPU smoke tests).
    """
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    rules = shard_cfg.resolve(mesh)
    spec = logical_to_spec(logical_axes, rules, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# logical axes that the FSDP placeholder resolves onto (weight shards that
# must be re-gathered before compute)
FSDP_TAGGED = ("qkv_in", "o_out", "ff_in", "expert_in", "ssm_in", "emb_embed")


def gather_weights_for_compute(params, axes_tree, shard_cfg: "ShardConfig"):
    """ZeRO-3 just-in-time weight all-gather, as a sharding constraint.

    FSDP stores weights sharded over the data axis; naive SPMD then runs
    the matmul with a *contraction-dim-sharded* weight, producing partial
    sums and a per-matmul activation all-reduce (measured 229 GB/device
    per step on yi-6b).  Re-pinning each weight leaf to "replicated over
    data, still TP-sharded over model" right before use makes XLA insert a
    small weight all-gather inside the layer loop instead — the ZeRO-3
    schedule (weights stream in, activations never reduce over data).
    No-op outside a mesh context or when FSDP is off.
    """
    mesh = _ambient_mesh()
    if mesh is None or not shard_cfg.fsdp:
        return params
    rules = shard_cfg.resolve(mesh)
    compute_rules = rules.to_dict()
    for name in FSDP_TAGGED:
        compute_rules[name] = None
    compute_rules = AxisRules(tuple(compute_rules.items()))

    p_leaves, p_def = jax.tree.flatten(params)
    ax_leaves = jax.tree.flatten(
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x))[0]
    if len(p_leaves) != len(ax_leaves):
        return params                     # structure drift: fail open
    out = []
    for leaf, ax in zip(p_leaves, ax_leaves):
        axes = tuple(ax) + (None,) * (leaf.ndim - len(tuple(ax)))
        spec = logical_to_spec(axes, compute_rules, mesh, leaf.shape)
        out.append(jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec)))
    return jax.tree.unflatten(p_def, out)


def shardings_for(shapes_tree, axes_tree, rules: AxisRules, mesh: Mesh):
    """NamedShardings with a per-dimension divisibility guard.

    XLA SPMD wants evenly divisible dims for most ops; the full configs
    guarantee it for the big dims, but odd ones (whisper's 6 heads or
    51865 vocab, batch=1 long-context decode) must fall back to
    replication on that dim instead of failing to lower.
    """
    def one(shape_leaf, ax):
        shape = tuple(shape_leaf.shape)
        axes = tuple(ax) + (None,) * (len(shape) - len(tuple(ax)))
        return NamedSharding(mesh,
                             logical_to_spec(axes, rules, mesh, shape))

    # The axes tree mirrors the shapes tree but its leaves are *tuples*
    # (pytree containers), so a joint tree.map can't see them — flatten
    # both with their own leaf definitions and zip.
    sh_leaves, sh_def = jax.tree.flatten(shapes_tree)
    ax_leaves = jax.tree.flatten(
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x))[0]
    assert len(sh_leaves) == len(ax_leaves), \
        f"shapes/axes mismatch: {len(sh_leaves)} vs {len(ax_leaves)}"
    return jax.tree.unflatten(sh_def, [one(s, a) for s, a
                                       in zip(sh_leaves, ax_leaves)])


def divisible_or_replicate(
    dim_size: int, logical: str, rules: AxisRules, mesh: Mesh
) -> MeshAxes:
    """Check a dim is divisible by its mesh-axis product, else replicate.

    XLA SPMD requires even divisibility for many ops; our configs guarantee
    it for the assigned architectures, but reduced smoke configs may not —
    this helper keeps them runnable.
    """
    mesh_axes = rules.mesh_axes_for(logical)
    if mesh_axes is None:
        return None
    if isinstance(mesh_axes, str):
        mesh_axes = (mesh_axes,)
    size = 1
    for a in mesh_axes:
        size *= mesh.shape[a]
    return mesh_axes if dim_size % size == 0 else None

"""RunConfig: the *execution* configuration of a training/serving step.

This is the typed destination of SAPPHIRE's tunable knobs — the analogue of
a Ceph config file after constraint resolution.  ``ModelConfig`` describes
*what* to compute; ``RunConfig`` describes *how*: parallel layout,
microbatching, rematerialization, kernel selection and block sizes, dtypes,
collective behavior.  Every field maps 1:1 to one or more knobs in
``repro.core.knobs`` (C1-washed, C2-bounded, C3-gated, C4-projected).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict

from repro.parallel.sharding import ShardConfig, shard_config_from_knobs


@dataclass(frozen=True)
class RunConfig:
    # ---- distribution layout (module-selector knobs, C3) ----
    shard: ShardConfig = ShardConfig()

    # ---- step structure ----
    microbatch: int = 0               # 0 = no grad accumulation (single shot)
    remat_policy: str = "none"        # none | dots | block | full
    grad_accum_unroll: bool = False   # unroll the accumulation loop

    # ---- attention ----
    attention_impl: str = "reference"  # reference | chunked | flash
    flash_block_q: int = 512           # MXU-aligned (C2: multiple of 128)
    flash_block_k: int = 512
    chunk_size_k: int = 2048           # chunked (online-softmax) KV chunk

    # ---- numerics ----
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    accum_dtype: str = "float32"
    matmul_precision: str = "default"  # default | high | highest
    grad_allreduce_dtype: str = "float32"  # float32 | bfloat16 (compression)
    tp_reduce_dtype: str = "float32"   # dtype of TP partial-sum reductions:
                                       # bfloat16 halves the activation
                                       # all-reduce bytes (Megatron-style)

    # ---- optimizer ----
    optimizer: str = "adamw"           # adamw | adafactor
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip_norm: float = 1.0
    master_weights_f32: bool = True

    # ---- MoE ----
    moe_capacity_factor: float = 1.25
    moe_impl: str = "dense"            # dense (einsum over experts) | dropping

    # ---- SSM / xLSTM ----
    ssm_chunk: int = 256               # chunked-scan chunk length
    mlstm_chunk: int = 256

    # ---- serving ----
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8 (simulated quant)
    kv_layout: str = "bshd"            # bshd | bhsd
    prefill_chunk: int = 0             # 0 = single-shot prefill
    decode_batch_tile: int = 0         # 0 = whole batch at once

    # ---- collectives ----
    allreduce_per_microbatch: bool = False  # overlap grads w/ next microbatch
    pod_hierarchical_allreduce: bool = True

    # ---- inert telemetry knobs (Ceph debug_* analogues; never read by the
    #      step function — SAPPHIRE's washing/ranking must discover this) ----
    telemetry_interval_steps: int = 100
    log_verbosity: int = 1
    profiler_trace_steps: int = 0
    checkpoint_interval_steps: int = 1000

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def runconfig_from_knobs(knobs: Dict[str, object]) -> RunConfig:
    """Build a RunConfig from a flat knob dict (post constraint-resolution).

    Unknown knobs are ignored (they may belong to other subsystems); gated
    knobs arrive already projected by the constraint solver.
    """
    base = RunConfig()
    fields = {f.name for f in dataclasses.fields(RunConfig)}
    kw = {}
    for k, v in knobs.items():
        if k in fields:
            kw[k] = v
    kw["shard"] = shard_config_from_knobs(knobs)
    return dataclasses.replace(base, **kw)

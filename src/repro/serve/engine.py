"""Batched serving engine with token-level continuous batching.

Orca-style scheduling: one compiled ``decode_step`` advances **all** slots
every iteration; a freshly admitted request replays its prompt through the
same step (prefill-as-decode) while neighbouring slots keep generating —
no global prefill/decode phase barrier, no recompilation on admission.

Mechanics (enabled by the model's per-slot position vector):

* ``DecodeState.pos`` is a [slots] vector — each slot attends to exactly
  its own ``kv_len = pos+1`` prefix, so a recycled slot needs no cache
  zeroing: stale rows sit beyond its kv_len and are masked;
* admission resets ``pos[slot] = 0`` and streams the prompt tokens in as
  that slot's per-step input;
* emission: a slot in the replay phase discards logits until its prompt is
  consumed, then greedy-decodes; finished slots idle on token 0 until
  recycled;
* admission control: the KV-cache budget (kvcache.py, the
  ``kvcache_hbm_frac`` knob) caps slots × s_max up front.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.runconfig import RunConfig
from repro.serve.kvcache import CachePlan

IDLE, REPLAY, DECODE = 0, 1, 2


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    admitted_at_step: int = -1
    finished_at_step: int = -1


class Engine:
    def __init__(self, model: Model, params, rc: RunConfig, *,
                 slots: int = 8, s_max: int = 1024, hbm_bytes: float = 16e9,
                 kv_frac: float = 0.3):
        if model.cfg.is_encoder_decoder:
            raise NotImplementedError(
                "engine serves decoder-only stacks; whisper decodes via "
                "Model.decode_step directly (examples/serve_batched.py)")
        self.model, self.params, self.rc = model, params, rc
        self.slots, self.s_max = slots, s_max
        self.plan = CachePlan.build(model.cfg, rc, hbm_bytes=hbm_bytes,
                                    kv_frac=kv_frac)
        if not self.plan.fits(slots, s_max):
            raise ValueError(
                f"kv budget: {slots}x{s_max} needs "
                f"{slots * s_max * self.plan.bytes_per_token_per_seq / 2**30:.2f}"
                f" GiB > {self.plan.budget_bytes / 2**30:.2f} GiB — lower "
                f"slots/s_max or raise kvcache_hbm_frac")
        self.state = model.init_decode_state({}, slots, s_max, rc)
        self._decode = jax.jit(
            lambda p, tok, st: model.decode_step(p, tok, st, rc))

        self.phase = np.full(slots, IDLE, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.replay_cursor = np.zeros(slots, np.int32)
        self.next_tok = np.zeros((slots, 1), np.int32)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.step_count = 0
        self._rid = 0

    # ---- public API ----------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) + max_new_tokens > self.s_max:
            raise ValueError("request exceeds s_max")
        rid = self._rid
        self._rid += 1
        self.queue.append(Request(rid, prompt, max_new_tokens))
        return rid

    def run(self, max_steps: int = 100_000) -> List[Request]:
        for _ in range(max_steps):
            if not self.queue and all(p == IDLE for p in self.phase):
                break
            self.step()
        return self.finished

    # ---- one engine iteration ---------------------------------------------------

    def step(self):
        self._admit()
        if all(p == IDLE for p in self.phase):
            return
        logits, self.state = self._decode(self.params,
                                          jnp.asarray(self.next_tok),
                                          self.state)
        argmax = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1), np.int32)
        self.step_count += 1
        for s in range(self.slots):
            req = self.slot_req[s]
            if req is None:
                self.next_tok[s, 0] = 0
                continue
            if self.phase[s] == REPLAY:
                self.replay_cursor[s] += 1
                if self.replay_cursor[s] < len(req.prompt):
                    self.next_tok[s, 0] = req.prompt[self.replay_cursor[s]]
                else:
                    self.phase[s] = DECODE        # prompt consumed: emit
                    req.out_tokens.append(int(argmax[s]))
                    self.next_tok[s, 0] = argmax[s]
            else:                                  # DECODE
                req.out_tokens.append(int(argmax[s]))
                self.next_tok[s, 0] = argmax[s]
            if req.out_tokens and (
                    len(req.out_tokens) >= req.max_new_tokens
                    or len(req.prompt) + len(req.out_tokens) >= self.s_max):
                req.done = True
                req.finished_at_step = self.step_count
                self.finished.append(req)
                self.slot_req[s] = None
                self.phase[s] = IDLE
                self.next_tok[s, 0] = 0

    def _admit(self):
        for s in range(self.slots):
            if self.phase[s] != IDLE or not self.queue:
                continue
            req = self.queue.pop(0)
            req.admitted_at_step = self.step_count
            self.slot_req[s] = req
            self.phase[s] = REPLAY
            self.replay_cursor[s] = 0
            self.next_tok[s, 0] = req.prompt[0]
            # recycle the slot: pos -> 0 (stale cache rows are masked by
            # the per-slot kv_len; no zeroing needed)
            self.state = self.state._replace(
                pos=self.state.pos.at[s].set(0))

    # ---- metrics ------------------------------------------------------------

    def utilization(self) -> float:
        return float(np.mean(self.phase != IDLE))

"""KV-cache budgeting: HBM planning under the memory-fraction knobs.

The C4 sum constraint ``act_hbm_frac + kvcache_hbm_frac <= 0.9`` (the
bluestore cache-ratio analogue) is enforced by the constraint solver; this
module turns the granted fraction into concrete serving limits:

    plan = CachePlan.build(cfg, rc, mesh_chips, tp, hbm_bytes, frac)
    plan.max_batch(seq_len)  /  plan.max_seq(batch)

Cache buffers themselves live in models/attention.py (layout and dtype are
knobs); this is the admission-control arithmetic the engine uses.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.models.config import ModelConfig
from repro.runconfig import RunConfig


def _dtype_bytes(name: str) -> int:
    return {"bfloat16": 2, "float16": 2, "float32": 4, "int8": 1}[name]


@dataclass(frozen=True)
class CachePlan:
    bytes_per_token_per_seq: int      # per sequence position, all layers
    budget_bytes: int                 # per-replica KV budget
    cfg: ModelConfig

    @classmethod
    def build(cls, cfg: ModelConfig, rc: RunConfig, *, hbm_bytes: float,
              kv_frac: float, tp: int = 1) -> "CachePlan":
        per_tok = (2 * cfg.kv_dim * _dtype_bytes(rc.kv_cache_dtype)
                   * cfg.attn_layer_count)
        if cfg.is_encoder_decoder:
            per_tok += 2 * cfg.kv_dim * _dtype_bytes(rc.kv_cache_dtype) \
                * cfg.n_layers           # cross-attn memory
        per_tok = max(per_tok // max(tp, 1), 1)
        return cls(per_tok, int(hbm_bytes * kv_frac), cfg)

    def max_batch(self, seq_len: int) -> int:
        return max(self.budget_bytes // (self.bytes_per_token_per_seq
                                         * max(seq_len, 1)), 0)

    def max_seq(self, batch: int) -> int:
        return max(self.budget_bytes // (self.bytes_per_token_per_seq
                                         * max(batch, 1)), 0)

    def fits(self, batch: int, seq_len: int) -> bool:
        return (batch * seq_len * self.bytes_per_token_per_seq
                <= self.budget_bytes)

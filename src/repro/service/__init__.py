"""Tuning-as-a-service: the Sapphire workflow as a persistent daemon.

Layer map (each module's docstring has the details):

* :mod:`repro.service.cache`    — cross-session probe cache
* :mod:`repro.service.pool`     — shared worker pool + per-session views
* :mod:`repro.service.shardlog` — sharded EvalDB + session namespaces
* :mod:`repro.service.session`  — one Controller+strategy conversation
* :mod:`repro.service.server`   — the daemon object (workloads, sessions)
* :mod:`repro.service.wire`     — HTTP/JSON surface (stdlib http.server)
* :mod:`repro.service.client`   — thin urllib client

``python -m repro.service`` runs the daemon.
"""

from repro.service.cache import ProbeCache, probe_key
from repro.service.client import RemoteSession, TuningClient, \
    TuningServiceError
from repro.service.pool import PoolView, SharedEvaluationPool, WorkloadPool
from repro.service.server import TuningServer, WorkloadSpec, default_catalog
from repro.service.session import SessionClosed, TuningSession
from repro.service.shardlog import SessionDB, ShardedEvalLog
from repro.service.wire import (make_wire_server, serve_background,
                                space_from_json, space_to_json)

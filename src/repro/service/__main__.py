"""``python -m repro.service`` — run the tuning daemon.

Example::

    python -m repro.service --port 8421 --db-root /tmp/tuning \\
        --workers 8 --shards 4

Then, from any client::

    curl -s localhost:8421/v1/workloads
    curl -s -X POST localhost:8421/v1/sessions \\
        -d '{"workload": "yi-6b:train_4k", "budget": 16, "seed": 3}'
"""

from __future__ import annotations

import argparse

from repro.service.server import TuningServer, default_catalog
from repro.service.wire import make_wire_server


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="Sapphire tuning daemon: sessions over a shared "
                    "evaluation pool")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8421)
    p.add_argument("--db-root", default=None,
                   help="directory for the sharded evaluation log "
                        "(default: in-memory)")
    p.add_argument("--workers", type=int, default=4,
                   help="evaluation worker threads in the shared pool")
    p.add_argument("--shards", type=int, default=4,
                   help="JSONL shards in the evaluation log")
    p.add_argument("--cache", type=int, default=4096,
                   help="probe-cache capacity (completed results)")
    p.add_argument("--workloads", nargs="*", default=None,
                   help="restrict the hosted catalog to these names")
    args = p.parse_args(argv)

    catalog = default_catalog()
    if args.workloads:
        missing = [w for w in args.workloads if w not in catalog]
        if missing:
            p.error(f"unknown workloads {missing}; "
                    f"catalog: {sorted(catalog)}")
        catalog = {w: catalog[w] for w in args.workloads}

    tuning = TuningServer(catalog, db_root=args.db_root,
                          n_shards=args.shards, max_workers=args.workers,
                          cache_capacity=args.cache)
    httpd = make_wire_server(tuning, args.host, args.port)
    host, port = httpd.server_address[:2]
    print(f"tuning daemon on http://{host}:{port} "
          f"({len(catalog)} workloads, {args.workers} workers, "
          f"db={'memory' if not args.db_root else args.db_root})")
    try:
        httpd.serve_forever()
    except KeyboardInterrupt:
        print("shutting down")
    finally:
        httpd.shutdown()
        tuning.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Cross-session probe cache — BestConfig's shared-service payoff.

When many tuning sessions probe the same workload, popular measurements
repeat: every client's initial design covers the same region, and clients
created from the same recipe (same strategy, same seed — the "recommended
run" a service hands out) ask for *identical* probes.  PR 7's seeded-probe
contract makes those repeats cacheable bit-exactly: a ``(config, fidelity,
seed, workload)`` quadruple fully determines the measurement's noise draw,
so handing one client another client's result is indistinguishable from
re-running the benchmark.

:class:`ProbeCache` deduplicates both *completed* probes (an LRU of
results) and *in-flight* ones (a waiter list per key: the second request
for a probe that is still running attaches to the first instead of
submitting again).  Unseeded requests bypass the cache entirely — without
a pinned noise stream two "identical" probes are different draws and
sharing one would silently halve the evidence.

The cache stores :class:`~repro.core.service.EvalResult` objects from the
*pool's* tickets; the pool re-tickets them per consumer on delivery, so a
cached hit carries the requesting session's own request (its tag, its
uid), only the measurement payload is shared.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

from repro.core.service import EvalRequest, EvalResult

Waiter = Any                        # opaque consumer token owned by the pool


def _norm(v):
    """Normalize config values for keying: numpy scalars hash/compare
    equal to their Python counterparts, but keys should not depend on
    which side produced the config."""
    item = getattr(v, "item", None)
    return item() if item is not None else v


def probe_key(request: EvalRequest, space=None) -> Optional[Tuple]:
    """Identity of a measurement, or ``None`` when it has no identity.

    A probe without a seed is a fresh noise draw every time — never
    cacheable.  ``n_repeats`` participates because a replicating service
    fans a request into that many sub-measurements (a 2-repeat pooled
    mean is not a 1-repeat value).

    With the workload's ``space``, the config is keyed *projected*:
    :meth:`~repro.core.space.Space.project` normalizes it (clipping,
    gating pins, constraint repair), then knobs that cannot affect the
    measurement — ``inert`` decoys, and knobs whose gate selector holds
    them at an ignored default — are dropped from the key.  Two sessions
    probing configs that differ only in a telemetry knob then share one
    measurement.  The shared result is *semantically* identical, not
    bit-identical: a seeded backend that hashes the full config into its
    noise stream would have drawn differently for each variant — but
    both draws come from the same distribution, which is exactly the
    equivalence the cache trades on (ROADMAP service rung (d))."""
    if request.seed is None:
        return None
    cfg = request.config
    if space is not None:
        cfg = space.project(cfg)
        drop = set()
        for k in space.knobs:
            if k.inert:
                drop.add(k.name)
            elif k.gated_by is not None:
                sel, enabling = k.gated_by
                if cfg.get(sel) not in enabling:
                    drop.add(k.name)     # pinned to default by project()
        items = ((n, v) for n, v in cfg.items() if n not in drop)
    else:
        items = cfg.items()
    return (request.workload, request.fidelity, int(request.seed),
            request.n_repeats,
            tuple(sorted((k, _norm(v)) for k, v in items)))


class ProbeCache:
    """Thread-safe completed-LRU + in-flight waiter registry.

    The lookup/settle pair is atomic per key: a concurrent lookup either
    sees the completed result, or joins the in-flight waiter list, or
    becomes the one registered owner that must actually evaluate — there
    is no window where two owners race the same key.
    """

    def __init__(self, capacity: int = 4096):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._completed: "OrderedDict[Tuple, EvalResult]" = OrderedDict()
        self._inflight: Dict[Tuple, List[Waiter]] = {}
        self.stats: Dict[str, int] = {
            "requests": 0, "hits": 0, "hits_completed": 0,
            "hits_inflight": 0, "misses": 0, "uncached": 0,
            "evictions": 0}

    def lookup(self, key: Optional[Tuple],
               waiter: Waiter) -> Tuple[str, Optional[EvalResult]]:
        """One atomic cache decision for one request.

        Returns ``("hit", result)`` — serve the stored result now;
        ``("wait", None)`` — *waiter* was attached to the in-flight probe
        and will be delivered at :meth:`settle`; ``("miss", None)`` — the
        caller owns the key and must evaluate, then settle;
        ``("uncached", None)`` — unseeded request, evaluate privately.
        """
        with self._lock:
            self.stats["requests"] += 1
            if key is None:
                self.stats["uncached"] += 1
                return "uncached", None
            res = self._completed.get(key)
            if res is not None:
                self._completed.move_to_end(key)
                self.stats["hits"] += 1
                self.stats["hits_completed"] += 1
                return "hit", res
            waiters = self._inflight.get(key)
            if waiters is not None:
                waiters.append(waiter)
                self.stats["hits"] += 1
                self.stats["hits_inflight"] += 1
                return "wait", None
            self._inflight[key] = []
            self.stats["misses"] += 1
            return "miss", None

    def settle(self, key: Tuple, result: EvalResult) -> List[Waiter]:
        """The owner's evaluation landed: release the key's waiters.

        Only *ok* results are stored for future lookups — a failed probe
        is delivered to whoever already waits on it (they asked for this
        measurement and this is its outcome), but the next request for
        the same key re-evaluates rather than replaying an error that may
        have been transient (pool shutdown races, resource pressure).
        """
        with self._lock:
            waiters = self._inflight.pop(key, [])
            if result.ok:
                self._completed[key] = result
                while len(self._completed) > self.capacity:
                    self._completed.popitem(last=False)
                    self.stats["evictions"] += 1
            return waiters

    def forget(self, key: Tuple) -> List[Waiter]:
        """Drop an in-flight registration without storing anything (the
        owner's submit failed before reaching the pool)."""
        with self._lock:
            return self._inflight.pop(key, [])

    @property
    def hit_rate(self) -> float:
        with self._lock:
            return self.stats["hits"] / max(self.stats["requests"], 1)

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {**self.stats,
                    "completed": len(self._completed),
                    "inflight": len(self._inflight),
                    "hit_rate": (self.stats["hits"]
                                 / max(self.stats["requests"], 1))}

    def __len__(self) -> int:
        with self._lock:
            return len(self._completed)

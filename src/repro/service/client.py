"""Thin HTTP client for the tuning daemon (urllib only).

:class:`TuningClient` speaks :mod:`repro.service.wire`'s JSON surface;
:class:`RemoteSession` mirrors the session verbs so remote code reads
like local ask/tell::

    client = TuningClient("http://127.0.0.1:8421")
    sess = client.create_session("yi-6b:train_4k", budget=16, seed=3)
    for _ in range(4):
        configs = sess.ask()
        sess.tell(configs, [my_benchmark(c) for c in configs])
    best_cfg, best_val = sess.best()

or hands the whole drive to the server (the shared-pool path that
cache-shares probes with every other user of the workload)::

    result = sess.run()          # blocks; returns best + full trace

Errors come back as :class:`TuningServiceError` carrying the HTTP
status and the server's message.
"""

from __future__ import annotations

import json
import urllib.error
import urllib.request
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.space import Space
from repro.service.wire import space_from_json


class TuningServiceError(RuntimeError):
    def __init__(self, status: int, message: str):
        super().__init__(f"[{status}] {message}")
        self.status = status
        self.message = message


class TuningClient:
    def __init__(self, base_url: str, timeout: float = 600.0):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout

    def _call(self, method: str, path: str,
              payload: Optional[dict] = None) -> dict:
        data = None
        headers = {"Accept": "application/json"}
        if method == "POST":
            data = json.dumps(payload or {}).encode()
            headers["Content-Type"] = "application/json"
        req = urllib.request.Request(self.base_url + path, data=data,
                                     headers=headers, method=method)
        try:
            with urllib.request.urlopen(req, timeout=self.timeout) as resp:
                return json.loads(resp.read() or b"{}")
        except urllib.error.HTTPError as e:
            try:
                msg = json.loads(e.read() or b"{}").get("error", str(e))
            except json.JSONDecodeError:
                msg = str(e)
            raise TuningServiceError(e.code, msg) from None

    # -- daemon-level --------------------------------------------------------

    def health(self) -> dict:
        return self._call("GET", "/v1/health")

    def workloads(self) -> List[dict]:
        return self._call("GET", "/v1/workloads")["workloads"]

    def stats(self) -> dict:
        return self._call("GET", "/v1/stats")

    def sessions(self) -> List[dict]:
        return self._call("GET", "/v1/sessions")["sessions"]

    def create_session(self, workload: str, **kwargs) -> "RemoteSession":
        """Create a session; kwargs pass straight to the wire's
        create-session fields.  Two that matter for warm starts:
        ``transfer_from`` (``True`` or a spec dict) makes a
        ``strategy="transfer_bo"`` session mine the daemon's sharded log
        for sibling-workload evidence, and ``resume="s0007"`` reopens an
        idle-evicted session from its server-side snapshot."""
        out = self._call("POST", "/v1/sessions",
                         {"workload": workload, **kwargs})
        return RemoteSession(self, out["session"], out["workload"],
                             space_from_json(out["space"]))


class RemoteSession:
    """Client-side handle; the strategy state lives on the server."""

    def __init__(self, client: TuningClient, session_id: str,
                 workload: str, space: Space):
        self.client = client
        self.session_id = session_id
        self.workload = workload
        self.space = space          # decoded: validate configs locally

    def _call(self, method: str, verb: str,
              payload: Optional[dict] = None) -> dict:
        return self.client._call(
            method, f"/v1/sessions/{self.session_id}/{verb}", payload)

    def ask(self, n: Optional[int] = None) -> List[Dict]:
        payload = {} if n is None else {"n": n}
        return self._call("POST", "ask", payload)["configs"]

    def tell(self, configs: Sequence[Dict], values: Sequence[float],
             variances: Optional[Sequence[float]] = None) -> int:
        payload = {"configs": list(configs),
                   "values": [float(v) for v in values]}
        if variances is not None:
            payload["variances"] = [float(v) for v in variances]
        return self._call("POST", "tell", payload)["told"]

    def run(self, budget: Optional[int] = None,
            batch_size: Optional[int] = None,
            fidelity: Optional[str] = None) -> dict:
        payload = {k: v for k, v in (("budget", budget),
                                     ("batch_size", batch_size),
                                     ("fidelity", fidelity))
                   if v is not None}
        return self._call("POST", "run", payload)

    def best(self) -> Tuple[Dict, float]:
        out = self._call("GET", "best")
        return out["config"], out["value"]

    def history(self, limit: Optional[int] = None) -> List[dict]:
        verb = "history" if limit is None else f"history?limit={limit}"
        return self._call("GET", verb)["records"]

    def state(self) -> dict:
        return self._call("GET", "state")["state"]

    def close(self) -> None:
        self._call("POST", "close")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        try:
            self.close()
        except TuningServiceError:
            pass                    # already closed server-side

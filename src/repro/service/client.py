"""Thin HTTP client for the tuning daemon (urllib only).

:class:`TuningClient` speaks :mod:`repro.service.wire`'s JSON surface;
:class:`RemoteSession` mirrors the session verbs so remote code reads
like local ask/tell::

    client = TuningClient("http://127.0.0.1:8421")
    sess = client.create_session("yi-6b:train_4k", budget=16, seed=3)
    for _ in range(4):
        configs = sess.ask()
        sess.tell(configs, [my_benchmark(c) for c in configs])
    best_cfg, best_val = sess.best()

or hands the whole drive to the server (the shared-pool path that
cache-shares probes with every other user of the workload)::

    result = sess.run()          # blocks; returns best + full trace

Errors come back as :class:`TuningServiceError` carrying the HTTP
status and the server's message.
"""

from __future__ import annotations

import json
import time
import urllib.error
import urllib.request
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.space import Space
from repro.service.wire import space_from_json


class TuningServiceError(RuntimeError):
    """``status`` is the HTTP status the server replied with, or 0 for a
    transport-level failure (connection refused/reset, timeout) where no
    server reply exists — for a non-idempotent verb that means the server
    *may or may not* have applied the request."""

    def __init__(self, status: int, message: str):
        super().__init__(f"[{status}] {message}")
        self.status = status
        self.message = message


# verbs safe to resend on a transport failure: every GET (pure reads)
# plus POST ask — a lost ask response leaves at most an untold batch
# behind, which the strategy's budget accounting already tolerates.
# tell / create-session / run / close are NOT safe: resending a tell the
# server already applied double-counts observations, and a second
# create-session opens a second session.
def _idempotent(method: str, path: str) -> bool:
    return method == "GET" or path.endswith("/ask")


class TuningClient:
    """``retries``/``retry_backoff_s`` bound the transport-retry loop on
    idempotent verbs (see :func:`_idempotent`): ``retries`` is the number
    of *re*-sends after the first attempt, each preceded by an
    exponentially growing ``retry_backoff_s * 2**i`` sleep.  Server-side
    errors (any HTTP reply, 4xx/5xx) are never retried — the server
    spoke; transport failures on non-idempotent verbs raise immediately
    with status 0 and a message saying the outcome is unknown."""

    def __init__(self, base_url: str, timeout: float = 600.0,
                 retries: int = 3, retry_backoff_s: float = 0.2):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout
        self.retries = retries
        self.retry_backoff_s = retry_backoff_s

    def _call(self, method: str, path: str,
              payload: Optional[dict] = None) -> dict:
        data = None
        headers = {"Accept": "application/json"}
        if method == "POST":
            data = json.dumps(payload or {}).encode()
            headers["Content-Type"] = "application/json"
        attempts = 1 + (self.retries if _idempotent(method, path) else 0)
        for attempt in range(attempts):
            req = urllib.request.Request(self.base_url + path, data=data,
                                         headers=headers, method=method)
            try:
                with urllib.request.urlopen(req,
                                            timeout=self.timeout) as resp:
                    return json.loads(resp.read() or b"{}")
            except urllib.error.HTTPError as e:
                # the server replied: this is a service error, never a
                # transport flake — no retry regardless of verb.  (Must
                # precede URLError: HTTPError subclasses it.)
                try:
                    msg = json.loads(e.read() or b"{}").get("error", str(e))
                except json.JSONDecodeError:
                    msg = str(e)
                raise TuningServiceError(e.code, msg) from None
            except (urllib.error.URLError, ConnectionError,
                    TimeoutError) as e:
                reason = getattr(e, "reason", None) or e
                if attempt + 1 < attempts:
                    time.sleep(self.retry_backoff_s * 2.0 ** attempt)
                    continue
                if not _idempotent(method, path):
                    raise TuningServiceError(
                        0, f"transport failure on non-idempotent "
                        f"{method} {path} ({reason!r}): the server may or "
                        "may not have applied this request — inspect "
                        "session state before resending") from e
                raise TuningServiceError(
                    0, f"transport failure on {method} {path} after "
                    f"{attempts} attempts ({reason!r})") from e

    # -- daemon-level --------------------------------------------------------

    def health(self) -> dict:
        return self._call("GET", "/v1/health")

    def workloads(self) -> List[dict]:
        return self._call("GET", "/v1/workloads")["workloads"]

    def stats(self) -> dict:
        return self._call("GET", "/v1/stats")

    def sessions(self) -> List[dict]:
        return self._call("GET", "/v1/sessions")["sessions"]

    def create_session(self, workload: str, **kwargs) -> "RemoteSession":
        """Create a session; kwargs pass straight to the wire's
        create-session fields.  Two that matter for warm starts:
        ``transfer_from`` (``True`` or a spec dict) makes a
        ``strategy="transfer_bo"`` session mine the daemon's sharded log
        for sibling-workload evidence, and ``resume="s0007"`` reopens an
        idle-evicted session from its server-side snapshot."""
        out = self._call("POST", "/v1/sessions",
                         {"workload": workload, **kwargs})
        return RemoteSession(self, out["session"], out["workload"],
                             space_from_json(out["space"]))


class RemoteSession:
    """Client-side handle; the strategy state lives on the server."""

    def __init__(self, client: TuningClient, session_id: str,
                 workload: str, space: Space):
        self.client = client
        self.session_id = session_id
        self.workload = workload
        self.space = space          # decoded: validate configs locally

    def _call(self, method: str, verb: str,
              payload: Optional[dict] = None) -> dict:
        return self.client._call(
            method, f"/v1/sessions/{self.session_id}/{verb}", payload)

    def ask(self, n: Optional[int] = None) -> List[Dict]:
        payload = {} if n is None else {"n": n}
        return self._call("POST", "ask", payload)["configs"]

    def tell(self, configs: Sequence[Dict], values: Sequence[float],
             variances: Optional[Sequence[float]] = None) -> int:
        payload = {"configs": list(configs),
                   "values": [float(v) for v in values]}
        if variances is not None:
            payload["variances"] = [float(v) for v in variances]
        return self._call("POST", "tell", payload)["told"]

    def run(self, budget: Optional[int] = None,
            batch_size: Optional[int] = None,
            fidelity: Optional[str] = None) -> dict:
        payload = {k: v for k, v in (("budget", budget),
                                     ("batch_size", batch_size),
                                     ("fidelity", fidelity))
                   if v is not None}
        return self._call("POST", "run", payload)

    def best(self) -> Tuple[Dict, float]:
        out = self._call("GET", "best")
        return out["config"], out["value"]

    def history(self, limit: Optional[int] = None) -> List[dict]:
        verb = "history" if limit is None else f"history?limit={limit}"
        return self._call("GET", verb)["records"]

    def state(self) -> dict:
        return self._call("GET", "state")["state"]

    def close(self) -> None:
        self._call("POST", "close")

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        try:
            self.close()
        except TuningServiceError:
            pass                    # already closed server-side

"""The process-wide evaluation pool every tuning session shares.

The dCache Sapphire deployment splits an always-on driver from background
benchmark workers; here the split is :class:`SharedEvaluationPool` (one
per daemon) fanning requests from any number of per-session
:class:`PoolView` facades into one
:class:`~repro.core.service.WorkerPoolEvaluationService`.  Three layers:

* :class:`WorkloadPool` — the worker pool, with the backend resolved per
  request by its *workload* field (the core pool routes on fidelity only;
  a daemon hosts many workloads behind one thread pool).
* :class:`PoolView` — what a session's Controller sees: a full
  :class:`~repro.core.service.EvaluationService` whose completions are
  released in **submission order** (a reorder buffer over the pool's
  out-of-order workers).  In-order release is what makes a server-side
  session's trace bit-identical to a local run on an immediate service:
  same tell order, same GP posterior, same next ask.
* :class:`SharedEvaluationPool` — the multiplexer: routes every view
  submission through the cross-session :class:`~repro.service.cache.
  ProbeCache` (completed hits answer inline, in-flight hits attach as
  waiters, misses go to the workers) and re-tickets shared results onto
  each waiting view's own request.
"""

from __future__ import annotations

import threading
import time
from dataclasses import replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.resilience import (CircuitBreaker, TransientEvalError,
                                   classify_failure)
from repro.core.service import (EvalRequest, EvalResult, EvalTicket,
                                WorkerPoolEvaluationService, _failed,
                                _result, _score_one, _ServiceBase)
from repro.service.cache import ProbeCache, probe_key


class WorkloadPool(WorkerPoolEvaluationService):
    """Worker pool whose backend table is keyed by *workload*, not
    fidelity: one daemon thread pool serves every hosted workload, and a
    request for an unregistered workload completes as a failed result
    (the service contract — never an exception, never an orphan)."""

    def _work(self, ticket: EvalTicket):
        t0 = time.monotonic()
        try:
            backend = self._workload_backend(ticket.request)
            scored = _score_one(backend, ticket.request.config,
                                ticket.request)
        except Exception as e:
            scored = _failed(e)
        self._complete(_result(ticket, scored, time.monotonic() - t0))

    def _workload_backend(self, request: EvalRequest):
        if self._any is not None:
            return self._any
        try:
            return self.backends[request.workload]
        except KeyError:
            raise KeyError(
                f"no backend for workload {request.workload!r}; "
                f"hosted: {tuple(sorted(self.backends))}") from None

    def add_backend(self, workload: str, backend) -> None:
        self.backends[workload] = backend


class PoolView(_ServiceBase):
    """A session's private window onto the shared pool.

    ``submit`` hands the tickets to the pool; the pool delivers each
    result back through :meth:`_deliver` (from a worker thread, from
    another session's completion, or inline on a cache hit), re-ticketed
    onto this view's own request.  With ``ordered=True`` (the default) a
    result is *released* — made visible to poll/gather — only once every
    earlier submission of this view has been released, so the session's
    driver observes the completion order an immediate service would have
    produced, regardless of worker scheduling or which session's probe
    satisfied the cache."""

    def __init__(self, pool: "SharedEvaluationPool", ordered: bool = True):
        super().__init__()
        self._pool = pool
        self.ordered = ordered
        self._tickets: Dict[int, EvalTicket] = {}
        self._held: Dict[int, EvalResult] = {}
        self._next_release = 0

    def submit(self, requests: Sequence[EvalRequest]) -> List[EvalTicket]:
        tickets = self._issue(requests)
        with self._cv:
            for t in tickets:
                self._tickets[t.uid] = t
        self._pool.dispatch(self, tickets)
        return tickets

    def _deliver(self, uid: int, result: EvalResult) -> None:
        # _cv is an RLock-backed Condition: _complete (and a sink that
        # re-enters submit -> dispatch -> an inline cache hit back into
        # _deliver) may re-acquire it on this thread.  The lock must span
        # the release loop so two workers' deliveries cannot interleave
        # their in-order releases.
        with self._cv:
            mine = self._tickets.pop(uid, None)
            if mine is None:
                return          # duplicate delivery: this uid settled once
            res = replace(result, ticket=mine)
            if not self.ordered:
                self._complete(res)
                return
            self._held[uid] = res
            while self._next_release in self._held:
                nxt = self._next_release
                self._next_release += 1
                self._complete(self._held.pop(nxt))

    def close(self):                # the pool outlives its views
        pass


class SharedEvaluationPool:
    """Multiplexes many :class:`PoolView` consumers over one
    :class:`WorkloadPool` behind one :class:`ProbeCache`.

    The pool owns the only sink on the inner service, so the inner pool
    must not be polled directly while attached.  Completions are mapped
    back to the consumers that asked: the cache-registered owner plus
    every waiter that piled onto the same probe key while it ran."""

    def __init__(self, backends=None, max_workers: int = 4,
                 cache_capacity: int = 4096,
                 deadline_s: Optional[float] = None,
                 breaker_threshold: int = 5, breaker_reset_s: float = 30.0,
                 breaker_clock=time.monotonic):
        self.inner = WorkloadPool(dict(backends or {}),
                                  max_workers=max_workers,
                                  deadline_s=deadline_s)
        self.cache = ProbeCache(cache_capacity)
        # per-workload circuit breakers: a backend tripping
        # breaker_threshold CONSECUTIVE transient failures (worker
        # deaths, probe timeouts — permanent failures are config
        # verdicts and don't count) sheds subsequent load as inline
        # failed-transient completions instead of burning workers and
        # budget against a downed backend; it half-opens after
        # breaker_reset_s and one successful trial closes it again.
        # breaker_threshold <= 0 disables breaking entirely.
        self.breaker_threshold = breaker_threshold
        self.breaker_reset_s = breaker_reset_s
        self._breaker_clock = breaker_clock
        self._breakers: Dict[str, CircuitBreaker] = {}
        self.shed = 0                   # requests refused by open breakers
        # workload -> Space: when registered, probe keys are *projected*
        # (inert/gated knobs dropped) so near-identical probes dedupe
        self.spaces: Dict[str, object] = {}
        self._lock = threading.Lock()
        # inner uid -> (key-or-None, owner view, owner view-uid)
        self._meta: Dict[int, Tuple[Optional[Tuple], PoolView, int]] = {}
        self.inner._sink = self._on_result
        self._views = 0

    # -- consumer side ------------------------------------------------------

    def view(self, ordered: bool = True) -> PoolView:
        with self._lock:
            self._views += 1
        return PoolView(self, ordered=ordered)

    def add_backend(self, workload: str, backend) -> None:
        self.inner.add_backend(workload, backend)

    def register_space(self, workload: str, space) -> None:
        """Declare a workload's search space: from now on its probe keys
        are projected through it (:func:`~repro.service.cache.probe_key`
        with ``space``), so probes differing only in inert or gated-off
        knobs share one cache entry."""
        with self._lock:
            self.spaces[workload] = space

    @property
    def workloads(self) -> Tuple[str, ...]:
        return tuple(sorted(self.inner.backends))

    def dispatch(self, view: PoolView,
                 tickets: Sequence[EvalTicket]) -> None:
        """Route one view's submissions: cache hits answer inline,
        in-flight hits attach as waiters, everything else goes to the
        workers under this pool's own tickets."""
        hits: List[Tuple[int, EvalResult]] = []
        to_submit: List[Tuple[EvalRequest, Optional[Tuple], int]] = []
        for t in tickets:
            # breaker check BEFORE the cache lookup: a refused probe must
            # never register as the cache's in-flight owner (waiters piling
            # onto a probe nobody will run would wedge until eviction)
            if not self._admit(t.request.workload):
                with self._lock:
                    self.shed += 1
                err = TransientEvalError(
                    f"circuit breaker open for workload "
                    f"{t.request.workload!r}: backend shedding load after "
                    "consecutive transient failures")
                hits.append((t.uid, replace(
                    _result(t, _failed(err), 0.0), error_kind="transient")))
                continue
            key = probe_key(t.request, self.spaces.get(t.request.workload))
            verdict, res = self.cache.lookup(key, (view, t.uid))
            if verdict == "hit":
                hits.append((t.uid, res))
            elif verdict == "wait":
                pass                        # delivered at settle time
            else:                           # miss | uncached: we evaluate
                to_submit.append((t.request, key, t.uid))
        if to_submit:
            inner_tickets = self.inner._issue([r for r, _, _ in to_submit])
            with self._lock:
                for it, (_, key, vuid) in zip(inner_tickets, to_submit):
                    self._meta[it.uid] = (key, view, vuid)
            self.inner._dispatch(inner_tickets)
        for vuid, res in hits:
            view._deliver(vuid, res)

    # -- circuit breaking ---------------------------------------------------

    def _breaker(self, workload: str) -> CircuitBreaker:
        b = self._breakers.get(workload)
        if b is None:
            b = self._breakers[workload] = CircuitBreaker(
                threshold=self.breaker_threshold,
                reset_s=self.breaker_reset_s, clock=self._breaker_clock)
        return b

    def _admit(self, workload: str) -> bool:
        if self.breaker_threshold <= 0:
            return True
        with self._lock:
            return self._breaker(workload).allow()

    def _record_outcome(self, result: EvalResult) -> None:
        if self.breaker_threshold <= 0:
            return
        workload = result.request.workload
        with self._lock:
            b = self._breaker(workload)
            if result.ok or classify_failure(result) != "transient":
                # ok — or a permanent failure, which is a verdict on the
                # config, not evidence the backend is down
                b.record_success()
            else:
                b.record_failure()

    # -- inner-pool sink ----------------------------------------------------

    def _on_result(self, result: EvalResult) -> None:
        with self._lock:
            meta = self._meta.pop(result.ticket.uid, None)
        if meta is None:                    # racing close(); drop
            return
        self._record_outcome(result)
        key, owner, owner_uid = meta
        deliveries: List[Tuple[PoolView, int]] = [(owner, owner_uid)]
        if key is not None:
            deliveries += self.cache.settle(key, result)
        # outside every pool/cache lock: delivery may re-enter submit
        for v, uid in deliveries:
            v._deliver(uid, result)

    # -- lifecycle ----------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        with self._lock:
            breakers = {wl: b.state for wl, b in self._breakers.items()}
            shed = self.shed
        return {"cache": self.cache.snapshot(),
                "workloads": list(self.workloads),
                "backend_calls": sum(
                    int(getattr(b, "calls", 0))
                    for b in self.inner.backends.values()),
                "inner_in_flight": self.inner.in_flight,
                "max_workers": self.inner.max_workers,
                "timed_out": self.inner.timed_out,
                "breakers": breakers,
                "shed": shed,
                "views": self._views}

    def close(self):
        self.inner.close()
        self.inner._sink = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

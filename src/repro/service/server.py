"""The tuning daemon: many sessions, one evaluation pool, one log.

:class:`TuningServer` is the Sapphire workflow as a persistent service
(the ROADMAP's "millions of users" direction, BestConfig's shared
deployment): clients create :class:`~repro.service.session.
TuningSession`\\ s against named *workloads* from the server's registry,
and every session's probes multiplex through one process-wide
:class:`~repro.service.pool.SharedEvaluationPool` — so concurrent users
of a popular workload share a worker pool, a probe cache, and (behind
per-session namespaces) one sharded evaluation log.

The server itself is transport-free; :mod:`repro.service.wire` puts the
HTTP/JSON surface on top and ``python -m repro.service`` runs the
daemon.  The default workload catalog exposes the repo's analytic
test-cluster cells (smoke-sized model configs — CPU-fast, seeded, and
exactly what the benchmarks drive); real deployments register their own
``(space, backend)`` pairs via :meth:`TuningServer.register_workload`.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.controller import Controller
from repro.core.replication import ReplicationPolicy
from repro.core.space import Space
from repro.core.strategy import (BOConfig, GAConfig, SAConfig, make_strategy,
                                 strategy_names)
from repro.service.pool import SharedEvaluationPool
from repro.service.session import TuningSession
from repro.service.shardlog import ShardedEvalLog


@dataclass
class WorkloadSpec:
    """A hosted workload: a name plus a lazy ``(space, backend)`` build
    (lazy so the default catalog's cost models only materialize for the
    workloads clients actually tune)."""
    name: str
    build: Callable[[], Tuple[Space, object]]
    description: str = ""
    _cached: Optional[Tuple[Space, object]] = field(default=None,
                                                    repr=False)

    def materialize(self) -> Tuple[Space, object]:
        if self._cached is None:
            self._cached = self.build()
        return self._cached


def _analytic_spec(arch: str, shape: str,
                   noise_sigma: float = 0.025) -> WorkloadSpec:
    name = f"{arch}:{shape}"

    def build():
        from repro.configs import get_smoke_config
        from repro.core.costmodel import SINGLE_POD
        from repro.core.evaluators import AnalyticEvaluator
        from repro.core.knobs import clean_space
        from repro.models.config import SHAPES_BY_NAME
        cfg = get_smoke_config(arch)
        cell = SHAPES_BY_NAME[shape]
        space, _, _ = clean_space(cfg, cell, SINGLE_POD)
        ev = AnalyticEvaluator(cfg, cell, SINGLE_POD,
                               noise_sigma=noise_sigma, history_cap=256)
        return space, ev

    return WorkloadSpec(name, build,
                        f"analytic test cluster, {arch} @ {shape}")


def default_catalog() -> Dict[str, WorkloadSpec]:
    specs = [_analytic_spec(arch, shape)
             for arch in ("yi-6b", "qwen1.5-4b", "xlstm-1.3b")
             for shape in ("train_4k", "decode_32k")]
    return {s.name: s for s in specs}


_STRATEGY_CFG = {"bo": BOConfig, "sa": SAConfig, "ga": GAConfig}


def _strategy_kwargs(name: str, kwargs: Optional[dict]) -> dict:
    """Wire-side strategies arrive with a plain-dict ``cfg``; rebuild the
    registry's dataclass so unknown fields fail loudly here, not deep in
    the strategy."""
    kwargs = dict(kwargs or {})
    cfg = kwargs.get("cfg")
    if isinstance(cfg, dict):
        cls = _STRATEGY_CFG.get(name)
        if cls is None:
            raise ValueError(f"strategy {name!r} takes no cfg dict")
        kwargs["cfg"] = cls(**cfg)
    return kwargs


class TuningServer:
    """The daemon object: workload registry + session table + shared
    pool + sharded log.  Thread-safe — the HTTP layer serves each request
    on its own thread, and the in-process benchmark drives it from N
    client threads directly."""

    def __init__(self, workloads: Optional[Dict[str, WorkloadSpec]] = None,
                 db_root: Optional[str] = None, n_shards: int = 4,
                 max_workers: int = 4, cache_capacity: int = 4096):
        self.registry: Dict[str, WorkloadSpec] = (
            dict(workloads) if workloads is not None else default_catalog())
        self.pool = SharedEvaluationPool(max_workers=max_workers,
                                         cache_capacity=cache_capacity)
        self.log = ShardedEvalLog(db_root, n_shards=n_shards)
        self.sessions: Dict[str, TuningSession] = {}
        self._lock = threading.RLock()
        self._counter = 0
        self.created_total = 0

    # -- workloads -----------------------------------------------------------

    def register_workload(self, name: str, space: Space, backend,
                          description: str = "") -> None:
        with self._lock:
            self.registry[name] = WorkloadSpec(
                name, lambda: (space, backend), description,
                _cached=(space, backend))

    def workloads(self) -> List[dict]:
        with self._lock:
            return [{"name": s.name, "description": s.description}
                    for s in self.registry.values()]

    def _resolve_workload(self, name: str) -> Tuple[Space, object]:
        with self._lock:
            try:
                spec = self.registry[name]
            except KeyError:
                raise KeyError(
                    f"unknown workload {name!r}; hosted: "
                    f"{tuple(sorted(self.registry))}") from None
            space, backend = spec.materialize()
            if name not in self.pool.inner.backends:
                self.pool.add_backend(name, backend)
            return space, backend

    # -- sessions ------------------------------------------------------------

    def create_session(self, workload: str, strategy: str = "bo",
                       budget: Optional[int] = None, seed: int = 0,
                       batch_size: Optional[int] = None,
                       strategy_kwargs: Optional[dict] = None,
                       replication: Optional[dict] = None,
                       deterministic: bool = True,
                       tag: str = "",
                       state: Optional[dict] = None) -> TuningSession:
        if strategy not in strategy_names():
            raise KeyError(f"unknown strategy {strategy!r}; "
                           f"registered: {strategy_names()}")
        space, _ = self._resolve_workload(workload)
        kwargs = _strategy_kwargs(strategy, strategy_kwargs)
        strat = make_strategy(strategy, space, budget=budget, seed=seed,
                              batch_size=batch_size, **kwargs)
        if state is not None:
            load = getattr(strat, "load_state", None)
            if load is None:
                raise TypeError(f"strategy {strategy!r} cannot load_state")
            load(state)
        policy = None
        if replication:
            policy = ReplicationPolicy(**replication)
        with self._lock:
            self._counter += 1
            self.created_total += 1
            sid = f"s{self._counter:04d}"
            view = self.pool.view(ordered=deterministic)
            ctrl = Controller(view, db=self.log.namespace(sid),
                              tag=tag or strategy, workload=workload,
                              replication=policy, seed=seed)
            sess = TuningSession(sid, workload, strategy, strat, ctrl,
                                 deterministic=deterministic, budget=budget,
                                 batch_size=batch_size)
            self.sessions[sid] = sess
            return sess

    def session(self, session_id: str) -> TuningSession:
        with self._lock:
            try:
                return self.sessions[session_id]
            except KeyError:
                raise KeyError(f"no session {session_id!r}") from None

    def close_session(self, session_id: str) -> None:
        with self._lock:
            sess = self.session(session_id)
            del self.sessions[session_id]
        sess.close()

    def list_sessions(self) -> List[dict]:
        with self._lock:
            return [s.describe() for s in self.sessions.values()]

    # -- daemon-level introspection / lifecycle ------------------------------

    def stats(self) -> dict:
        with self._lock:
            open_sessions = len(self.sessions)
        return {"sessions_open": open_sessions,
                "sessions_created": self.created_total,
                "evaluations_logged": len(self.log),
                "pool": self.pool.stats()}

    def close(self):
        with self._lock:
            sessions = list(self.sessions.values())
            self.sessions.clear()
        for s in sessions:
            s.close()
        self.pool.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

"""The tuning daemon: many sessions, one evaluation pool, one log.

:class:`TuningServer` is the Sapphire workflow as a persistent service
(the ROADMAP's "millions of users" direction, BestConfig's shared
deployment): clients create :class:`~repro.service.session.
TuningSession`\\ s against named *workloads* from the server's registry,
and every session's probes multiplex through one process-wide
:class:`~repro.service.pool.SharedEvaluationPool` — so concurrent users
of a popular workload share a worker pool, a probe cache, and (behind
per-session namespaces) one sharded evaluation log.

The server itself is transport-free; :mod:`repro.service.wire` puts the
HTTP/JSON surface on top and ``python -m repro.service`` runs the
daemon.  The default workload catalog exposes the repo's analytic
test-cluster cells (smoke-sized model configs — CPU-fast, seeded, and
exactly what the benchmarks drive); real deployments register their own
``(space, backend)`` pairs via :meth:`TuningServer.register_workload`.
"""

from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.core.controller import Controller
from repro.core.replication import ReplicationPolicy
from repro.core.space import Space
from repro.core.strategy import (BOConfig, GAConfig, SAConfig, make_strategy,
                                 strategy_names)
from repro.service.pool import SharedEvaluationPool
from repro.service.session import TuningSession
from repro.service.shardlog import ShardedEvalLog
from repro.transfer import build_corpus   # registers "transfer_bo" too


@dataclass
class WorkloadSpec:
    """A hosted workload: a name plus a lazy ``(space, backend)`` build
    (lazy so the default catalog's cost models only materialize for the
    workloads clients actually tune)."""
    name: str
    build: Callable[[], Tuple[Space, object]]
    description: str = ""
    _cached: Optional[Tuple[Space, object]] = field(default=None,
                                                    repr=False)

    def materialize(self) -> Tuple[Space, object]:
        if self._cached is None:
            self._cached = self.build()
        return self._cached


def _analytic_spec(arch: str, shape: str,
                   noise_sigma: float = 0.025) -> WorkloadSpec:
    name = f"{arch}:{shape}"

    def build():
        from repro.configs import get_smoke_config
        from repro.core.costmodel import SINGLE_POD
        from repro.core.evaluators import AnalyticEvaluator
        from repro.core.knobs import clean_space
        from repro.models.config import SHAPES_BY_NAME
        cfg = get_smoke_config(arch)
        cell = SHAPES_BY_NAME[shape]
        space, _, _ = clean_space(cfg, cell, SINGLE_POD)
        ev = AnalyticEvaluator(cfg, cell, SINGLE_POD,
                               noise_sigma=noise_sigma, history_cap=256)
        return space, ev

    return WorkloadSpec(name, build,
                        f"analytic test cluster, {arch} @ {shape}")


def default_catalog() -> Dict[str, WorkloadSpec]:
    specs = [_analytic_spec(arch, shape)
             for arch in ("yi-6b", "qwen1.5-4b", "xlstm-1.3b")
             for shape in ("train_4k", "decode_32k")]
    return {s.name: s for s in specs}


_STRATEGY_CFG = {"bo": BOConfig, "sa": SAConfig, "ga": GAConfig,
                 "transfer_bo": BOConfig}


def _strategy_kwargs(name: str, kwargs: Optional[dict]) -> dict:
    """Wire-side strategies arrive with a plain-dict ``cfg``; rebuild the
    registry's dataclass so unknown fields fail loudly here, not deep in
    the strategy."""
    kwargs = dict(kwargs or {})
    cfg = kwargs.get("cfg")
    if isinstance(cfg, dict):
        cls = _STRATEGY_CFG.get(name)
        if cls is None:
            raise ValueError(f"strategy {name!r} takes no cfg dict")
        kwargs["cfg"] = cls(**cfg)
    return kwargs


class TuningServer:
    """The daemon object: workload registry + session table + shared
    pool + sharded log.  Thread-safe — the HTTP layer serves each request
    on its own thread, and the in-process benchmark drives it from N
    client threads directly."""

    def __init__(self, workloads: Optional[Dict[str, WorkloadSpec]] = None,
                 db_root: Optional[str] = None, n_shards: int = 4,
                 max_workers: int = 4, cache_capacity: int = 4096,
                 session_ttl: Optional[float] = None):
        self.registry: Dict[str, WorkloadSpec] = (
            dict(workloads) if workloads is not None else default_catalog())
        self.pool = SharedEvaluationPool(max_workers=max_workers,
                                         cache_capacity=cache_capacity)
        self.log = ShardedEvalLog(db_root, n_shards=n_shards)
        self.sessions: Dict[str, TuningSession] = {}
        # idle-session eviction: sessions untouched for longer than
        # session_ttl seconds are snapshotted (state_dict to the log
        # root) and closed by the lazy sweep — no background thread, the
        # sweep runs on the server's own entry points
        self.session_ttl = session_ttl
        self._snapshots: Dict[str, dict] = {}
        self._lock = threading.RLock()
        # a restarted daemon must not reuse a crashed predecessor's
        # session ids: a fresh counter would hand out "s0001" again,
        # colliding with the old s0001's journal namespace (and silently
        # cross-contaminating its history).  Seed the counter past every
        # session id visible in the log's namespaces and the sessions
        # dir (snapshots + manifests).
        self._counter = self._max_existing_sid()
        self.created_total = 0
        self.evicted_total = 0

    def _max_existing_sid(self) -> int:
        import re
        top = 0
        pat = re.compile(r"^s(\d+)$")
        for ns in self.log.namespaces():
            m = pat.match(ns)
            if m:
                top = max(top, int(m.group(1)))
        d = (self.log.root / "sessions") if self.log.root is not None \
            else None
        if d is not None and d.is_dir():
            for p in d.iterdir():
                m = pat.match(p.name.split(".", 1)[0])
                if m:
                    top = max(top, int(m.group(1)))
        return top

    # -- workloads -----------------------------------------------------------

    def register_workload(self, name: str, space: Space, backend,
                          description: str = "") -> None:
        with self._lock:
            self.registry[name] = WorkloadSpec(
                name, lambda: (space, backend), description,
                _cached=(space, backend))

    def workloads(self) -> List[dict]:
        with self._lock:
            return [{"name": s.name, "description": s.description}
                    for s in self.registry.values()]

    def _resolve_workload(self, name: str) -> Tuple[Space, object]:
        with self._lock:
            try:
                spec = self.registry[name]
            except KeyError:
                raise KeyError(
                    f"unknown workload {name!r}; hosted: "
                    f"{tuple(sorted(self.registry))}") from None
            space, backend = spec.materialize()
            if name not in self.pool.inner.backends:
                self.pool.add_backend(name, backend)
                # projected probe keys: the cache dedupes probes that
                # differ only in inert / gated-off knobs of this space
                self.pool.register_space(name, space)
            return space, backend

    # -- sessions ------------------------------------------------------------

    def create_session(self, workload: str, strategy: str = "bo",
                       budget: Optional[int] = None, seed: int = 0,
                       batch_size: Optional[int] = None,
                       strategy_kwargs: Optional[dict] = None,
                       replication: Optional[dict] = None,
                       deterministic: bool = True,
                       tag: str = "",
                       state: Optional[dict] = None,
                       transfer_from: Union[None, bool, dict] = None,
                       resume: Optional[str] = None) -> TuningSession:
        self.evict_idle()
        if strategy not in strategy_names():
            raise KeyError(f"unknown strategy {strategy!r}; "
                           f"registered: {strategy_names()}")
        space, _ = self._resolve_workload(workload)
        kwargs = _strategy_kwargs(strategy, strategy_kwargs)
        if resume is not None:
            if state is not None:
                raise ValueError("create-session: pass either 'state' or "
                                 "'resume', not both")
            try:
                snap = self._load_snapshot(resume)
            except KeyError:
                # no snapshot — the daemon (or its predecessor process)
                # never evicted this session: crash-recovery path.  The
                # journal + manifest rebuild it with zero lost tells.
                return self._resume_from_journal(resume, workload, space)
            if snap["workload"] != workload:
                raise ValueError(
                    f"resume {resume!r}: snapshot belongs to workload "
                    f"{snap['workload']!r}, not {workload!r}")
            state = snap["state"]
        if transfer_from:
            kwargs["corpus"] = self._build_transfer_corpus(
                workload, space, transfer_from)
        strat = make_strategy(strategy, space, budget=budget, seed=seed,
                              batch_size=batch_size, **kwargs)
        if state is not None:
            load = getattr(strat, "load_state", None)
            if load is None:
                raise TypeError(f"strategy {strategy!r} cannot load_state")
            load(state)
        policy = None
        if replication:
            policy = ReplicationPolicy(**replication)
        with self._lock:
            self._counter += 1
            self.created_total += 1
            sid = f"s{self._counter:04d}"
            view = self.pool.view(ordered=deterministic)
            ctrl = Controller(view, db=self.log.namespace(sid),
                              tag=tag or strategy, workload=workload,
                              replication=policy, seed=seed)
            sess = TuningSession(sid, workload, strategy, strat, ctrl,
                                 deterministic=deterministic, budget=budget,
                                 batch_size=batch_size)
            self.sessions[sid] = sess
            self._write_manifest(sess, seed, strategy_kwargs, replication)
            return sess

    def _write_manifest(self, sess: TuningSession, seed: int,
                        strategy_kwargs: Optional[dict],
                        replication: Optional[dict]) -> None:
        """Journal the session's *recipe* next to the snapshots.  The
        sharded log already journals every tell (the session appends to
        its namespace before the strategy is told — journal-before-ack);
        the manifest is the missing half for crash recovery: what
        strategy, seed and budget to rebuild so the journaled rows can
        be replayed into a fresh strategy after a daemon killed mid-run
        (eviction snapshots never happened for it)."""
        d = self._snapshot_dir()
        if d is None:
            return
        man = {"session": sess.session_id, "workload": sess.workload,
               "strategy": sess.strategy_name, "budget": sess.budget,
               "seed": seed, "batch_size": sess.batch_size,
               "deterministic": sess.deterministic,
               "tag": sess.controller.tag,
               "replication": replication,
               "created_at": sess.created_at}
        if strategy_kwargs:
            try:
                json.dumps(strategy_kwargs)
                man["strategy_kwargs"] = strategy_kwargs
            except TypeError:
                pass    # in-process-only kwargs (live objects): the wire
                #         path is always JSON-safe, so nothing is lost
        (d / f"{sess.session_id}.meta.json").write_text(json.dumps(man))

    def _resume_from_journal(self, sid: str, workload: str,
                             space: Space) -> TuningSession:
        """Crash-recovery resume: rebuild the session from its manifest
        and replay every journaled tell from its log namespace.  Unlike
        a snapshot resume (which copies strategy state into a *new*
        session id), this continues the *same* session id and namespace
        — the journal is the ground truth, and subsequent tells keep
        appending to it."""
        d = self._snapshot_dir()
        p = (d / f"{sid}.meta.json") if d is not None else None
        if p is None or not p.exists():
            raise KeyError(
                f"no session snapshot {sid!r} (and no journal manifest "
                "to rebuild it from)")
        man = json.loads(p.read_text())
        if man.get("workload") != workload:
            raise ValueError(
                f"resume {sid!r}: journal belongs to workload "
                f"{man.get('workload')!r}, not {workload!r}")
        with self._lock:
            if sid in self.sessions:
                raise ValueError(f"resume {sid!r}: session is still open "
                                 "on this daemon")
        strategy = man.get("strategy", "bo")
        kwargs = _strategy_kwargs(strategy, man.get("strategy_kwargs"))
        seed = int(man.get("seed", 0))
        strat = make_strategy(strategy, space, budget=man.get("budget"),
                              seed=seed, batch_size=man.get("batch_size"),
                              **kwargs)
        ndb = self.log.namespace(sid)
        rows = [r for r in ndb.records
                if r.ok and r.value == r.value
                and r.value not in (float("inf"), float("-inf"))]
        if rows:
            Controller._teller(strat)(
                [dict(r.config) for r in rows],
                [float(r.value) for r in rows],
                [float(r.variance) for r in rows])
        policy = (ReplicationPolicy(**man["replication"])
                  if man.get("replication") else None)
        deterministic = bool(man.get("deterministic", True))
        with self._lock:
            self.created_total += 1
            view = self.pool.view(ordered=deterministic)
            ctrl = Controller(view, db=ndb,
                              tag=man.get("tag") or strategy,
                              workload=workload, replication=policy,
                              seed=seed)
            sess = TuningSession(sid, workload, strategy, strat, ctrl,
                                 deterministic=deterministic,
                                 budget=man.get("budget"),
                                 batch_size=man.get("batch_size"))
            self.sessions[sid] = sess
            return sess

    def _build_transfer_corpus(self, workload: str, space: Space,
                               spec: Union[bool, dict]):
        """``transfer_from`` corpus over the daemon's own sharded log.

        The spec (``True`` for all defaults) may narrow the donor set
        (``workloads``), extend the exclusion list (``exclude`` — the
        target workload is always excluded), and tune corpus assembly
        (``min_points``).  Donor workloads hosted in the registry get
        their spaces materialized so signature mismatches are detected
        up front rather than row by row."""
        spec = {} if spec is True else dict(spec)
        unknown = set(spec) - {"workloads", "exclude", "min_points"}
        if unknown:
            raise ValueError(f"transfer_from: unknown fields "
                             f"{sorted(unknown)}")
        exclude = set(spec.get("exclude", ())) | {workload}
        only = spec.get("workloads")
        records = self.log.records
        if only is not None:
            only = set(only)
            records = [r for r in records if r.workload in only]
        spaces: Dict[str, Space] = {}
        for wl in {r.workload for r in records if r.workload}:
            if wl in exclude or wl not in self.registry:
                continue
            try:
                spaces[wl] = self.registry[wl].materialize()[0]
            except Exception:
                pass          # undeclared: corpus falls back to row checks
        return build_corpus(space, [records], spaces=spaces,
                            exclude=sorted(exclude),
                            min_points=int(spec.get("min_points", 2)))

    # -- idle eviction + snapshots -------------------------------------------

    def _snapshot_dir(self):
        if self.log.root is None:
            return None
        d = self.log.root / "sessions"
        d.mkdir(parents=True, exist_ok=True)
        return d

    def _snapshot(self, sess: TuningSession) -> Optional[dict]:
        fn = getattr(sess.strategy, "state_dict", None)
        if fn is None:
            return None
        snap = {"session": sess.session_id, "workload": sess.workload,
                "strategy": sess.strategy_name, "state": fn(),
                "evicted_at": time.time()}
        self._snapshots[sess.session_id] = snap
        d = self._snapshot_dir()
        if d is not None:
            (d / f"{sess.session_id}.json").write_text(json.dumps(snap))
        return snap

    def _load_snapshot(self, name: str) -> dict:
        with self._lock:
            snap = self._snapshots.get(name)
        if snap is None:
            d = self._snapshot_dir()
            p = d / f"{name}.json" if d is not None else None
            if p is None or not p.exists():
                raise KeyError(f"no session snapshot {name!r}")
            snap = json.loads(p.read_text())
        return snap

    def evict_idle(self, now: Optional[float] = None) -> List[str]:
        """Close sessions idle past ``session_ttl``, each snapshotted
        first (``state_dict`` to the log root, when the strategy has
        one) so ``create_session(resume=<id>)`` can continue it.  Runs
        lazily from the server's own entry points — a daemon with no
        traffic evicts nothing, and needs to evict nothing."""
        if self.session_ttl is None:
            return []
        now = time.time() if now is None else now
        with self._lock:
            idle = [s for s in self.sessions.values()
                    if now - s.last_used > self.session_ttl]
            for s in idle:
                del self.sessions[s.session_id]
                self._snapshot(s)
                self.evicted_total += 1
        for s in idle:
            s.close()
        return [s.session_id for s in idle]

    def session(self, session_id: str) -> TuningSession:
        self.evict_idle()
        with self._lock:
            try:
                return self.sessions[session_id]
            except KeyError:
                raise KeyError(f"no session {session_id!r}") from None

    def close_session(self, session_id: str) -> None:
        with self._lock:
            sess = self.session(session_id)
            del self.sessions[session_id]
        sess.close()

    def list_sessions(self) -> List[dict]:
        self.evict_idle()
        with self._lock:
            return [s.describe() for s in self.sessions.values()]

    # -- daemon-level introspection / lifecycle ------------------------------

    def stats(self) -> dict:
        self.evict_idle()
        with self._lock:
            open_sessions = len(self.sessions)
        return {"sessions_open": open_sessions,
                "sessions_created": self.created_total,
                "sessions_evicted": self.evicted_total,
                "evaluations_logged": len(self.log),
                "pool": self.pool.stats()}

    def close(self):
        with self._lock:
            sessions = list(self.sessions.values())
            self.sessions.clear()
        for s in sessions:
            s.close()
        self.pool.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

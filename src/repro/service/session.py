"""One tuning session: a Controller + strategy pair owned by the daemon.

A :class:`TuningSession` is the unit a client rents from the server —
the Sapphire recommendation workflow as a stateful conversation.  It
wraps one registry :class:`~repro.core.strategy.SearchStrategy` and one
:class:`~repro.core.controller.Controller` whose evaluation service is a
:class:`~repro.service.pool.PoolView` onto the daemon's shared pool and
whose EvalDB is this session's namespace of the shared sharded log.

Two usage modes share the same strategy state:

* **ask/tell** — the client runs its own benchmarks: ``ask`` proposes
  probe configs, ``tell`` feeds measured values back (recorded into the
  session's namespace with the ``"client"`` fidelity so server-side and
  client-side measurements stay distinguishable in the log);
* **run** — the server drives :meth:`~repro.core.controller.Controller.
  run_async` to completion against the shared pool.  With
  ``deterministic=True`` (the default) the loop runs at the synchronous
  barrier cadence (``max_in_flight = min_ask =`` the strategy's batch
  width) over the view's in-order completions, which makes the trace
  bit-identical to a local ``run_async`` with the same seed — the
  property that lets a cache hit from another session stand in for a
  private evaluation.

All entry points serialize on one reentrant lock: a session is a single
conversation, not a parallel object (concurrency lives across sessions,
in the pool)."""

from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence

from repro.core.controller import Controller, EvalRecord, _batch_width
from repro.core.strategy import SearchStrategy, Trace, _json_cfg


class SessionClosed(RuntimeError):
    pass


class TuningSession:
    def __init__(self, session_id: str, workload: str,
                 strategy_name: str, strategy: SearchStrategy,
                 controller: Controller, deterministic: bool = True,
                 budget: Optional[int] = None,
                 batch_size: Optional[int] = None):
        self.session_id = session_id
        self.workload = workload
        self.strategy_name = strategy_name
        self.strategy = strategy
        self.controller = controller
        self.deterministic = deterministic
        self.budget = budget
        self.batch_size = batch_size
        self.created_at = time.time()
        self.last_used = self.created_at
        self.closed = False
        self.runs = 0
        self._lock = threading.RLock()

    def touch(self) -> None:
        """Stamp client activity — the idle clock the server's
        ``session_ttl`` eviction sweep reads."""
        self.last_used = time.time()

    @property
    def db(self):
        return self.controller.db

    def _check_open(self):
        if self.closed:
            raise SessionClosed(f"session {self.session_id} is closed")

    # -- ask/tell (client-side evaluation) ----------------------------------

    def ask(self, n: Optional[int] = None) -> List[Dict]:
        with self._lock:
            self._check_open()
            self.touch()
            return [_json_cfg(c) for c in self.strategy.ask(n)]

    def tell(self, configs: Sequence[Dict], values: Sequence[float],
             variances: Optional[Sequence[float]] = None) -> int:
        if len(configs) != len(values):
            raise ValueError(f"tell: {len(configs)} configs vs "
                             f"{len(values)} values")
        if variances is not None and len(variances) != len(values):
            raise ValueError(f"tell: {len(variances)} variances vs "
                             f"{len(values)} values")
        with self._lock:
            self._check_open()
            self.touch()
            cfgs = [dict(c) for c in configs]
            vals = [float(v) for v in values]
            vrs = ([float(v) for v in variances] if variances is not None
                   else [0.0] * len(vals))
            self.db.append_batch([
                EvalRecord(c, v, 0.0, self.controller.tag, self.workload,
                           "client", "ok", 1, s)
                for c, v, s in zip(cfgs, vals, vrs)])
            Controller._teller(self.strategy)(cfgs, vals, vrs)
            return len(cfgs)

    # -- server-side drive ---------------------------------------------------

    def run(self, budget: Optional[int] = None,
            batch_size: Optional[int] = None,
            fidelity: Optional[str] = None) -> Trace:
        """Drive the strategy to completion on the shared pool.  The
        deterministic barrier cadence submits exactly one strategy-width
        wave at a time and tells it whole — the replayable schedule;
        ``deterministic=False`` sessions run the default overlapped loop
        (faster on a busy pool, order-dependent trace)."""
        with self._lock:
            self._check_open()
            self.touch()
            budget = budget if budget is not None else self.budget
            batch_size = (batch_size if batch_size is not None
                          else self.batch_size)
            kwargs = {}
            if self.deterministic:
                width = _batch_width(self.strategy, batch_size)
                kwargs = {"max_in_flight": width, "min_ask": width}
            if fidelity is not None:
                kwargs["fidelity"] = fidelity
            trace = self.controller.run_async(
                self.strategy, budget=budget, batch_size=batch_size,
                **kwargs)
            self.runs += 1
            self.touch()         # a long run is activity up to its end
            return trace

    # -- introspection -------------------------------------------------------

    def best(self):
        with self._lock:
            self._check_open()
            self.touch()
            cfg, val = self.strategy.best()
            return _json_cfg(cfg), float(val)

    def history(self, limit: Optional[int] = None) -> List[EvalRecord]:
        with self._lock:
            recs = self.db.records
            return recs[-limit:] if limit else recs

    def state(self) -> dict:
        with self._lock:
            self._check_open()
            fn = getattr(self.strategy, "state_dict", None)
            if fn is None:
                raise TypeError(
                    f"strategy {self.strategy_name!r} has no serializable "
                    "state (state_dict unsupported)")
            return fn()

    def describe(self) -> dict:
        trace = getattr(self.strategy, "trace", None)
        return {"session": self.session_id, "workload": self.workload,
                "strategy": self.strategy_name, "budget": self.budget,
                "deterministic": self.deterministic, "closed": self.closed,
                "runs": self.runs, "evaluations": len(self.db),
                "observations": len(trace.values) if trace else 0,
                "created_at": self.created_at,
                "last_used": self.last_used}

    def close(self):
        with self._lock:
            if self.closed:
                return
            self.closed = True
            fn = getattr(self.strategy, "close", None)
            if fn is not None:
                fn()

"""One append log for every session: sharded JSONL + namespace views.

A daemon hosting hundreds of sessions cannot give each its own EvalDB
file (fd exhaustion, a directory of thousands of one-line logs) nor share
one file for everything (a single writer lock serializing every session's
completion wave).  The middle ground: ``n_shards`` JSONL files, each a
normal :class:`~repro.core.controller.EvalDB` opened ``shared_path=True``
(advisory file locks — a second daemon on the same root fails safe
instead of interleaving lines), with a session's namespace mapped to a
shard by stable hash.  Each record carries its owning namespace in the
``ns`` field, so a shard's file remains a valid EvalDB log (legacy
tooling reads it; ``ns`` rides along) and a warm-restarted daemon
reloads every session's history by filtering its shard.

:class:`SessionDB` is the per-session facade a Controller writes
through: it stamps ``ns`` on append and filters on read — the
EvalDB-shaped surface (``append_batch`` / ``records`` / ``pairs`` /
``len``) the rest of the repo already speaks.
"""

from __future__ import annotations

import hashlib
from dataclasses import replace
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.core.controller import EvalDB, EvalRecord


def shard_index(ns: str, n_shards: int) -> int:
    """Stable across processes and restarts (not ``hash()``: that is
    salted per interpreter, and a restarted daemon must find the same
    shard its sessions wrote before)."""
    h = hashlib.blake2s(ns.encode()).digest()[:4]
    return int.from_bytes(h, "little") % max(n_shards, 1)


class SessionDB:
    """A namespace window over one shard: EvalDB-shaped, ns-stamped."""

    def __init__(self, shard: EvalDB, ns: str):
        self.shard = shard
        self.ns = ns

    @property
    def path(self):
        return self.shard.path

    def _stamp(self, rec: EvalRecord) -> EvalRecord:
        return rec if rec.ns == self.ns else replace(rec, ns=self.ns)

    def append(self, rec: EvalRecord):
        self.shard.append(self._stamp(rec))

    def append_batch(self, recs) -> None:
        self.shard.append_batch([self._stamp(r) for r in recs])

    @property
    def records(self) -> List[EvalRecord]:
        return [r for r in self.shard.records if r.ns == self.ns]

    def pairs(self, tag: Optional[str] = None,
              workload: Optional[str] = None,
              include_failed: bool = False):
        rs = [r for r in self.records
              if (tag is None or r.tag == tag)
              and (workload is None or r.workload == workload)
              and (include_failed or r.ok)]
        return [r.config for r in rs], [r.value for r in rs]

    def __len__(self):
        return len(self.records)


class ShardedEvalLog:
    """``n_shards`` EvalDBs under one root (or in-memory when rootless).

    ``namespace(ns)`` hands out the :class:`SessionDB` for a session;
    existing shard files reload on construction, so the namespaces of a
    previous daemon run are immediately queryable (warm restart)."""

    def __init__(self, root: Optional[str] = None, n_shards: int = 4):
        self.root = Path(root) if root else None
        self.n_shards = max(int(n_shards), 1)
        if self.root is not None:
            self.root.mkdir(parents=True, exist_ok=True)
        self.shards: List[EvalDB] = [
            EvalDB(str(self.root / f"shard-{i:02d}.jsonl")
                   if self.root else None,
                   shared_path=self.root is not None)
            for i in range(self.n_shards)]

    def shard_for(self, ns: str) -> EvalDB:
        return self.shards[shard_index(ns, self.n_shards)]

    def namespace(self, ns: str) -> SessionDB:
        if not ns:
            raise ValueError("ShardedEvalLog namespaces must be non-empty")
        return SessionDB(self.shard_for(ns), ns)

    def namespaces(self) -> Tuple[str, ...]:
        seen = {r.ns for s in self.shards for r in s.records if r.ns}
        return tuple(sorted(seen))

    @property
    def records(self) -> List[EvalRecord]:
        return [r for s in self.shards for r in s.records]

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.records:
            out[r.ns] = out.get(r.ns, 0) + 1
        return out

    def __len__(self):
        return sum(len(s.records) for s in self.shards)

"""HTTP/JSON surface for the tuning daemon (stdlib only).

One :class:`~http.server.ThreadingHTTPServer` fronts a
:class:`~repro.service.server.TuningServer`; every endpoint maps 1:1
onto a server/session method, with the blocking ``run`` endpoint held
open for the whole server-side drive (each request runs on its own
thread, so a long ``run`` never starves ``ask``/``tell`` traffic on
other sessions).

::

    GET  /v1/health                       liveness + version
    GET  /v1/workloads                    hosted workload catalog
    GET  /v1/stats                        daemon counters + cache stats
    GET  /v1/sessions                     open sessions
    POST /v1/sessions                     create-session
         (``transfer_from`` warm-starts a ``transfer_bo`` session from
         the daemon's own sharded log; ``resume`` reopens an evicted
         session from its snapshot by id)
    POST /v1/sessions/<id>/ask            {"n": int?}        -> configs
    POST /v1/sessions/<id>/tell           {configs, values, variances?}
    POST /v1/sessions/<id>/run            {budget?, batch_size?, fidelity?}
    GET  /v1/sessions/<id>/best           incumbent config + value
    GET  /v1/sessions/<id>/history?limit= namespaced EvalDB records
    GET  /v1/sessions/<id>/state          strategy state_dict (warm restart)
    POST /v1/sessions/<id>/close          close-session

Errors are JSON too: ``{"error": msg}`` with 400 (bad request), 404
(unknown session/workload/route) or 409 (closed session / no
observations yet).  The Space codec round-trips every knob field and
all four constraint classes so a remote client can validate configs
locally before ``tell``-ing them.
"""

from __future__ import annotations

import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Tuple

from repro.core.space import (Divides, Knob, Leq, ProductLeq, Space,
                              SumLeq)
from repro.core.strategy import _json_cfg
from repro.service.server import TuningServer
from repro.service.session import SessionClosed

WIRE_VERSION = 1


# ---------------------------------------------------------------------------
# Space <-> JSON
# ---------------------------------------------------------------------------

_CONSTRAINTS = {"sum_leq": SumLeq, "leq": Leq, "divides": Divides,
                "product_leq": ProductLeq}


def constraint_to_json(c) -> dict:
    for name, cls in _CONSTRAINTS.items():
        if type(c) is cls:
            d = {"type": name, "knobs": list(c.knobs)}
            if name in ("sum_leq", "product_leq"):
                d["limit"] = c.limit
            if name == "divides":
                d["target"] = c.target
            return d
    raise TypeError(f"cannot serialize constraint {type(c).__name__}")


def constraint_from_json(d: dict):
    cls = _CONSTRAINTS[d["type"]]
    knobs = tuple(d["knobs"])
    if d["type"] in ("sum_leq", "product_leq"):
        return cls(knobs, limit=float(d["limit"]))
    if d["type"] == "divides":
        t = d.get("target")
        return cls(knobs, target=None if t is None else int(t))
    return cls(knobs)


def knob_to_json(k: Knob) -> dict:
    return {"name": k.name, "kind": k.kind, "default": k.default,
            "lo": k.lo, "hi": k.hi,
            "choices": list(k.choices) if k.choices is not None else None,
            "log_scale": k.log_scale, "dynamic_bound": k.dynamic_bound,
            "align": k.align, "configurable": k.configurable,
            "gated_by": ([k.gated_by[0], list(k.gated_by[1])]
                         if k.gated_by is not None else None),
            "module": k.module, "restart_required": k.restart_required,
            "inert": k.inert, "description": k.description}


def knob_from_json(d: dict) -> Knob:
    gated = d.get("gated_by")
    choices = d.get("choices")
    return Knob(d["name"], d["kind"], d["default"],
                lo=d.get("lo"), hi=d.get("hi"),
                choices=tuple(choices) if choices is not None else None,
                log_scale=bool(d.get("log_scale", False)),
                dynamic_bound=bool(d.get("dynamic_bound", False)),
                align=int(d.get("align", 1)),
                configurable=bool(d.get("configurable", True)),
                gated_by=((gated[0], tuple(gated[1]))
                          if gated is not None else None),
                module=str(d.get("module", "core")),
                restart_required=bool(d.get("restart_required", True)),
                inert=bool(d.get("inert", False)),
                description=str(d.get("description", "")))


def space_to_json(space: Space) -> dict:
    return {"knobs": [knob_to_json(k) for k in space.knobs],
            "constraints": [constraint_to_json(c)
                            for c in space.constraints]}


def space_from_json(d: dict) -> Space:
    return Space(tuple(knob_from_json(k) for k in d["knobs"]),
                 tuple(constraint_from_json(c)
                       for c in d.get("constraints", ())))


def record_to_json(r) -> dict:
    return {"config": _json_cfg(r.config), "value": r.value,
            "wall_s": r.wall_s, "tag": r.tag, "workload": r.workload,
            "fidelity": r.fidelity, "status": r.status,
            "repeats": r.repeats, "variance": r.variance}


def trace_to_json(t) -> dict:
    return {"configs": [_json_cfg(c) for c in t.configs],
            "values": [float(v) for v in t.values],
            "variances": [float(v) for v in t.variances],
            "best_values": [float(v) for v in t.best_values],
            "boundary_events": [[int(i), str(k)]
                                for i, k in t.boundary_events]}


# ---------------------------------------------------------------------------
# the request handler
# ---------------------------------------------------------------------------

class _ApiError(Exception):
    def __init__(self, code: int, msg: str):
        super().__init__(msg)
        self.code = code


_SESSION_PATH = re.compile(
    r"^/v1/sessions/([^/]+)/(ask|tell|run|best|history|state|close)$")


class TuningRequestHandler(BaseHTTPRequestHandler):
    """Routes one request; ``self.server.tuning`` is the TuningServer."""

    protocol_version = "HTTP/1.1"

    # -- plumbing -----------------------------------------------------------

    def log_message(self, fmt, *args):    # quiet by default; the daemon
        pass                              # entrypoint has its own logging

    def _payload(self) -> dict:
        n = int(self.headers.get("Content-Length") or 0)
        if n == 0:
            return {}
        try:
            body = json.loads(self.rfile.read(n) or b"{}")
        except json.JSONDecodeError as e:
            raise _ApiError(400, f"bad JSON body: {e}")
        if not isinstance(body, dict):
            raise _ApiError(400, "JSON body must be an object")
        return body

    def _reply(self, obj, code: int = 200):
        data = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _session(self, sid: str):
        try:
            return self.server.tuning.session(sid)
        except KeyError as e:
            raise _ApiError(404, str(e))

    def _dispatch(self, method: str):
        srv: TuningServer = self.server.tuning
        path, _, query = self.path.partition("?")
        try:
            if path == "/v1/health" and method == "GET":
                return self._reply({"ok": True, "version": WIRE_VERSION})
            if path == "/v1/workloads" and method == "GET":
                return self._reply({"workloads": srv.workloads()})
            if path == "/v1/stats" and method == "GET":
                return self._reply(srv.stats())
            if path == "/v1/sessions" and method == "GET":
                return self._reply({"sessions": srv.list_sessions()})
            if path == "/v1/sessions" and method == "POST":
                return self._create(srv)
            m = _SESSION_PATH.match(path)
            if m is not None:
                return self._session_call(m.group(1), m.group(2),
                                          method, query)
            raise _ApiError(404, f"no route {method} {path}")
        except _ApiError as e:
            self._reply({"error": str(e)}, e.code)
        except SessionClosed as e:
            self._reply({"error": str(e)}, 409)
        except (KeyError, TypeError, ValueError) as e:
            self._reply({"error": str(e)}, 400)
        except Exception as e:           # never a half-closed socket
            self._reply({"error": f"internal: {e!r}"}, 500)

    # -- endpoints ----------------------------------------------------------

    def _create(self, srv: TuningServer):
        body = self._payload()
        try:
            workload = body.pop("workload")
        except KeyError:
            raise _ApiError(400, "create-session needs a 'workload'")
        allowed = {"strategy", "budget", "seed", "batch_size",
                   "strategy_kwargs", "replication", "deterministic",
                   "tag", "state", "transfer_from", "resume"}
        unknown = set(body) - allowed
        if unknown:
            raise _ApiError(400, f"unknown create-session fields "
                                 f"{sorted(unknown)}")
        try:
            sess = srv.create_session(workload, **body)
        except KeyError as e:
            raise _ApiError(404, str(e))
        self._reply({"session": sess.session_id,
                     "workload": sess.workload,
                     "strategy": sess.strategy_name,
                     "space": space_to_json(sess.strategy.space)},
                    201)

    def _session_call(self, sid: str, verb: str, method: str, query: str):
        wants_post = verb in ("ask", "tell", "run", "close")
        if (method == "POST") != wants_post:
            raise _ApiError(405,
                            f"{verb} is {'POST' if wants_post else 'GET'}")
        srv: TuningServer = self.server.tuning
        sess = self._session(sid)
        if verb == "ask":
            n = self._payload().get("n")
            cfgs = sess.ask(None if n is None else int(n))
            return self._reply({"configs": cfgs})
        if verb == "tell":
            body = self._payload()
            told = sess.tell(body.get("configs", []),
                             body.get("values", []),
                             body.get("variances"))
            return self._reply({"told": told})
        if verb == "run":
            body = self._payload()
            trace = sess.run(budget=body.get("budget"),
                             batch_size=body.get("batch_size"),
                             fidelity=body.get("fidelity"))
            cfg, val = trace.best
            return self._reply({"best_config": _json_cfg(cfg),
                                "best_value": float(val),
                                "n_evaluations": len(trace.values),
                                "trace": trace_to_json(trace)})
        if verb == "best":
            try:
                cfg, val = sess.best()
            except SessionClosed:
                raise
            except (ValueError, RuntimeError):
                raise _ApiError(409, f"session {sid} has no "
                                     "observations yet")
            return self._reply({"config": cfg, "value": val})
        if verb == "history":
            limit = None
            m = re.search(r"(?:^|&)limit=(\d+)", query)
            if m:
                limit = int(m.group(1))
            return self._reply({"records": [record_to_json(r)
                                            for r in sess.history(limit)]})
        if verb == "state":
            return self._reply({"state": sess.state()})
        if verb == "close":
            srv.close_session(sid)
            return self._reply({"closed": sid})
        raise _ApiError(404, f"no verb {verb!r}")    # pragma: no cover

    def do_GET(self):
        self._dispatch("GET")

    def do_POST(self):
        self._dispatch("POST")


# ---------------------------------------------------------------------------
# server bootstrap
# ---------------------------------------------------------------------------

def make_wire_server(tuning: TuningServer, host: str = "127.0.0.1",
                     port: int = 0) -> ThreadingHTTPServer:
    """Bind the HTTP front end (``port=0`` picks an ephemeral port —
    ``httpd.server_address`` has the real one).  The caller owns both
    lifecycles: ``httpd.shutdown()`` stops serving, ``tuning.close()``
    stops the daemon."""
    httpd = ThreadingHTTPServer((host, port), TuningRequestHandler)
    httpd.daemon_threads = True
    httpd.tuning = tuning
    return httpd


def serve_background(tuning: TuningServer, host: str = "127.0.0.1",
                     port: int = 0) -> Tuple[ThreadingHTTPServer,
                                             threading.Thread]:
    """In-process daemon for tests/examples: serve on a background
    thread, return (httpd, thread)."""
    httpd = make_wire_server(tuning, host, port)
    thread = threading.Thread(target=httpd.serve_forever,
                              name="tuning-wire", daemon=True)
    thread.start()
    return httpd, thread

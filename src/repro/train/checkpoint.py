"""Fault-tolerant checkpointing: atomic, step-tagged, auto-resume.

Layout (one directory per step, written atomically via tmp+rename):

    <root>/step_000200.tmp/...      (in flight)
    <root>/step_000200/
        manifest.json               (treedef, shapes, dtypes, step, ...)
        shard_00000.npz             (this host's leaves)

* **atomic**: readers never observe a partial checkpoint — the rename is
  the commit point; stale ``.tmp`` dirs from crashed writers are garbage-
  collected on the next save.
* **sharded-save**: each host writes only its own ``shard_<proc>.npz``
  (here: one host); a restore reassembles per-host leaves.  On a fleet the
  4 TB grok-1 state writes in parallel across hosts.
* **async flush**: ``save(..., blocking=False)`` hands the host-side
  arrays to a writer thread so the train loop resumes immediately (the
  device->host copy is the only synchronous part).
* **retention**: keep the last N checkpoints (plus every multiple of
  ``keep_every`` — the "durable" snapshots for post-hoc evals).
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

_BF16 = np.dtype(ml_dtypes.bfloat16)


class CheckpointManager:
    def __init__(self, root: str, keep_last: int = 3,
                 keep_every: Optional[int] = None, process_index: int = 0):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.keep_every = keep_every
        self.process_index = process_index
        self._writer: Optional[threading.Thread] = None
        self._gc_tmp()

    # ---- write --------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = True,
             extra: Optional[Dict] = None):
        """Checkpoint a pytree of arrays at ``step``."""
        self.wait()                       # one in-flight save at a time
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]   # device -> host copy
        # numpy can't serialize ml_dtypes (bfloat16 & friends): store the
        # raw bits and record the logical dtype in the manifest.
        store_leaves = [x.view(np.uint16) if x.dtype == _BF16 else x
                        for x in host_leaves]
        manifest = {
            "step": int(step),
            "n_leaves": len(host_leaves),
            "shapes": [list(x.shape) for x in host_leaves],
            "dtypes": [str(x.dtype) for x in host_leaves],
            # structure check is textual: proto serialization rejects
            # user-defined nodes (NamedTuple states)
            "treedef": str(treedef),
            "time": time.time(),
            "extra": extra or {},
        }

        def _write():
            final = self.root / f"step_{step:08d}"
            tmp = self.root / f"step_{step:08d}.tmp"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / f"shard_{self.process_index:05d}.npz",
                     **{f"leaf_{i}": x for i, x in enumerate(store_leaves)})
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)             # commit point
            self._retain()

        if blocking:
            _write()
        else:
            self._writer = threading.Thread(target=_write, daemon=True)
            self._writer.start()

    def wait(self):
        if self._writer is not None:
            self._writer.join()
            self._writer = None

    # ---- read ---------------------------------------------------------------

    def steps(self) -> List[int]:
        out = []
        for p in self.root.iterdir():
            if p.is_dir() and p.name.startswith("step_") \
                    and not p.name.endswith(".tmp"):
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: Any, step: Optional[int] = None
                ) -> Tuple[Any, int]:
        """Restore into the structure of ``template`` (shape/dtype checked)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        with np.load(d / f"shard_{self.process_index:05d}.npz") as z:
            leaves = []
            for i in range(manifest["n_leaves"]):
                x = z[f"leaf_{i}"]
                if manifest["dtypes"][i] == "bfloat16":
                    x = x.view(_BF16)
                leaves.append(x)
        t_leaves, treedef = jax.tree.flatten(template)
        if len(t_leaves) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, template "
                f"{len(t_leaves)} — architecture/RunConfig mismatch")
        for i, (a, b) in enumerate(zip(t_leaves, leaves)):
            if tuple(a.shape) != tuple(b.shape):
                raise ValueError(f"leaf {i}: shape {b.shape} != {a.shape}")
        restored = [jnp.asarray(b, dtype=a.dtype)
                    for a, b in zip(t_leaves, leaves)]
        return jax.tree.unflatten(treedef, restored), step

    # ---- housekeeping ---------------------------------------------------------

    def _retain(self):
        steps = self.steps()
        if len(steps) <= self.keep_last:
            return
        drop = steps[:-self.keep_last]
        for s in drop:
            if self.keep_every and s % self.keep_every == 0:
                continue
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    def _gc_tmp(self):
        for p in self.root.glob("step_*.tmp"):
            shutil.rmtree(p, ignore_errors=True)

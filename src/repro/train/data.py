"""Deterministic, stateless synthetic LM data pipeline.

``batch_at(seed, step, ...)`` is a pure function — the stream has no
cursor, so a restart at any step on any mesh carve reproduces the exact
token stream (the elastic-scaling requirement: data position is part of
the checkpoint *implicitly*, as just the step number).

Per-host sharding: each host materializes only its slice of the global
batch (``host_slice``); under pjit the global array is assembled from
per-host shards (jax.make_array_from_process_local_data on a fleet).

The synthetic distribution is not uniform noise: documents are drawn from
a Zipf-ish unigram mixture with doc-boundary resets, so the loss actually
*decreases* during the example training runs (quickstart/train_lm) and
data-dependent bugs (e.g. label misalignment) surface in tests.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def _unigram_logits(vocab: int) -> jnp.ndarray:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -1.1 * jnp.log(ranks)          # Zipf(1.1)


def batch_at(seed: int, step: int, *, global_batch: int, seq_len: int,
             vocab_size: int, doc_len: int = 512,
             host_index: int = 0, host_count: int = 1) -> Dict[str, jnp.ndarray]:
    """Return {tokens, labels} [B_host, S] for (seed, step) — pure."""
    assert global_batch % host_count == 0
    b_host = global_batch // host_count
    key = jax.random.fold_in(jax.random.fold_in(jax.random.key(seed), step),
                             host_index)
    logits = _unigram_logits(vocab_size)
    # one extra token so labels are a true shift
    toks = jax.random.categorical(
        key, jnp.broadcast_to(logits, (b_host, seq_len + 1, vocab_size)))
    # doc boundaries: token 0 acts as BOS every doc_len positions
    pos = jnp.arange(seq_len + 1)
    toks = jnp.where((pos % doc_len == 0)[None, :], 0, toks)
    toks = toks.astype(jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def host_slice(global_batch: int, host_index: int, host_count: int
               ) -> Tuple[int, int]:
    per = global_batch // host_count
    return host_index * per, (host_index + 1) * per


class SyntheticDataset:
    """Thin iterator facade over ``batch_at`` (examples / train driver)."""

    def __init__(self, seed: int, global_batch: int, seq_len: int,
                 vocab_size: int, start_step: int = 0,
                 host_index: int = 0, host_count: int = 1):
        self.seed = seed
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.vocab_size = vocab_size
        self.step = start_step
        self.host_index = host_index
        self.host_count = host_count

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, jnp.ndarray]:
        b = batch_at(self.seed, self.step, global_batch=self.global_batch,
                     seq_len=self.seq_len, vocab_size=self.vocab_size,
                     host_index=self.host_index, host_count=self.host_count)
        self.step += 1
        return b

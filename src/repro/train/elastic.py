"""Elasticity & straggler mitigation: the fleet-runtime control plane.

On 1000+ nodes, failures are routine: the runtime must (a) detect sick /
slow hosts, (b) compute a new mesh carve from the survivors, (c) map the
checkpointed state onto the new carve and resume from the stateless data
stream (train/data.py makes the stream a pure function of (seed, step), so
no data cursor needs rescuing).

Everything here is deterministic control-plane *logic* — exactly the part
that can and should be unit-tested off-fleet.  The actual collectives are
jax's; this module only decides shapes and assignments.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

HEALTHY, STRAGGLER, DEAD = "healthy", "straggler", "dead"


# ---------------------------------------------------------------------------
# straggler detection
# ---------------------------------------------------------------------------

@dataclass
class StepWatchdog:
    """Flags hosts whose step times sit above k× the fleet median.

    Fed per-step host timings (on a fleet: from the heartbeat channel).
    The baseline is the *median* — a p99 baseline would be contaminated
    by the straggler's own samples.  A host is a straggler only after
    ``patience`` consecutive slow steps, so transient hiccups (GC,
    checkpoint flush) don't trigger a re-carve.
    """
    k: float = 1.5
    patience: int = 3
    window: int = 64
    _times: Dict[int, List[float]] = field(default_factory=dict)
    _slow: Dict[int, int] = field(default_factory=dict)

    def observe(self, host: int, step_s: float):
        self._times.setdefault(host, []).append(step_s)
        self._times[host] = self._times[host][-self.window:]

    def classify(self) -> Dict[int, str]:
        if not self._times:
            return {}
        all_t = np.concatenate([np.asarray(v) for v in self._times.values()])
        base = float(np.median(all_t))
        out = {}
        for host, ts in self._times.items():
            slow = ts[-1] > self.k * base
            self._slow[host] = self._slow.get(host, 0) + 1 if slow else 0
            out[host] = STRAGGLER if self._slow[host] >= self.patience \
                else HEALTHY
        return out


# ---------------------------------------------------------------------------
# mesh re-carve
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Carve:
    pod: int
    data: int
    model: int

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model


def recarve(alive_chips: int, prefer: Carve,
            model_min: Optional[int] = None) -> Carve:
    """Largest usable carve from the survivors.

    Keeps the model axis (changing it re-shards every weight tensor; the
    data axis only re-shards the batch) unless fewer chips remain than one
    model group, then shrinks model to the largest power-of-two that fits.
    """
    model = prefer.model
    model_min = model_min or 1
    while model > model_min and alive_chips < model:
        model //= 2
    dp_total = alive_chips // model
    if dp_total == 0:
        raise ValueError("not enough chips for one model group")
    # prefer keeping pods intact
    pod = min(prefer.pod, dp_total)
    while pod > 1 and dp_total % pod != 0:
        pod -= 1
    data = dp_total // pod
    return Carve(pod, data, model)


@dataclass(frozen=True)
class ReshardPlan:
    """old shard index -> new shard owners, per logical axis size change."""
    old: Carve
    new: Carve
    batch_scale: float              # global-batch change if kept per-chip
    param_moves: Tuple[Tuple[int, int], ...]   # (old_dp_shard, new_dp_shard)

    def summary(self) -> str:
        return (f"{self.old.pod}x{self.old.data}x{self.old.model} -> "
                f"{self.new.pod}x{self.new.data}x{self.new.model} "
                f"({len(self.param_moves)} shard moves)")


def plan_reshard(old: Carve, new: Carve) -> ReshardPlan:
    """FSDP (ZeRO-3) state moves when the DP world shrinks/grows.

    Parameters are sharded over dp_total = pod·data; a world change from
    Do to Dn means new shard j gathers old shards overlapping
    [j/Dn, (j+1)/Dn) of the flat parameter space.
    """
    do, dn = old.pod * old.data, new.pod * new.data
    moves: List[Tuple[int, int]] = []
    for j in range(dn):
        lo, hi = j / dn, (j + 1) / dn
        for i in range(do):
            ilo, ihi = i / do, (i + 1) / do
            if ilo < hi and ihi > lo:           # overlap
                moves.append((i, j))
    return ReshardPlan(old, new, batch_scale=dn / do,
                       param_moves=tuple(moves))


# ---------------------------------------------------------------------------
# the restart policy
# ---------------------------------------------------------------------------

@dataclass
class ElasticPolicy:
    """checkpoint-restart policy for failures & stragglers.

    decide() returns one of:
      ("continue",)                       — all healthy
      ("evict", host, plan)               — drop straggler, re-carve
      ("restore", step, plan)             — dead host: restart from ckpt
    """
    carve: Carve
    chips_per_host: int = 4
    evict_stragglers: bool = True

    def decide(self, health: Dict[int, str], latest_ckpt: Optional[int]):
        dead = [h for h, s in health.items() if s == DEAD]
        slow = [h for h, s in health.items() if s == STRAGGLER]
        n_hosts = max(len(health), 1)
        if dead:
            alive = (n_hosts - len(dead)) * self.chips_per_host
            new = recarve(alive, self.carve)
            return ("restore", latest_ckpt, plan_reshard(self.carve, new))
        if slow and self.evict_stragglers:
            alive = (n_hosts - len(slow)) * self.chips_per_host
            new = recarve(alive, self.carve)
            return ("evict", slow[0], plan_reshard(self.carve, new))
        return ("continue",)

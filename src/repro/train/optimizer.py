"""Optimizers from scratch: AdamW (f32 master weights) and Adafactor.

The optimizer choice, betas, weight decay, clipping and master-weight
policy are all SAPPHIRE knobs (C3: ``optimizer`` gates ``beta1/beta2``).
State layout is a pytree mirroring the parameters so the same logical-axis
sharding rules apply (FSDP shards optimizer state with the parameters —
ZeRO semantics fall out of the axis rules for free).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.runconfig import RunConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any
    master: Any          # f32 master copy (or None-like empty when disabled)


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any              # row second-moment factors
    vc: Any              # col second-moment factors
    v: Any               # full second moment for <2D params
    master: Any


def _f32(t):
    return jax.tree.map(lambda x: x.astype(jnp.float32), t)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def linear_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        return jnp.where(step < warmup, warm, base_lr * (1 - prog))
    return lr


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params, rc: RunConfig) -> AdamWState:
    zeros = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
    master = _f32(params) if rc.master_weights_f32 else \
        jax.tree.map(lambda x: jnp.zeros((0,), jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.zeros_like, zeros), master)


def adamw_update(grads, state: AdamWState, params, rc: RunConfig,
                 lr: jnp.ndarray):
    b1, b2, eps, wd = rc.beta1, rc.beta2, 1e-8, rc.weight_decay
    step = state.step + 1
    g32, _ = clip_by_global_norm(grads, rc.grad_clip_norm)
    m = jax.tree.map(lambda mi, gi: b1 * mi + (1 - b1) * gi, state.m, g32)
    v = jax.tree.map(lambda vi, gi: b2 * vi + (1 - b2) * gi * gi, state.v, g32)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    base = state.master if rc.master_weights_f32 else _f32(params)
    new_master = jax.tree.map(
        lambda p, mi, vi: p - lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
        - lr * wd * p,
        base, m, v)
    new_params = jax.tree.map(lambda p, nm: nm.astype(p.dtype),
                              params, new_master)
    keep_master = new_master if rc.master_weights_f32 else state.master
    return new_params, AdamWState(step, m, v, keep_master)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments — 1/3 the optimizer HBM of AdamW)
# ---------------------------------------------------------------------------

def _factored(x) -> bool:
    return x.ndim >= 2


def adafactor_init(params, rc: RunConfig) -> AdafactorState:
    def rows(x):
        return (jnp.zeros(x.shape[:-1], jnp.float32) if _factored(x)
                else jnp.zeros((0,), jnp.float32))

    def cols(x):
        return (jnp.zeros(x.shape[:-2] + x.shape[-1:], jnp.float32)
                if _factored(x) else jnp.zeros((0,), jnp.float32))

    def full(x):
        return (jnp.zeros((0,), jnp.float32) if _factored(x)
                else jnp.zeros_like(x, jnp.float32))

    master = _f32(params) if rc.master_weights_f32 else \
        jax.tree.map(lambda x: jnp.zeros((0,), jnp.float32), params)
    return AdafactorState(jnp.zeros((), jnp.int32),
                          jax.tree.map(rows, params),
                          jax.tree.map(cols, params),
                          jax.tree.map(full, params), master)


def adafactor_update(grads, state: AdafactorState, params, rc: RunConfig,
                     lr: jnp.ndarray):
    step = state.step + 1
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8
    eps = 1e-30
    g32, _ = clip_by_global_norm(grads, rc.grad_clip_norm)

    def upd(g, vr, vc, v, p_master):
        if _factored(g):
            g2 = g * g + eps
            vr_new = decay * vr + (1 - decay) * jnp.mean(g2, axis=-1)
            vc_new = decay * vc + (1 - decay) * jnp.mean(g2, axis=-2)
            row_mean = jnp.mean(vr_new, axis=-1, keepdims=True)
            pre = (vr_new / jnp.maximum(row_mean, eps))[..., None] \
                * vc_new[..., None, :]
            upd_ = g / jnp.sqrt(jnp.maximum(pre, eps))
            v_new = v
        else:
            v_new = decay * v + (1 - decay) * (g * g)
            upd_ = g / jnp.sqrt(v_new + 1e-12)
            vr_new, vc_new = vr, vc
        # relative step size (Adafactor's update clipping)
        d = jnp.sqrt(jnp.mean(jnp.square(upd_)) + eps)
        upd_ = upd_ / jnp.maximum(1.0, d)
        new_p = p_master - lr * upd_ - lr * rc.weight_decay * p_master
        return new_p, vr_new, vc_new, v_new

    base = state.master if rc.master_weights_f32 else _f32(params)
    out = jax.tree.map(upd, g32, state.vr, state.vc, state.v, base)
    treedef = jax.tree.structure(params)
    leaves = jax.tree.leaves(out, is_leaf=lambda x: isinstance(x, tuple))
    new_master = jax.tree.unflatten(treedef, [l[0] for l in leaves])
    vr = jax.tree.unflatten(treedef, [l[1] for l in leaves])
    vc = jax.tree.unflatten(treedef, [l[2] for l in leaves])
    v = jax.tree.unflatten(treedef, [l[3] for l in leaves])
    new_params = jax.tree.map(lambda p, nm: nm.astype(p.dtype),
                              params, new_master)
    keep_master = new_master if rc.master_weights_f32 else state.master
    return new_params, AdafactorState(step, vr, vc, v, keep_master)


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------

def opt_init(params, rc: RunConfig):
    if rc.optimizer == "adamw":
        return adamw_init(params, rc)
    if rc.optimizer == "adafactor":
        return adafactor_init(params, rc)
    raise ValueError(rc.optimizer)


def opt_update(grads, state, params, rc: RunConfig, lr):
    if rc.optimizer == "adamw":
        return adamw_update(grads, state, params, rc, lr)
    return adafactor_update(grads, state, params, rc, lr)


def opt_state_axes(param_axes, rc: RunConfig):
    """Logical axes for the optimizer state (mirrors parameter axes)."""
    if rc.optimizer == "adamw":
        master = param_axes if rc.master_weights_f32 else \
            jax.tree.map(lambda _: (None,), param_axes,
                         is_leaf=_is_axes_leaf)
        return AdamWState(step=(), m=param_axes, v=param_axes, master=master)
    rows = jax.tree.map(lambda ax: tuple(ax[:-1]), param_axes,
                        is_leaf=_is_axes_leaf)
    cols = jax.tree.map(lambda ax: tuple(ax[:-2]) + tuple(ax[-1:])
                        if len(ax) >= 2 else (None,),
                        param_axes, is_leaf=_is_axes_leaf)
    master = param_axes if rc.master_weights_f32 else \
        jax.tree.map(lambda _: (None,), param_axes, is_leaf=_is_axes_leaf)
    return AdafactorState(step=(), vr=rows, vc=cols,
                          v=jax.tree.map(lambda _: (None,), param_axes,
                                         is_leaf=_is_axes_leaf),
                          master=master)


def _is_axes_leaf(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)

"""Train-step factory: microbatched grad accumulation under RunConfig knobs.

``make_train_step(model, rc)`` returns a pure ``step_fn(state, batch)``
suitable for ``jax.jit`` under a mesh (launch/train.py supplies the
shardings).  Knobs that shape the compiled program:

* ``microbatch``                — grad-accumulation split (scan or unrolled);
* ``remat_policy``              — applied inside the model backbone;
* ``grad_allreduce_dtype``      — gradients cast to bf16 *before* the
  cross-replica reduction (visible as halved all-reduce bytes in HLO);
* ``allreduce_per_microbatch``  — reduce inside the accumulation loop so
  XLA overlaps microbatch i's reduction with i+1's compute, instead of one
  bulk reduction at the end;
* ``optimizer`` family          — AdamW / Adafactor (train/optimizer.py).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.runconfig import RunConfig
from repro.train import optimizer as opt


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jnp.ndarray


def init_state(model: Model, rng, rc: RunConfig) -> TrainState:
    params = model.init(rng)
    return TrainState(params, opt.opt_init(params, rc),
                      jnp.zeros((), jnp.int32))


def state_axes(model: Model, rc: RunConfig) -> TrainState:
    pax = model.param_axes()
    return TrainState(pax, opt.opt_state_axes(pax, rc), ())


def _split_micro(batch: Dict[str, jnp.ndarray], n_micro: int):
    """[B, ...] -> [n_micro, B/n_micro, ...] per batch leaf.

    ``positions`` (M-RoPE ids) is [3, B, S]: its batch dim is axis 1.
    """
    out = {}
    for key, x in batch.items():
        if key == "positions":
            b = x.shape[1]
            x = x.reshape((x.shape[0], n_micro, b // n_micro) + x.shape[2:])
            out[key] = jnp.moveaxis(x, 1, 0)
        else:
            b = x.shape[0]
            out[key] = x.reshape((n_micro, b // n_micro) + x.shape[1:])
    return out


def make_train_step(model: Model, rc: RunConfig,
                    lr_schedule: Callable = None,
                    batch_size: int = None):
    """Build the jit-able step function for this (model, RunConfig)."""
    lr_schedule = lr_schedule or opt.cosine_schedule(
        rc.learning_rate, warmup=100, total=10_000)

    grad_dtype = jnp.bfloat16 if rc.grad_allreduce_dtype == "bfloat16" \
        else jnp.float32

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb, rc)
        return loss, metrics

    def step_fn(state: TrainState, batch: Dict[str, jnp.ndarray]):
        b = batch["tokens"].shape[0]
        # rc.microbatch is PER-REPLICA: under jit, shapes are global, so
        # the number of accumulation steps is per_replica // microbatch
        # (dp read from the ambient mesh at trace time; 1 on a bare host).
        from repro.parallel.sharding import data_parallel_size
        dp = data_parallel_size(rc.shard)
        per_replica = max(b // dp, 1)
        micro = rc.microbatch if rc.microbatch > 0 else per_replica
        n_micro = max(per_replica // min(micro, per_replica), 1)
        n_micro = min(n_micro, b)            # b must split into n_micro

        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if n_micro == 1 or b % n_micro != 0:
            (loss, metrics), grads = grad_fn(state.params, batch)
            grads = jax.tree.map(lambda g: g.astype(grad_dtype), grads)
        else:
            mbs = _split_micro(batch, n_micro)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = grad_fn(state.params, mb)
                g = jax.tree.map(lambda x: x.astype(grad_dtype), g)
                # per-microbatch reduction: accumulate in the (possibly
                # compressed) reduction dtype right away — the pattern XLA
                # overlaps; bulk mode accumulates f32 and casts at the end.
                if rc.allreduce_per_microbatch:
                    g_acc = jax.tree.map(lambda a, x: a + x, g_acc, g)
                else:
                    g_acc = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), None

            acc_dtype = grad_dtype if rc.allreduce_per_microbatch \
                else jnp.float32
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype),
                              state.params)
            if rc.grad_accum_unroll:
                carry = (g0, jnp.zeros((), jnp.float32))
                for i in range(n_micro):
                    mb = jax.tree.map(lambda x: x[i], mbs)
                    carry, _ = accum(carry, mb)
                grads_sum, loss_sum = carry
            else:
                (grads_sum, loss_sum), _ = jax.lax.scan(
                    accum, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32) / n_micro).astype(grad_dtype),
                grads_sum)
            loss = loss_sum / n_micro
            metrics = {"nll": loss, "aux": jnp.zeros((), jnp.float32)}

        lr = lr_schedule(state.step)
        new_params, new_opt = opt.opt_update(grads, state.opt_state,
                                             state.params, rc, lr)
        gnorm = opt.global_norm(grads)
        out_metrics = {"loss": loss.astype(jnp.float32),
                       "grad_norm": gnorm, "lr": lr,
                       **{k: v.astype(jnp.float32)
                          for k, v in metrics.items()}}
        return TrainState(new_params, new_opt, state.step + 1), out_metrics

    return step_fn

"""Cross-workload transfer: meta-learned priors over the config zoo.

The subsystem that makes tuning evidence outlive the run that produced
it (Sapphire's amortization premise; the open problem BestConfig and
Magpie both name):

* :mod:`repro.transfer.corpus` — sweep EvalDB files / ShardedEvalLog
  roots into per-workload ``(X, y, var)`` datasets over one shared
  Space, skipping incompatible sources loudly;
* :func:`repro.core.gp.fit` with a task column — the rank-1 ICM
  multi-task GP the corpus is stacked into;
* :mod:`repro.transfer.strategy` — ``TransferBOStrategy`` (registry
  name ``"transfer_bo"``): hyperparameter warm start + design seeding +
  decaying pseudo-observations, degrading to plain BO on an empty
  corpus.

Importing this package registers the strategy.
"""

from repro.transfer.corpus import (CorpusMismatch, TaskData,
                                   TransferCorpus, build_corpus,
                                   corpus_from_log, space_signature)
from repro.transfer.strategy import TransferBOStrategy

__all__ = [
    "CorpusMismatch", "TaskData", "TransferCorpus", "TransferBOStrategy",
    "build_corpus", "corpus_from_log", "space_signature",
]

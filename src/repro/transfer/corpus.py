"""The transfer corpus: per-workload datasets mined from evaluation logs.

Sapphire's amortization argument (and BestConfig's / Magpie's open
problem) is that tuning evidence should outlive the run that produced
it.  Every run in this repo already logs :class:`~repro.core.controller.
EvalRecord` rows with a ``workload`` stamp — plain EvalDB JSONL files,
or the daemon's :class:`~repro.service.shardlog.ShardedEvalLog` root.
This module sweeps those logs into a :class:`TransferCorpus`: one
:class:`TaskData` per workload, every row keyed on a single shared
:class:`~repro.core.space.Space` so the multi-task GP can stack them
into one training matrix.

Space compatibility is decided by the PR 8 wire codec
(:func:`space_signature` — canonical JSON over every knob field and
constraint): a source whose declared space does not match the target's
signature is **skipped loudly** (a :class:`CorpusMismatch` warning, never
silence), and sources without a declared space are validated record by
record against the target space — wrong knob set or out-of-bounds values
(a donor run whose dynamic boundaries expanded past ours) drop the row,
again with a warning that counts what was lost.
"""

from __future__ import annotations

import json
import warnings
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.controller import EvalDB, EvalRecord
from repro.core.space import Config, Space


class CorpusMismatch(UserWarning):
    """A corpus source (or part of one) was skipped: incompatible space,
    unknown knobs, or out-of-bounds values.  Always warned, never silent —
    a transfer prior quietly missing half its corpus is worse than none."""


def space_signature(space: Space) -> str:
    """Canonical identity of a search space: the wire codec's JSON with
    sorted keys.  Two spaces transfer-compatible ⇔ equal signatures —
    same knobs, kinds, bounds, choices, gating and constraints, so the
    unit-cube encoding of any config is identical under either."""
    from repro.service.wire import space_to_json
    return json.dumps(space_to_json(space), sort_keys=True)


@dataclass
class TaskData:
    """One workload's observations, already projected onto the shared
    space: raw objective values (minimization) + per-row measurement
    variances (0.0 = no replicated estimate)."""
    workload: str
    configs: List[Config]
    values: np.ndarray        # [n] raw objective
    variances: np.ndarray     # [n] variance of each reported mean

    def __len__(self) -> int:
        return len(self.configs)

    @property
    def best(self) -> Tuple[Config, float]:
        i = int(np.argmin(self.values))
        return self.configs[i], float(self.values[i])

    def top(self, k: int) -> List[Config]:
        order = np.argsort(self.values)[:k]
        return [self.configs[int(i)] for i in order]


@dataclass
class TransferCorpus:
    """Per-workload datasets over one shared :class:`Space` — the input
    to the multi-task prior fit and the warm-start seeds."""
    space: Space
    tasks: List[TaskData]

    @property
    def n_tasks(self) -> int:
        return len(self.tasks)

    @property
    def workloads(self) -> Tuple[str, ...]:
        return tuple(t.workload for t in self.tasks)

    def __len__(self) -> int:
        return sum(len(t) for t in self.tasks)

    def __bool__(self) -> bool:
        return self.n_tasks > 0

    def best_configs(self, per_task: int = 1) -> List[Config]:
        """Each task's best ``per_task`` configs, interleaved best-first
        across tasks (task order by its own best value) — the natural
        seeds for a new workload's initial design."""
        ranked = sorted(self.tasks, key=lambda t: t.best[1])
        out: List[Config] = []
        for j in range(per_task):
            for t in ranked:
                if j < len(t):
                    out.append(t.top(j + 1)[j])
        return out

    def stacked(self, log_objective: bool = True,
                max_per_task: Optional[int] = None,
                seed: int = 0) -> Tuple[np.ndarray, np.ndarray,
                                        np.ndarray, np.ndarray]:
        """The multi-task training matrix: ``(x, y, var, task)`` with
        ``x`` [n, d] unit-cube rows, ``task`` [n] int32 indices into
        :attr:`tasks`.  ``log_objective`` matches BO's modeling transform
        (y → log y, variances through the delta method var/y²).
        ``max_per_task`` caps each task's rows — every task keeps its
        best rows plus a seeded random sample of the rest, so a huge
        donor log cannot make the O(n³) prior fit unpayable."""
        rng = np.random.default_rng(seed)
        xs, ys, vs, ts = [], [], [], []
        for ti, task in enumerate(self.tasks):
            idx = np.arange(len(task))
            if max_per_task is not None and len(task) > max_per_task:
                order = np.argsort(task.values)
                keep_best = order[:max(max_per_task // 4, 1)]
                rest = np.setdiff1d(idx, keep_best)
                fill = rng.choice(rest, max_per_task - len(keep_best),
                                  replace=False)
                idx = np.sort(np.concatenate([keep_best, fill]))
            cfgs = [task.configs[int(i)] for i in idx]
            y = task.values[idx].astype(np.float64)
            var = task.variances[idx].astype(np.float64)
            if log_objective:
                var = var / np.maximum(y, 1e-12) ** 2
                y = np.log(np.maximum(y, 1e-12))
            xs.append(self.space.encode_batch(cfgs))
            ys.append(y)
            vs.append(var)
            ts.append(np.full(len(idx), ti, np.int32))
        if not xs:
            d = len(self.space)
            return (np.zeros((0, d)), np.zeros(0), np.zeros(0),
                    np.zeros(0, np.int32))
        return (np.vstack(xs), np.concatenate(ys), np.concatenate(vs),
                np.concatenate(ts))


# ---------------------------------------------------------------------------
# building a corpus from logs
# ---------------------------------------------------------------------------

Source = Union[str, Path, Sequence[EvalRecord]]


def _records_from(source: Source) -> List[EvalRecord]:
    """Records of one source: a JSONL file (EvalDB reload), a directory
    (every ``*.jsonl`` under it — a ShardedEvalLog root, or a folder of
    per-run EvalDBs), or an in-memory record sequence."""
    if isinstance(source, (str, Path)):
        p = Path(source)
        if p.is_dir():
            recs: List[EvalRecord] = []
            for f in sorted(p.glob("*.jsonl")):
                recs.extend(EvalDB(str(f), shared_path=True).records)
            return recs
        if p.exists():
            return EvalDB(str(p), shared_path=True).records
        warnings.warn(f"transfer corpus: source {p} does not exist; "
                      "skipping", CorpusMismatch, stacklevel=3)
        return []
    return list(source)


def build_corpus(space: Space, sources: Sequence[Source], *,
                 spaces: Optional[Dict[str, Space]] = None,
                 exclude: Sequence[str] = (),
                 min_points: int = 2) -> TransferCorpus:
    """Assemble a :class:`TransferCorpus` over ``space`` from evaluation
    logs.

    ``sources`` are swept with :func:`_records_from` and grouped by each
    record's ``workload`` stamp.  ``spaces`` optionally declares the
    space a workload's records were produced in: a declared space whose
    :func:`space_signature` differs from the target's skips that whole
    workload with a :class:`CorpusMismatch` warning.  Undeclared
    workloads are validated row by row against the target space (knob
    set equality, value bounds); rows that fail are dropped and counted
    in one warning per workload.  ``exclude`` drops workloads outright —
    the leave-one-out hold-out, and the session's own workload when a
    server warm-starts from its shared log.  Workloads ending up with
    fewer than ``min_points`` usable rows are dropped (a one-row task
    destabilizes the task-kernel fit more than it informs it).
    """
    target_sig = space_signature(space)
    names = set(space.names)
    excluded = set(exclude)
    by_workload: Dict[str, List[EvalRecord]] = {}
    for src in sources:
        for r in _records_from(src):
            if not r.workload or r.workload in excluded:
                continue
            by_workload.setdefault(r.workload, []).append(r)

    tasks: List[TaskData] = []
    for wl in sorted(by_workload):
        if spaces is not None and wl in spaces:
            sig = space_signature(spaces[wl])
            if sig != target_sig:
                warnings.warn(
                    f"transfer corpus: workload {wl!r} was tuned in an "
                    "incompatible space (signature mismatch with the "
                    "target); skipping all "
                    f"{len(by_workload[wl])} records", CorpusMismatch,
                    stacklevel=2)
                continue
        cfgs: List[Config] = []
        vals: List[float] = []
        vrs: List[float] = []
        dropped = 0
        for r in by_workload[wl]:
            if not r.ok or not np.isfinite(r.value):
                continue
            if set(r.config) != names or space.validate(r.config):
                dropped += 1
                continue
            cfgs.append(dict(r.config))
            vals.append(float(r.value))
            vrs.append(float(r.variance))
        if dropped:
            warnings.warn(
                f"transfer corpus: workload {wl!r}: dropped {dropped} "
                "record(s) whose configs do not fit the target space "
                "(unknown knobs or out-of-bounds values)", CorpusMismatch,
                stacklevel=2)
        if len(cfgs) < min_points:
            if cfgs:
                warnings.warn(
                    f"transfer corpus: workload {wl!r} has only "
                    f"{len(cfgs)} usable record(s) (< {min_points}); "
                    "dropping the task", CorpusMismatch, stacklevel=2)
            continue
        tasks.append(TaskData(wl, cfgs, np.asarray(vals, np.float64),
                              np.asarray(vrs, np.float64)))
    return TransferCorpus(space, tasks)


def corpus_from_log(space: Space, log, *, exclude: Sequence[str] = (),
                    spaces: Optional[Dict[str, Space]] = None,
                    min_points: int = 2) -> TransferCorpus:
    """Corpus straight from a live :class:`~repro.service.shardlog.
    ShardedEvalLog` (or anything with ``.records``) — the server-side
    ``transfer_from`` path, where the daemon mines its own shared log."""
    return build_corpus(space, [log.records], spaces=spaces,
                        exclude=exclude, min_points=min_points)

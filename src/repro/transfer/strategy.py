"""`TransferBOStrategy`: BO warm-started from a multi-task corpus prior.

The transfer mechanism has three prongs, all riding existing machinery:

* **hyperparameter warm start** — the corpus multi-task GP's shared
  base-kernel triple (:func:`repro.core.gp.shared_params`) seeds the new
  workload's GP, so the first real fit starts from lengthscales learned
  across the whole workload family instead of the 0.3-isotropic default;
* **design seeding** — each corpus task's best configs go to the front
  of the initial design (:func:`repro.core.sampling.init_design` places
  caller configs before the LHS fill), so the very first evaluations
  probe where sibling workloads found their optima;
* **pseudo-observations** — the stacked prior's predictions at the
  corpus-best anchors enter the GP's training set with inflated
  variance, through the same heteroscedastic ``obs_var`` channel
  replicated measurements use.  They live only in
  :meth:`~repro.core.strategy.BOStrategy._training_data` — never in the
  trace — so ``best()`` and the budget see exclusively real
  measurements, and their variance grows exponentially with the real
  observation count: the prior fades exactly as evidence accumulates.

With an **empty corpus** every prong is inert: no seeds, no prior, no
pseudo rows, no extra RNG draws — the strategy is trace-identical to
plain :class:`~repro.core.strategy.BOStrategy` at equal seed (asserted
by tests and the ``perf_transfer`` benchmark gate).
"""

from __future__ import annotations

import math
from dataclasses import replace
from typing import List, Optional, Tuple

import numpy as np

from repro.core import gp
from repro.core.space import Config, Space
from repro.core.strategy import (BOConfig, BOStrategy, _config_key,
                                 register_strategy)
from repro.transfer.corpus import TransferCorpus


class _CorpusPrior:
    """The fitted corpus model behind one uniform ``predict`` surface.

    Multi-workload corpora fit the ICM multi-task GP and predict through
    the stacked (unseen-task) prior; a single-workload corpus falls back
    to the exact single-task path (:func:`repro.core.gp.fit` drops the
    task column itself) and predicts that task directly."""

    def __init__(self, corpus: TransferCorpus, kernel: str,
                 log_objective: bool, fit_steps: int,
                 max_per_task: Optional[int]):
        x, y, var, tasks = corpus.stacked(log_objective=log_objective,
                                          max_per_task=max_per_task)
        obs = var if np.any(var > 0) else None
        self.n_tasks = corpus.n_tasks
        self.kernel = kernel
        self.state = gp.fit(x, y, kernel, steps=fit_steps, obs_var=obs,
                            tasks=tasks, pad=False)
        self.multitask = isinstance(self.state, gp.MTGPState)

    @property
    def shared_params(self) -> gp.GPParams:
        return (gp.shared_params(self.state.params) if self.multitask
                else self.state.params)

    def predict(self, xq: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Stacked-prior mean/std at unit-cube rows ``xq`` (modeling
        scale — log objective when the corpus was stacked that way)."""
        if self.multitask:
            mu, sd = gp.predict_multitask(self.state, xq, task=None,
                                          kind=self.kernel)
        else:
            mu, sd = gp.predict(self.state, np.asarray(xq, np.float32),
                                self.kernel)
        return np.asarray(mu, np.float64), np.asarray(sd, np.float64)


class TransferBOStrategy(BOStrategy):
    """:class:`BOStrategy` + a cross-workload corpus prior.

    Parameters beyond the base strategy's:

    ``corpus``
        A :class:`~repro.transfer.corpus.TransferCorpus` over this
        strategy's space (or ``None`` / empty — plain BO).
    ``n_pseudo``
        Pseudo-observation budget: stacked-prior predictions at the
        corpus tasks' best configs (deduplicated, round-robin across
        tasks best-first).
    ``pseudo_var_inflation``
        Multiplier on the prior's predictive variance for pseudo rows —
        a pseudo observation starts life as a deliberately noisy
        measurement, so one real probe at the same config immediately
        dominates it.
    ``decay_tau``
        e-folding scale (in real observations) of the pseudo variance:
        ``var(n) = var0 · exp(n / tau)``.  Default: the design size, so
        the prior carries the design phase and fades through the BO
        rounds.
    ``seed_top_k``
        How many corpus-best configs to plant in the initial design
        (default: half the design, at most one per corpus task).
    """

    def __init__(self, space: Space, cfg: Optional[BOConfig] = None,
                 corpus: Optional[TransferCorpus] = None,
                 init_configs: Optional[List[Config]] = None,
                 n_pseudo: int = 16,
                 pseudo_var_inflation: float = 4.0,
                 decay_tau: Optional[float] = None,
                 seed_top_k: Optional[int] = None,
                 corpus_fit_steps: int = 200,
                 max_per_task: Optional[int] = 64):
        cfg = cfg or BOConfig()
        self._prior: Optional[_CorpusPrior] = None
        self._pseudo_configs: List[Config] = []
        self._pseudo_values: List[float] = []
        self._pseudo_var0: List[float] = []
        seeds = list(init_configs or [])
        if corpus is not None and corpus.n_tasks > 0:
            if set(corpus.space.names) != set(space.names):
                raise ValueError(
                    "TransferBOStrategy: corpus space does not match the "
                    "strategy space (different knob sets)")
            self._prior = _CorpusPrior(corpus, cfg.kernel,
                                       cfg.log_objective, corpus_fit_steps,
                                       max_per_task)
            if seed_top_k is None:
                seed_top_k = min(corpus.n_tasks, max(cfg.n_init // 2, 1))
            for c in corpus.best_configs(per_task=1)[:seed_top_k]:
                seeds.append(space.project(c))
            self._build_pseudo(space, corpus, cfg, n_pseudo,
                               pseudo_var_inflation)
        super().__init__(space, cfg, init_configs=seeds or None)
        self._decay_tau = float(decay_tau if decay_tau is not None
                                else max(self._n_init, 4))
        if self._prior is not None:
            # the corpus-shared base kernel is the warm-start carry from
            # round one; _fit_args below keeps feeding it back even when
            # cfg.warm_start is off
            self._params = self._prior.shared_params

    # -- prior construction ---------------------------------------------------

    def _build_pseudo(self, space: Space, corpus: TransferCorpus,
                      cfg: BOConfig, n_pseudo: int,
                      inflation: float) -> None:
        if n_pseudo <= 0:
            return
        per_task = -(-n_pseudo // corpus.n_tasks)
        anchors: List[Config] = []
        seen = set()
        for c in corpus.best_configs(per_task=per_task):
            c = space.project(c)
            key = _config_key(c)
            if key in seen:
                continue
            seen.add(key)
            anchors.append(c)
            if len(anchors) >= n_pseudo:
                break
        if not anchors:
            return
        xq = space.encode_batch(anchors).astype(np.float32)
        mu, sd = self._prior.predict(xq)
        if cfg.log_objective:
            # modeling scale is log y: map the prior back to raw units,
            # variances through the inverse delta method (var_raw ≈
            # var_log · y²) so BOStrategy's forward transform lands on
            # exactly the prior's log-scale uncertainty
            y_raw = np.exp(np.clip(mu, -50.0, 50.0))
            var_raw = (sd ** 2) * inflation * y_raw ** 2
        else:
            y_raw = mu
            var_raw = (sd ** 2) * inflation
        self._pseudo_configs = anchors
        self._pseudo_values = [float(v) for v in y_raw]
        self._pseudo_var0 = [max(float(v), 1e-12) for v in var_raw]

    # -- BOStrategy hooks -----------------------------------------------------

    def _fit_args(self):
        warm, steps = super()._fit_args()
        if warm is None and self._prior is not None:
            # without cfg.warm_start the base strategy refits cold every
            # round; the transfer prior still deserves to seed the Adam
            # loop (full step count, so the data can overrule it)
            warm = self._params
        return warm, steps

    def _training_data(self):
        if not self._pseudo_configs:
            return super()._training_data()
        n_real = len(self.trace.values)
        growth = math.exp(min(n_real / self._decay_tau, 50.0))
        pseudo_var = [v * growth for v in self._pseudo_var0]
        return (list(self.trace.configs) + self._pseudo_configs,
                list(self.trace.values) + self._pseudo_values,
                list(self.trace.variances) + pseudo_var)


@register_strategy("transfer_bo")
def _make_transfer_bo(space: Space, cfg: Optional[BOConfig] = None,
                      budget: Optional[int] = None,
                      seed: Optional[int] = None,
                      batch_size: Optional[int] = None,
                      corpus: Optional[TransferCorpus] = None,
                      init_configs: Optional[List[Config]] = None,
                      n_pseudo: int = 16,
                      pseudo_var_inflation: float = 4.0,
                      decay_tau: Optional[float] = None,
                      seed_top_k: Optional[int] = None,
                      corpus_fit_steps: int = 200,
                      max_per_task: Optional[int] = 64,
                      **_) -> TransferBOStrategy:
    if cfg is None:
        cfg = BOConfig(seed=seed if seed is not None else 0)
    if budget is not None:
        n_init = min(cfg.n_init, budget)
        cfg = replace(cfg, n_init=n_init, n_iter=budget - n_init)
    if batch_size is not None:
        cfg = replace(cfg, batch_size=batch_size, warm_start=True)
    return TransferBOStrategy(
        space, cfg, corpus=corpus, init_configs=init_configs,
        n_pseudo=n_pseudo, pseudo_var_inflation=pseudo_var_inflation,
        decay_tau=decay_tau, seed_top_k=seed_top_k,
        corpus_fit_steps=corpus_fit_steps, max_per_task=max_per_task)

"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the host's single
device; only launch/dryrun.py forces 512 placeholder devices."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)

"""Per-architecture smoke tests: reduced same-family configs on CPU.

Every assigned architecture must (a) build, (b) run one forward/loss,
(c) run one TRAIN step, (d) prefill + decode one token — all with finite
outputs and the expected shapes.  The FULL configs are exercised only by
the dry-run (no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.config import applicable_shapes
from repro.models.model import Model
from repro.runconfig import RunConfig
from repro.train.train_loop import init_state, make_train_step


def _batch(cfg, B=2, S=16):
    b = {"tokens": jnp.ones((B, S), jnp.int32) * 3,
         "labels": jnp.ones((B, S), jnp.int32) * 5}
    if cfg.is_encoder_decoder:
        b["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model),
                                jnp.bfloat16)
    if cfg.mrope_sections is not None:
        b["positions"] = jnp.zeros((3, B, S), jnp.int32)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss_finite(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    loss, mets = m.loss(params, _batch(cfg), RunConfig())
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    rc = RunConfig(microbatch=1)        # exercise accumulation too
    state = init_state(m, jax.random.key(0), rc)
    step = jax.jit(make_train_step(m, rc, lr_schedule=lambda s: 1e-3))
    b = _batch(cfg, B=2, S=16)
    state2, mets = step(state, b)
    assert np.isfinite(float(mets["loss"]))
    assert int(state2.step) == 1
    # parameters actually moved
    d0 = jax.tree.leaves(state.params)[0]
    d1 = jax.tree.leaves(state2.params)[0]
    assert not np.allclose(np.asarray(d0, np.float32),
                           np.asarray(d1, np.float32))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    rc = RunConfig()
    inputs = {"tokens": jnp.ones((2, 8), jnp.int32)}
    if cfg.is_encoder_decoder:
        inputs["frames"] = jnp.zeros((2, cfg.encoder_seq, cfg.d_model),
                                     jnp.bfloat16)
    logits, st = m.prefill(params, inputs, 16, rc)
    assert logits.shape == (2, 1, cfg.vocab_size)
    logits2, st2 = m.decode_step(params, jnp.ones((2, 1), jnp.int32), st, rc)
    assert logits2.shape == (2, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits2).any())
    assert int(st2.pos[0]) == int(st.pos[0]) + 1


@pytest.mark.parametrize("arch", ["yi-6b", "xlstm-1.3b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode == full forward logits (cache correctness)."""
    cfg = get_smoke_config(arch)
    m = Model(cfg)
    params = m.init(jax.random.key(0))
    rc = RunConfig()
    toks = jax.random.randint(jax.random.key(1), (1, 8), 1, cfg.vocab_size)
    full, _ = __import__("repro.models.transformer", fromlist=["forward"]) \
        .forward(params, toks, cfg, rc)
    from repro.models import transformer
    state = transformer.init_decode_state(1, 16, cfg, rc)
    outs = []
    for t in range(8):
        logits, state = transformer.decode_step(params, toks[:, t:t + 1],
                                                state, cfg, rc)
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               atol=0.15, rtol=0.05)   # bf16 params


def test_long_context_applicability():
    """long_500k runs only for sub-quadratic stacks (DESIGN.md §6)."""
    runs = {a: [c.name for c in applicable_shapes(get_config(a))]
            for a in ARCH_IDS}
    assert "long_500k" in runs["xlstm_1_3b"]
    assert "long_500k" in runs["jamba_1_5_large_398b"]
    for dense in ("yi_6b", "mistral_nemo_12b", "grok_1_314b", "whisper_tiny"):
        assert "long_500k" not in runs[dense]


def test_exact_assigned_dimensions():
    """Configs carry the exact assignment numbers."""
    spec = {
        "xlstm_1_3b": (48, 2048, 4, 4, 50304),
        "qwen2_vl_72b": (80, 8192, 64, 8, 152064),
        "mistral_nemo_12b": (40, 5120, 32, 8, 131072),
        "codeqwen1_5_7b": (32, 4096, 32, 32, 92416),
        "yi_6b": (32, 4096, 32, 4, 64000),
        "qwen1_5_4b": (40, 2560, 20, 20, 151936),
        "grok_1_314b": (64, 6144, 48, 8, 131072),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 151936),
        "jamba_1_5_large_398b": (72, 8192, 64, 8, 65536),
        "whisper_tiny": (4, 384, 6, 6, 51865),
    }
    for a, (L, d, H, kv, V) in spec.items():
        c = get_config(a)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
                c.vocab_size) == (L, d, H, kv, V), a
    assert get_config("grok_1_314b").n_experts == 8
    assert get_config("qwen2_moe_a2_7b").n_experts == 60
    assert get_config("qwen2_moe_a2_7b").n_experts_per_tok == 4
    assert get_config("jamba_1_5_large_398b").n_experts == 16

"""Batched evaluation pipeline: batch == sequential equivalences across
the whole tuner stack (space codec, evaluators, controller/DB, q-batch BO,
ranking, Sapphire)."""

import numpy as np
import pytest

from repro.core import bo, gp, ranking
from repro.core.controller import Controller, EvalDB, EvalRecord
from repro.core.evaluators import AnalyticEvaluator, evaluate_many
from repro.core.sampling import latin_hypercube
from repro.core.space import Divides, Knob, Space, SumLeq


def rich_space() -> Space:
    return Space(
        knobs=(
            Knob("block", "int", 512, lo=128, hi=2048, align=128),
            Knob("depth", "int", 8, lo=1, hi=64, log_scale=True),
            Knob("frac_a", "float", 0.3, lo=0.0, hi=1.0),
            Knob("frac_b", "float", 0.3, lo=0.0, hi=1.0),
            Knob("lr", "float", 1e-3, lo=1e-5, hi=1e-1, log_scale=True),
            Knob("impl", "categorical", "ref", choices=("ref", "flash", "chunk")),
            Knob("fused", "bool", True),
            Knob("gated", "int", 4, lo=1, hi=16, gated_by=("impl", ("flash",))),
            Knob("div", "int", 4, lo=1, hi=16),
        ),
        constraints=(SumLeq(("frac_a", "frac_b"), limit=0.8),
                     Divides(("div",), target=12)),
    )


def configs_equal(a, b, rtol=1e-9):
    for k in a:
        va, vb = a[k], b[k]
        if isinstance(va, float):
            if not np.isclose(va, vb, rtol=rtol):
                return False
        elif va != vb:
            return False
    return True


# ---------------------------------------------------------------------------
# space: batched codec == per-config codec
# ---------------------------------------------------------------------------

class TestSpaceBatchCodec:
    def test_decode_batch_matches_from_unit(self):
        sp = rich_space()
        u = np.random.default_rng(0).random((64, len(sp)))
        seq = [sp.from_unit(row) for row in u]
        bat = sp.decode_batch(u)
        assert all(configs_equal(a, b) for a, b in zip(seq, bat))

    def test_encode_batch_matches_to_unit(self):
        sp = rich_space()
        cfgs = latin_hypercube(sp, 64, seed=1)
        seq = np.stack([sp.to_unit(c) for c in cfgs])
        bat = sp.encode_batch(cfgs)
        assert np.allclose(seq, bat, rtol=1e-12)

    def test_encode_decode_roundtrip(self):
        sp = rich_space()
        cfgs = latin_hypercube(sp, 32, seed=2)
        again = sp.decode_batch(sp.encode_batch(cfgs))
        assert all(configs_equal(a, b) for a, b in zip(cfgs, again))

    def test_project_batch_matches_project(self):
        sp = rich_space()
        rng = np.random.default_rng(3)
        raw = [{"block": int(rng.integers(0, 4096)),
                "frac_a": float(rng.random() * 2),
                "frac_b": float(rng.random() * 2),
                "impl": "ref", "div": int(rng.integers(1, 20))}
               for _ in range(40)]
        seq = [sp.project(c) for c in raw]
        bat = sp.project_batch(raw)
        assert all(configs_equal(a, b) for a, b in zip(seq, bat))
        # projection invariants hold on the batched path too
        for c in bat:
            assert c["frac_a"] + c["frac_b"] <= 0.8 + 1e-9
            assert 12 % c["div"] == 0
            assert sp.validate(c) == []


# ---------------------------------------------------------------------------
# evaluators: batch == N sequential calls (same seed, per-row noise keys)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def analytic_pair():
    from repro.configs import get_config
    from repro.core.costmodel import SINGLE_POD
    from repro.core.knobs import clean_space
    from repro.models.config import SHAPES_BY_NAME
    model_cfg = get_config("yi-6b")
    cell = SHAPES_BY_NAME["train_4k"]
    space, _, _ = clean_space(model_cfg, cell, SINGLE_POD)
    make = lambda: AnalyticEvaluator(model_cfg, cell, SINGLE_POD, seed=7)  # noqa: E731
    return space, make


class TestEvaluatorBatch:
    def test_batch_matches_sequential(self, analytic_pair):
        space, make = analytic_pair
        cfgs = latin_hypercube(space, 20, seed=1)
        a, b = make(), make()
        va = list(a.evaluate_batch(cfgs))
        vb = [b(c) for c in cfgs]
        # same per-row noise keys -> same stream (equal to f32 ULP; XLA's
        # vectorized exp may differ in the last bit across batch shapes)
        assert np.allclose(va, vb, rtol=1e-6)

    def test_interleaved_matches_sequential(self, analytic_pair):
        """Noise is keyed per *evaluation index*, so any batch/sequential
        interleaving reproduces the same stream."""
        space, make = analytic_pair
        cfgs = latin_hypercube(space, 15, seed=2)
        a, b = make(), make()
        va = ([a(c) for c in cfgs[:3]]
              + list(a.evaluate_batch(cfgs[3:11]))
              + [a(c) for c in cfgs[11:]])
        vb = [b(c) for c in cfgs]
        assert np.allclose(va, vb, rtol=1e-6)
        assert a.calls == b.calls == len(cfgs)
        assert len(a.history) == len(cfgs)

    def test_repeated_config_fresh_noise(self, analytic_pair):
        """The paper's averaging dilemma: same config, fresh noise."""
        space, make = analytic_pair
        ev = make()
        cfg = space.default_config()
        vals = ev.evaluate_batch([cfg] * 8)
        assert len(set(float(v) for v in vals)) == 8

    def test_empty_batch(self, analytic_pair):
        _, make = analytic_pair
        ev = make()
        assert len(ev.evaluate_batch([])) == 0
        assert ev.calls == 0

    def test_evaluate_many_fallback(self):
        calls = []
        f = lambda c: calls.append(c) or float(c["x"])  # noqa: E731
        vals = evaluate_many(f, [{"x": 1}, {"x": 2}])
        assert vals == [1.0, 2.0] and len(calls) == 2


# ---------------------------------------------------------------------------
# controller / EvalDB: batched appends round-trip (incl. numpy scalars)
# ---------------------------------------------------------------------------

class TestControllerBatch:
    def test_db_roundtrips_batched_numpy_values(self, tmp_path):
        db_file = tmp_path / "evals.jsonl"
        db = EvalDB(str(db_file))
        recs = [
            EvalRecord({"a": np.int64(3), "b": np.float32(0.25),
                        "c": np.bool_(True), "d": "flash"},
                       float(np.float32(1.5)), 0.1, "bo"),
            EvalRecord({"a": 4, "b": 0.5, "c": False, "d": "ref"},
                       2.5, 0.1, "bo"),
        ]
        db.append_batch(recs)
        db2 = EvalDB(str(db_file))
        cfgs, vals = db2.pairs("bo")
        assert vals == [1.5, 2.5]
        assert cfgs[0] == {"a": 3, "b": 0.25, "c": True, "d": "flash"}

    def test_controller_batch_matches_sequential_and_tags(self, tmp_path):
        f = lambda c: float(c["x"]) * 2   # noqa: E731
        db = EvalDB(str(tmp_path / "e.jsonl"))
        ctrl = Controller(f, db, tag="t")
        vals = ctrl.evaluate_batch([{"x": 1}, {"x": np.int64(2)}, {"x": 3}])
        assert vals == [2.0, 4.0, 6.0]
        assert len(db) == 3 and all(r.tag == "t" for r in db.records)
        reloaded = EvalDB(str(tmp_path / "e.jsonl"))
        assert reloaded.pairs("t")[1] == vals

    def test_controller_uses_evaluator_batch(self, analytic_pair):
        space, make = analytic_pair
        ev = make()
        ctrl = Controller(ev, EvalDB(), tag="rank")
        cfgs = latin_hypercube(space, 6, seed=3)
        vals = ctrl.evaluate_batch(cfgs)
        assert ev.calls == 6                      # one batched call
        assert vals == [r.value for r in ctrl.db.records]


# ---------------------------------------------------------------------------
# GP: conditioning (the q-batch fantasy update)
# ---------------------------------------------------------------------------

class TestGPCondition:
    def test_condition_matches_fit_with_fixed_params(self):
        rng = np.random.default_rng(0)
        x = rng.random((24, 2)).astype(np.float32)
        y = np.sin(3 * x[:, 0]) + x[:, 1]
        st = gp.fit(x, y, steps=80)
        st2 = gp.condition(st.params, x, y)
        mu1, sd1 = gp.predict(st, x[:5])
        mu2, sd2 = gp.predict(st2, x[:5])
        assert np.allclose(np.asarray(mu1), np.asarray(mu2), atol=1e-5)
        assert np.allclose(np.asarray(sd1), np.asarray(sd2), atol=1e-5)

    def test_fantasy_collapses_uncertainty(self):
        """Conditioning on a fantasized point must kill the posterior
        variance there — the mechanism that spreads a q-batch."""
        rng = np.random.default_rng(1)
        x = rng.random((16, 2)).astype(np.float32)
        y = x.sum(axis=1)
        st = gp.fit(x, y, steps=80)
        xq = np.array([[0.9, 0.1]], np.float32)
        _, sd_before = gp.predict(st, xq)
        x_aug = np.vstack([x, xq])
        y_aug = np.append(y, float(y.min()))
        st2 = gp.condition(st.params, x_aug, y_aug)
        _, sd_after = gp.predict(st2, xq)
        # observed points keep the fitted noise floor, so "collapse" means
        # well below the away-from-data std, not zero
        assert float(sd_after[0]) < 0.45 * float(sd_before[0])


# ---------------------------------------------------------------------------
# EI regression: peaks at the known minimum of a noiseless 1-D objective
# (guards the best_y threshold convention: predict() de-standardizes, so
# best_y is passed on the original y scale — no extra standardization)
# ---------------------------------------------------------------------------

def test_ei_peaks_at_known_minimum():
    xs = np.linspace(0.0, 1.0, 12, dtype=np.float32)[:, None]
    ys = (xs[:, 0] - 0.3) ** 2                 # noiseless, minimum at 0.3
    st = gp.fit(xs, ys, steps=150)
    cand = np.linspace(0.0, 1.0, 501, dtype=np.float32)[:, None]
    ei = np.asarray(gp.expected_improvement(st, cand, float(ys.min())))
    assert abs(float(cand[int(np.argmax(ei)), 0]) - 0.3) < 0.06
    # EI must be ~dead on already-sampled far-away points
    far = np.asarray(gp.expected_improvement(st, xs[-2:], float(ys.min())))
    assert float(far.max()) < float(ei.max()) * 1e-2


# ---------------------------------------------------------------------------
# BO: q-batch budget accounting + convergence
# ---------------------------------------------------------------------------

def _space2d():
    return Space((Knob("x", "float", 0.5, lo=0.0, hi=1.0),
                  Knob("y", "float", 0.5, lo=0.0, hi=1.0)))


class TestQBatchBO:
    @pytest.mark.parametrize("q", [1, 3, 5])
    def test_budget_exact_for_any_q(self, q):
        """n_iter counts evaluations, so the experiment budget is
        identical whatever the batch width (incl. non-divisible q)."""
        n_calls = []
        f = lambda c: (c["x"] - 0.5) ** 2     # noqa: E731

        def f_batch(cfgs):
            n_calls.append(len(cfgs))
            return [f(c) for c in cfgs]

        cfg = bo.BOConfig(n_init=4, n_iter=13, batch_size=q,
                          n_candidates=64, fit_steps=20)
        _, _, trace, _ = bo.minimize(f, _space2d(), cfg, f_batch=f_batch)
        assert len(trace.values) == 4 + 13
        if q > 1:
            # init batch, then full q-rounds, then the remainder round
            full, rem = divmod(13, q)
            assert n_calls == [4] + [q] * full + ([rem] if rem else [])

    def test_qbatch_converges_on_quadratic(self):
        rng = np.random.default_rng(0)
        f = lambda c: (c["x"] - 0.7) ** 2 + (c["y"] - 0.2) ** 2 \
            + rng.normal(0, 0.005)
        f_batch = lambda cfgs: [f(c) for c in cfgs]   # noqa: E731
        best, _, trace, _ = bo.minimize(
            f, _space2d(), bo.BOConfig(n_init=6, n_iter=24, batch_size=6,
                                       n_candidates=256, fit_steps=60),
            f_batch=f_batch)
        assert abs(best["x"] - 0.7) < 0.15 and abs(best["y"] - 0.2) < 0.15

    def test_qbatch_without_f_batch_falls_back(self):
        f = lambda c: (c["x"] - 0.3) ** 2     # noqa: E731
        _, _, trace, _ = bo.minimize(
            f, _space2d(), bo.BOConfig(n_init=4, n_iter=8, batch_size=4,
                                       n_candidates=64, fit_steps=20))
        assert len(trace.values) == 12
        bv = trace.best_values
        assert all(b2 <= b1 + 1e-12 for b1, b2 in zip(bv, bv[1:]))

    def test_batch_probes_are_distinct(self):
        """The constant liar must spread a round's probes, not stack q
        copies of the EI argmax."""
        seen = []

        def f_batch(cfgs):
            seen.append([tuple(sorted(c.items())) for c in cfgs])
            return [(c["x"] - 0.6) ** 2 + (c["y"] - 0.4) ** 2 for c in cfgs]

        f = lambda c: f_batch([c])[0]         # noqa: E731
        bo.minimize(f, _space2d(),
                    bo.BOConfig(n_init=4, n_iter=12, batch_size=4,
                                n_candidates=128, fit_steps=30),
                    f_batch=f_batch)
        rounds = [s for s in seen if len(s) == 4][1:]   # skip init batch
        for r in rounds:
            assert len(set(r)) == len(r)


# ---------------------------------------------------------------------------
# ranking + Sapphire: batched == sequential end to end
# ---------------------------------------------------------------------------

def test_ranking_batched_matches_sequential(analytic_pair):
    space, make = analytic_pair
    sub = space.subset(list(space.names[:12]))
    rk_seq = ranking.rank(sub, make(), n_samples=60, seed=5)
    rk_bat = ranking.rank(sub, make(), n_samples=60, seed=5, batch_size=25)
    assert np.allclose(rk_seq.values, rk_bat.values, rtol=1e-6)
    assert rk_seq.top(5) == rk_bat.top(5)
    assert np.allclose(rk_seq.importance, rk_bat.importance, rtol=1e-3)


def test_sapphire_batched_end_to_end(tmp_path):
    from repro.core.bo import BOConfig
    from repro.core.tuner import Sapphire
    s = Sapphire(arch="yi-6b", shape="train_4k", top_k=8, n_rank_samples=40,
                 batch_size=4, rank_batch_size=16,
                 bo_config=BOConfig(n_init=6, n_iter=12, n_candidates=128,
                                    fit_steps=30, seed=9),
                 seed=9, db_path=str(tmp_path / "db.jsonl"))
    res = s.tune()
    # tuning evaluations only: the default/expert baseline probes are
    # report overhead, not search budget
    assert res.n_evaluations == 40 + 6 + 12
    db = EvalDB(str(tmp_path / "db.jsonl"))
    assert len(db) == 40 + 6 + 12 + 2
    tags = {r.tag for r in db.records}
    assert tags == {"rank", "bo", "default", "expert"}
    errs = res.final_space.validate(
        {k: v for k, v in res.best_config.items()
         if k in res.final_space.names})
    assert errs == []

"""Fault-injection harness + resilience layer: seeded chaos is
bit-replayable, retries recover transient faults without inflating the
evaluation count, watchdogs unwedge hung probes, the circuit breaker
sheds load, and the EvalDB self-heals crash-truncated logs.

Same 120 s SIGALRM watchdog as test_service_async: a wedged
gather/drain fails fast instead of hanging CI.
"""

import signal
import threading
import time
import warnings

import pytest

from repro.core.controller import Controller, EvalDB, EvalRecord
from repro.core.faults import FaultInjectingService, FaultPlan
from repro.core.replication import ReplicationPolicy
from repro.core.resilience import (CircuitBreaker, ResilientService,
                                   RetryPolicy, TransientEvalError,
                                   classify_failure)
from repro.core.service import (CallableServiceAdapter, EvalRequest,
                                EvalResult, EvalTicket, as_service)
from repro.core.space import Knob, Space
from repro.core.strategy import make_strategy

WATCHDOG_S = 120


@pytest.fixture(autouse=True)
def _watchdog():
    if not hasattr(signal, "SIGALRM"):
        yield
        return

    def _fire(signum, frame):
        raise TimeoutError(f"faults test exceeded {WATCHDOG_S}s "
                           "(deadlocked gather/poll?)")

    old = signal.signal(signal.SIGALRM, _fire)
    signal.alarm(WATCHDOG_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


def _space():
    return Space((Knob("x", "float", 5.0, lo=0.0, hi=10.0),))


def _f(c):
    return (c["x"] - 3.0) ** 2


def _reqs(n, seed0=100):
    return [EvalRequest({"x": float(i)}, seed=seed0 + i) for i in range(n)]


FAST = RetryPolicy(max_attempts=5, backoff_s=0.0)


# ---------------------------------------------------------------------------
# failure classification
# ---------------------------------------------------------------------------

def _failed_result(exc=None, error="", error_kind=""):
    t = EvalTicket(0, EvalRequest({"x": 0.0}))
    return EvalResult(t, float("nan"), status="failed", feasible=False,
                      error=error or (repr(exc) if exc else ""),
                      exception=exc, error_kind=error_kind)


class TestClassifyFailure:
    def test_explicit_stamp_wins(self):
        r = _failed_result(exc=ValueError("boom"), error_kind="transient")
        assert classify_failure(r) == "transient"

    @pytest.mark.parametrize("exc", [
        TransientEvalError("x"), TimeoutError("x"),
        ConnectionResetError("x"), BrokenPipeError("x")])
    def test_transient_types(self, exc):
        assert classify_failure(_failed_result(exc=exc)) == "transient"

    @pytest.mark.parametrize("msg", [
        "benchmark timed out after 300s", "Connection reset by peer",
        "worker died mid-probe", "service temporarily unavailable"])
    def test_transient_patterns(self, msg):
        assert classify_failure(_failed_result(error=msg)) == "transient"

    @pytest.mark.parametrize("exc", [
        ValueError("invalid tile size"), KeyError("no backend"),
        FileNotFoundError("missing")])
    def test_permanent_default(self, exc):
        assert classify_failure(_failed_result(exc=exc)) == "permanent"


# ---------------------------------------------------------------------------
# FaultPlan determinism
# ---------------------------------------------------------------------------

class TestFaultPlan:
    def test_seeded_coins_replay(self):
        p1 = FaultPlan(transient_rate=0.3, seed=7)
        p2 = FaultPlan(transient_rate=0.3, seed=7)
        draws1 = [p1.draw(str(k), o) for k in range(50) for o in range(3)]
        draws2 = [p2.draw(str(k), o) for k in range(50) for o in range(3)]
        assert draws1 == draws2
        assert any(d == "transient" for d in draws1)
        assert any(d is None for d in draws1)

    def test_different_seed_different_stream(self):
        a = [FaultPlan(transient_rate=0.3, seed=1).draw(str(k), 0)
             for k in range(64)]
        b = [FaultPlan(transient_rate=0.3, seed=2).draw(str(k), 0)
             for k in range(64)]
        assert a != b

    def test_occurrence_folds_in(self):
        # a retried request draws a FRESH coin: the same key is not
        # deterministically re-failed on every occurrence
        p = FaultPlan(transient_rate=0.5, seed=3)
        per_key = [[p.coin("transient", str(k), o) for o in range(8)]
                   for k in range(16)]
        assert any(len(set(row)) == 2 for row in per_key)

    def test_rate_bounds_validated(self):
        with pytest.raises(ValueError):
            FaultPlan(transient_rate=1.5)

    def test_rate_extremes(self):
        assert FaultPlan().draw("k", 0) is None
        assert FaultPlan(transient_rate=1.0).draw("k", 0) == "transient"


# ---------------------------------------------------------------------------
# the chaos wrapper + retry wrapper together
# ---------------------------------------------------------------------------

class TestResilientService:
    def test_passthrough_no_faults(self):
        svc = ResilientService(CallableServiceAdapter(_f), FAST)
        rs = svc.gather(svc.submit(_reqs(4)))
        assert all(r.ok and r.attempts == 1 for r in rs)
        assert svc.retries == 0

    def test_transient_faults_recovered(self):
        chaos = FaultInjectingService(
            CallableServiceAdapter(_f), FaultPlan(transient_rate=0.5,
                                                  seed=7))
        svc = ResilientService(chaos, RetryPolicy(max_attempts=12,
                                                  backoff_s=0.0))
        rs = svc.gather(svc.submit(_reqs(20)))
        assert all(r.ok for r in rs)
        assert any(r.attempts > 1 for r in rs)
        assert svc.retries == chaos.injected["transient"] > 0
        # recovered values match the fault-free objective exactly
        for r in rs:
            assert r.value == _f(r.request.config)

    def test_worker_death_classified_and_recovered(self):
        chaos = FaultInjectingService(
            CallableServiceAdapter(_f), FaultPlan(death_rate=0.4, seed=5))
        svc = ResilientService(chaos, RetryPolicy(max_attempts=12,
                                                  backoff_s=0.0))
        rs = svc.gather(svc.submit(_reqs(16)))
        assert all(r.ok for r in rs)
        assert chaos.injected["death"] > 0

    def test_permanent_failure_not_retried(self):
        def broken(c):
            raise ValueError("config rejects itself")
        svc = ResilientService(CallableServiceAdapter(broken), FAST)
        rs = svc.gather(svc.submit(_reqs(3)))
        assert all(not r.ok and r.error_kind == "permanent"
                   and r.attempts == 1 for r in rs)
        assert svc.retries == 0

    def test_exhausted_attempts_fail_transient(self):
        always = FaultInjectingService(
            CallableServiceAdapter(_f), FaultPlan(transient_rate=1.0,
                                                  seed=1))
        svc = ResilientService(always, RetryPolicy(max_attempts=3,
                                                   backoff_s=0.0))
        rs = svc.gather(svc.submit(_reqs(2)))
        assert all(not r.ok and r.error_kind == "transient"
                   and r.attempts == 3 for r in rs)
        assert svc.exhausted == 2

    def test_retry_count_never_inflates_completions(self):
        chaos = FaultInjectingService(
            CallableServiceAdapter(_f), FaultPlan(transient_rate=0.5,
                                                  seed=9))
        svc = ResilientService(chaos, FAST)
        tickets = svc.submit(_reqs(12))
        rs = svc.drain()
        assert len(rs) == len(tickets) == 12     # one completion per request
        assert svc.in_flight == 0 and svc.ready == 0

    def test_attempt_watchdog_recovers_hangs(self):
        chaos = FaultInjectingService(
            CallableServiceAdapter(_f), FaultPlan(hang_rate=0.4, seed=9))
        svc = ResilientService(chaos, RetryPolicy(
            max_attempts=6, backoff_s=0.0, attempt_timeout_s=0.1))
        t0 = time.monotonic()
        rs = svc.gather(svc.submit(_reqs(10)))
        assert time.monotonic() - t0 < WATCHDOG_S / 2
        assert chaos.injected["hang"] > 0 and svc.timeouts > 0
        assert all(r.ok or r.error_kind == "transient" for r in rs)

    def test_attempt_watchdog_recovers_drops(self):
        chaos = FaultInjectingService(
            CallableServiceAdapter(_f), FaultPlan(drop_rate=0.4, seed=11))
        svc = ResilientService(chaos, RetryPolicy(
            max_attempts=6, backoff_s=0.0, attempt_timeout_s=0.1))
        rs = svc.gather(svc.submit(_reqs(10)))
        assert chaos.injected["drop"] > 0
        assert all(r.ok or r.error_kind == "transient" for r in rs)

    def test_duplicate_completions_dropped_exactly_once(self):
        chaos = FaultInjectingService(
            CallableServiceAdapter(_f), FaultPlan(duplicate_rate=1.0,
                                                  seed=2))
        svc = ResilientService(chaos, FAST)
        rs = svc.drain() + svc.gather(svc.submit(_reqs(8)))
        assert len(rs) == 8 and all(r.ok for r in rs)
        assert chaos.injected["duplicate"] == 8

    def test_latency_spikes_complete_out_of_order(self):
        chaos = FaultInjectingService(
            CallableServiceAdapter(_f),
            FaultPlan(latency_rate=0.5, latency_s=0.05, seed=4))
        svc = ResilientService(chaos, FAST)
        rs = svc.gather(svc.submit(_reqs(10)))
        assert all(r.ok for r in rs) and chaos.injected["latency"] > 0

    def test_deadline_bounds_total_attempts(self):
        always = FaultInjectingService(
            CallableServiceAdapter(_f), FaultPlan(transient_rate=1.0,
                                                  seed=1))
        svc = ResilientService(always, RetryPolicy(
            max_attempts=100, backoff_s=0.05, deadline_s=0.2))
        (r,) = svc.gather(svc.submit(_reqs(1)))
        assert not r.ok and r.attempts < 100

    @staticmethod
    def _seed_spy(seen):
        # request-aware callable: the built-in services pass the request
        # (and with it the measurement seed) to wants_request backends
        def spy(c, request=None):
            seen.append(request.seed)
            if len(seen) == 1:
                raise TransientEvalError("flake")
            return 0.0
        spy.wants_request = True
        return spy

    def test_reseed_attempts_folds_seed(self):
        seen = []
        svc = ResilientService(CallableServiceAdapter(self._seed_spy(seen)),
                               RetryPolicy(max_attempts=2, backoff_s=0.0,
                                           reseed_attempts=True))
        (r,) = svc.gather(svc.submit([EvalRequest({"x": 1.0}, seed=42)]))
        assert r.ok and r.attempts == 2
        assert seen[0] == 42 and seen[1] != 42       # fold-derived

    def test_default_retry_reuses_seed(self):
        seen = []
        svc = ResilientService(CallableServiceAdapter(self._seed_spy(seen)),
                               FAST)
        (r,) = svc.gather(svc.submit([EvalRequest({"x": 1.0}, seed=42)]))
        assert r.ok and seen == [42, 42]             # bit-identity path

    def test_backoff_deterministic_and_bounded(self):
        p = RetryPolicy(backoff_s=0.1, backoff_mult=2.0, max_backoff_s=0.3,
                        jitter=0.5)
        d2 = p.delay_s(7, 2)
        assert d2 == p.delay_s(7, 2)                 # deterministic
        assert 0.05 <= d2 <= 0.15                    # base 0.1 ± 50 %
        assert p.delay_s(7, 10) <= 0.3 * 1.25        # capped
        assert p.delay_s(7, 2) != p.delay_s(8, 2)    # seed-keyed jitter

    def test_requires_service_base(self):
        class NotAService:
            pass
        with pytest.raises(TypeError):
            ResilientService(NotAService())
        with pytest.raises(TypeError):
            FaultInjectingService(NotAService(), FaultPlan())

    def test_release_hung(self):
        chaos = FaultInjectingService(
            CallableServiceAdapter(_f), FaultPlan(hang_rate=1.0, seed=1))
        ts = chaos.submit(_reqs(3))
        assert chaos.hung == 3 and chaos.in_flight == 3
        assert chaos.release_hung() == 3
        rs = chaos.gather(ts)
        assert all(not r.ok for r in rs)
        assert all(classify_failure(r) == "transient" for r in rs)


# ---------------------------------------------------------------------------
# controller wiring: the chaos gate
# ---------------------------------------------------------------------------

def _run_trace(plan, seed=42, budget=24, replication=None):
    base = CallableServiceAdapter(_f)
    svc = base if plan is None else FaultInjectingService(base, plan)
    ctrl = Controller(svc, EvalDB(), tag="bo", seed=seed,
                      replication=replication,
                      resilience=RetryPolicy(max_attempts=6, backoff_s=0.0))
    strat = make_strategy("random", _space(), budget=budget, seed=seed)
    trace = ctrl.run_async(strat, batch_size=4)
    return trace, ctrl


class TestChaosGate:
    def test_trace_bit_identical_under_transient_faults(self):
        t0, c0 = _run_trace(None)
        t1, c1 = _run_trace(FaultPlan(transient_rate=0.2, seed=5))
        t2, c2 = _run_trace(FaultPlan(transient_rate=0.2, seed=5))
        assert t0.values == t1.values == t2.values
        assert [r.config for r in c0.db.records] == \
               [r.config for r in c1.db.records]

    def test_n_evaluations_never_inflated(self):
        _, ctrl = _run_trace(FaultPlan(transient_rate=0.3, seed=8))
        assert len(ctrl.db) == 24
        assert all(r.ok for r in ctrl.db.records)

    def test_with_resilience_derivative(self):
        ctrl = Controller(CallableServiceAdapter(_f), EvalDB(), seed=1)
        derived = ctrl.with_resilience(RetryPolicy(max_attempts=2))
        assert derived.resilience.max_attempts == 2
        assert ctrl.resilience is None
        assert isinstance(derived.service, ResilientService)

    def test_replication_stacks_on_resilience(self):
        def noisy(c, request=None):
            import hashlib
            h = int.from_bytes(
                hashlib.blake2s(str(request.seed).encode()).digest()[:4],
                "little")
            return _f(c) + (h / 2 ** 32 - 0.5) * 0.01
        noisy.wants_request = True

        def run(plan):
            base = CallableServiceAdapter(noisy)
            svc = base if plan is None else FaultInjectingService(base,
                                                                  plan)
            ctrl = Controller(
                svc, EvalDB(), tag="bo", seed=7,
                replication=ReplicationPolicy(n_repeats=3, seed=7),
                resilience=RetryPolicy(max_attempts=6, backoff_s=0.0))
            strat = make_strategy("random", _space(), budget=12, seed=7)
            tr = ctrl.run_async(strat, batch_size=4)
            return tr.values, [(r.repeats, round(r.variance, 12))
                               for r in ctrl.db.records]

        fault_free = run(None)
        chaotic = run(FaultPlan(transient_rate=0.25, seed=11))
        # retried repeats keep the Chan-merge invariants: pooled means,
        # variances and repeat counts all match the fault-free run
        assert fault_free == chaotic


# ---------------------------------------------------------------------------
# circuit breaker unit semantics
# ---------------------------------------------------------------------------

class TestCircuitBreaker:
    def _breaker(self, clk, threshold=3, reset_s=10.0):
        return CircuitBreaker(threshold=threshold, reset_s=reset_s,
                              clock=lambda: clk[0])

    def test_trips_after_consecutive_failures(self):
        clk = [0.0]
        b = self._breaker(clk)
        for _ in range(3):
            assert b.allow()
            b.record_failure()
        assert b.state == "open" and not b.allow() and b.trips == 1

    def test_success_resets_the_count(self):
        clk = [0.0]
        b = self._breaker(clk)
        for _ in range(5):
            b.record_failure()
            b.record_success()
        assert b.state == "closed" and b.allow()

    def test_half_open_admits_one_trial(self):
        clk = [0.0]
        b = self._breaker(clk)
        for _ in range(3):
            b.record_failure()
        clk[0] = 11.0
        assert b.state == "half_open"
        assert b.allow() and not b.allow()      # exactly one trial
        b.record_success()
        assert b.state == "closed"

    def test_failed_trial_reopens(self):
        clk = [0.0]
        b = self._breaker(clk)
        for _ in range(3):
            b.record_failure()
        clk[0] = 11.0
        assert b.allow()
        b.record_failure()
        assert b.state == "open" and not b.allow()
        clk[0] = 22.0
        assert b.state == "half_open" and b.allow()


# ---------------------------------------------------------------------------
# EvalDB crash-truncation self-heal
# ---------------------------------------------------------------------------

class TestEvalDBSelfHeal:
    def _seeded(self, path):
        db = EvalDB(str(path))
        db.append_batch([EvalRecord({"x": 1.0}, 1.0, 0.1),
                         EvalRecord({"x": 2.0}, 4.0, 0.1)])
        return db

    def test_torn_tail_quarantined_once(self, tmp_path):
        p = tmp_path / "log.jsonl"
        self._seeded(p)
        with p.open("a") as f:
            f.write('{"config": {"x": 3.0}, "val')    # killed writer
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            db = EvalDB(str(p))
        assert len(db) == 2
        assert any("quarantined" in str(x.message) for x in w)
        q = tmp_path / "log.jsonl.quarantine"
        assert q.exists() and '{"config": {"x": 3.0}' in q.read_text()
        # healed: the next load is warning-free, the log is appendable
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            db2 = EvalDB(str(p))
        assert len(db2) == 2 and not w
        db2.append(EvalRecord({"x": 5.0}, 4.0, 0.1))
        assert len(EvalDB(str(p))) == 3

    def test_missing_trailing_newline_finished_in_place(self, tmp_path):
        p = tmp_path / "log.jsonl"
        self._seeded(p)
        with p.open("rb+") as f:
            data = f.read()
            f.truncate(len(data) - 1)       # strip only the newline
        db = EvalDB(str(p))
        assert len(db) == 2                 # the record itself was whole
        assert p.read_bytes().endswith(b"\n")

    def test_hand_truncated_shard_self_heals(self, tmp_path):
        # the regression the ISSUE names: a sharded service log whose
        # shard was truncated mid-line by a killed daemon worker
        from repro.service.shardlog import ShardedEvalLog
        log = ShardedEvalLog(str(tmp_path), n_shards=2)
        ns = log.namespace("s0001")
        ns.append_batch([EvalRecord({"x": float(i)}, float(i), 0.0)
                         for i in range(4)])
        shard_path = ns.path
        whole = shard_path.read_bytes()
        shard_path.write_bytes(whole[:len(whole) - 9])   # mid-record cut
        with warnings.catch_warnings(record=True):
            warnings.simplefilter("always")
            log2 = ShardedEvalLog(str(tmp_path), n_shards=2)
        assert len(log2.namespace("s0001")) == 3
        # and the healed shard keeps accepting appends cleanly
        log2.namespace("s0001").append(
            EvalRecord({"x": 9.0}, 9.0, 0.0))
        assert len(ShardedEvalLog(str(tmp_path),
                                  n_shards=2).namespace("s0001")) == 4

    def test_empty_and_clean_files_untouched(self, tmp_path):
        p = tmp_path / "log.jsonl"
        p.write_text("")
        assert len(EvalDB(str(p))) == 0
        db = self._seeded(p)
        before = p.read_bytes()
        EvalDB(str(p))
        assert p.read_bytes() == before

"""Device-resident q-EI batch selection (the proposer hot path).

Guards the tentpole contracts:

* :func:`gp.chol_append` — the O(n²) incremental Cholesky append matches
  the full O(n³) rebuild to f32 tolerance, factor- and posterior-level;
* :func:`gp.select_batch` — the single-jit ``lax.scan`` selection
  reproduces the legacy per-pick rebuild loop (``strategy._select_batch``)
  pick for pick, for both constant-liar and Kriging-believer fantasies
  and both acquisitions;
* the Pallas gp_gram plumbing (``use_pallas``) is numerically
  interchangeable with the jnp kernels end to end.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gp
from repro.core.strategy import BOConfig, _select_batch


def _data(n=30, d=3, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.random((n, d))
    y = (np.sin(3 * x[:, 0]) + (x[:, 1] - 0.4) ** 2
         + 0.1 * rng.normal(size=n))
    return x, y


class TestCholAppend:
    def test_factor_matches_full_rebuild(self):
        x, _ = _data(24, 3, seed=1)
        params = gp.init_params(3)
        ls = np.exp(np.asarray(params.log_lengthscale))
        sv = float(np.exp(params.log_signal_var))
        nv = float(np.exp(params.log_noise_var))
        k = np.asarray(gp.matern52(x.astype(np.float32),
                                   x.astype(np.float32), ls, sv))
        kn = (k + (nv + 1e-4 * sv + 1e-6) * np.eye(24)).astype(np.float32)
        chol_head = np.linalg.cholesky(kn[:23, :23].astype(np.float64))
        l, d = gp.chol_append(jnp.asarray(chol_head, jnp.float32),
                              jnp.asarray(kn[23, :23]), float(kn[23, 23]))
        full = np.linalg.cholesky(kn.astype(np.float64))
        assert np.allclose(np.asarray(l), full[23, :23], atol=5e-5)
        assert abs(float(d) - full[23, 23]) < 5e-5

    def test_appended_posterior_matches_condition(self):
        """Appending one observation via chol_append reproduces the full
        gp.condition rebuild's posterior to f32 tolerance."""
        x, y = _data(28, 3, seed=2)
        st = gp.fit(x[:-1], y[:-1], steps=40, pad=False)
        ls = jnp.exp(st.params.log_lengthscale)
        sv = jnp.exp(st.params.log_signal_var)
        nv = jnp.exp(st.params.log_noise_var)
        x32 = x.astype(np.float32)
        k_vec = gp.matern52(x32[-1:], st.x, ls, sv)[0]
        l, d = gp.chol_append(st.chol, k_vec,
                              sv + nv + 1e-4 * sv + 1e-6)
        n = len(y)
        chol2 = np.zeros((n, n), np.float32)
        chol2[:n - 1, :n - 1] = np.asarray(st.chol)
        chol2[n - 1, :n - 1] = np.asarray(l)
        chol2[n - 1, n - 1] = float(d)
        # rebuild the appended state with condition's own standardization
        ref = gp.condition(st.params, x, y, pad=False)
        ys = np.asarray(ref.y)
        alpha = np.linalg.solve(chol2.T, np.linalg.solve(chol2, ys))
        appended = gp.GPState(st.params, jnp.asarray(x32), jnp.asarray(ys),
                              jnp.asarray(chol2), jnp.asarray(alpha),
                              ref.y_mean, ref.y_std)
        q = np.random.default_rng(3).random((16, 3)).astype(np.float32)
        mu_a, sd_a = gp.predict(appended, q)
        mu_r, sd_r = gp.predict(ref, q)
        assert np.allclose(np.asarray(mu_a), np.asarray(mu_r), atol=1e-3)
        assert np.allclose(np.asarray(sd_a), np.asarray(sd_r), atol=1e-3)


def _device_picks(st, cand, y, best_y, q, cfg, use_pallas=False):
    n = len(y)
    y_raw = np.zeros(int(st.x.shape[0]), np.float32)
    y_raw[:n] = np.asarray(y, np.float32)
    idx = np.asarray(gp.select_batch(
        st, cand.astype(np.float32), y_raw, n, best_y, q,
        kind=cfg.kernel, fantasy=cfg.fantasy, acquisition=cfg.acquisition,
        use_pallas=use_pallas))
    return idx, [cand[int(i)] for i in idx]


class TestSelectBatch:
    @pytest.mark.parametrize("fantasy", ["liar", "believer"])
    @pytest.mark.parametrize("q", [1, 4])
    def test_matches_legacy_rebuild(self, fantasy, q):
        x, y = _data(30, 3, seed=4)
        cfg = BOConfig(fantasy=fantasy)
        pad_to = gp._bucket(30 + q)
        st = gp.fit(x, y, steps=40, pad_to=pad_to)
        cand = np.random.default_rng(5).random((200, 3))
        best_y = float(np.min(y))
        legacy = _select_batch(st, cand, best_y, q, cfg, x, y, pad_to)
        idx, device = _device_picks(st, cand, y, best_y, q, cfg)
        assert len(set(idx.tolist())) == q          # q distinct candidates
        assert np.array_equal(np.stack(legacy), np.stack(device))

    def test_matches_legacy_ucb(self):
        x, y = _data(26, 2, seed=6)
        cfg = BOConfig(acquisition="ucb")
        pad_to = gp._bucket(26 + 3)
        st = gp.fit(x, y, steps=30, pad_to=pad_to)
        cand = np.random.default_rng(7).random((150, 2))
        best_y = float(np.min(y))
        legacy = _select_batch(st, cand, best_y, 3, cfg, x, y, pad_to)
        _, device = _device_picks(st, cand, y, best_y, 3, cfg)
        assert np.array_equal(np.stack(legacy), np.stack(device))

    def test_unpadded_state(self):
        """pad=False (n == m, no pseudo-points) is a valid layout too:
        picks agree with the legacy loop even though the rebuild path
        re-buckets while the append path grows exactly."""
        x, y = _data(20, 2, seed=8)
        cfg = BOConfig()
        st = gp.fit(x, y, steps=30, pad=False)
        cand = np.random.default_rng(9).random((80, 2))
        best_y = float(np.min(y))
        legacy = _select_batch(st, cand, best_y, 3, cfg, x, y,
                               gp._bucket(20 + 3))
        _, device = _device_picks(st, cand, y, best_y, 3, cfg)
        assert np.array_equal(np.stack(legacy), np.stack(device))

    def test_growing_n_reuses_compilation(self):
        """n is traced: growing observation counts at a pinned padded
        shape never recompile — the budget-pinned jit contract."""
        x, y = _data(40, 2, seed=10)
        cfg = BOConfig()
        pad_to = gp._bucket(40 + 2)
        cand = np.random.default_rng(11).random((64, 2))
        cache_size = getattr(gp.select_batch, "_cache_size", None)
        compiled_before = cache_size() if cache_size else None
        for n in (24, 31, 40):
            st = gp.fit(x[:n], y[:n], steps=10, pad_to=pad_to)
            _, picks = _device_picks(st, cand, y[:n],
                                     float(np.min(y[:n])), 2, cfg)
            assert len(picks) == 2
        if compiled_before is not None:
            # one compilation covered all three observation counts
            assert cache_size() == compiled_before + 1


class TestPallasPlumbing:
    def test_fit_predict_select_match_jnp(self):
        """use_pallas (interpret mode off-TPU) is numerically
        interchangeable with the jnp kernels through fit, predict and
        select_batch."""
        x, y = _data(12, 2, seed=12)
        cfg = BOConfig()
        pad_to = gp._bucket(12 + 2)
        st_j = gp.fit(x, y, steps=15, pad_to=pad_to)
        st_p = gp.fit(x, y, steps=15, pad_to=pad_to, use_pallas=True)
        assert np.allclose(np.asarray(st_j.chol), np.asarray(st_p.chol),
                           atol=1e-4)
        q = np.random.default_rng(13).random((8, 2)).astype(np.float32)
        mu_j, sd_j = gp.predict(st_j, q)
        mu_p, sd_p = gp.predict(st_p, q, use_pallas=True)
        assert np.allclose(np.asarray(mu_j), np.asarray(mu_p), atol=1e-3)
        assert np.allclose(np.asarray(sd_j), np.asarray(sd_p), atol=1e-3)
        cand = np.random.default_rng(14).random((24, 2))
        best_y = float(np.min(y))
        idx_j, _ = _device_picks(st_j, cand, y, best_y, 2, cfg)
        idx_p, _ = _device_picks(st_j, cand, y, best_y, 2, cfg,
                                 use_pallas=True)
        assert np.array_equal(idx_j, idx_p)

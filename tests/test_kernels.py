"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import reference_attention
from repro.kernels.gp_gram.ops import matern52_cross, matern52_gram
from repro.kernels.gp_gram.ref import matern52_cross_ref, matern52_gram_ref
from repro.kernels.mlstm_chunk.ops import mlstm_chunk
from repro.kernels.mlstm_chunk.ref import mlstm_sequential


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


FLASH_CASES = [
    # (B, Sq, Sk, H, Kh, D, causal, window, softcap, bq, bk)
    (2, 256, 256, 4, 2, 64, True, None, None, 128, 128),
    (1, 128, 384, 8, 8, 128, True, None, 30.0, 128, 128),
    (2, 200, 200, 4, 1, 64, True, 64, None, 128, 128),
    (1, 512, 512, 2, 2, 128, False, None, None, 256, 128),
    (1, 96, 96, 6, 6, 64, True, None, None, 128, 128),     # whisper-ish
    (2, 64, 64, 4, 4, 32, True, 16, 10.0, 64, 64),
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=lambda c: f"B{c[0]}S{c[1]}x{c[2]}H{c[3]}-{c[4]}D{c[5]}")
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_reference(case, dtype):
    B, Sq, Sk, H, Kh, D, causal, window, softcap, bq, bk = case
    k1, k2, k3 = jax.random.split(jax.random.key(Sq + H), 3)
    q = _rand(k1, (B, Sq, H, D), dtype)
    k = _rand(k2, (B, Sk, Kh, D), dtype)
    v = _rand(k3, (B, Sk, Kh, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, block_q=bq, block_k=bk)
    ref = reference_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_block_size_invariance():
    """Output must not depend on the (tuned) block sizes."""
    k1, k2, k3 = jax.random.split(jax.random.key(7), 3)
    q = _rand(k1, (1, 256, 4, 64), jnp.float32)
    k = _rand(k2, (1, 256, 2, 64), jnp.float32)
    v = _rand(k3, (1, 256, 2, 64), jnp.float32)
    outs = [flash_attention(q, k, v, block_q=bq, block_k=bk)
            for bq, bk in [(64, 64), (128, 256), (256, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5)


MLSTM_CASES = [
    (2, 128, 2, 32, 32), (1, 256, 4, 64, 64), (2, 64, 1, 16, 16),
    (1, 512, 2, 32, 128), (1, 128, 2, 32, 128),
]


@pytest.mark.parametrize("case", MLSTM_CASES,
                         ids=lambda c: f"B{c[0]}S{c[1]}H{c[2]}P{c[3]}C{c[4]}")
def test_mlstm_chunk_matches_sequential(case):
    B, S, H, P, chunk = case
    ks = jax.random.split(jax.random.key(S * H + P), 5)
    q = _rand(ks[0], (B, S, H, P), jnp.float32) * 0.5
    k = _rand(ks[1], (B, S, H, P), jnp.float32) * 0.5 / (P ** 0.5)
    v = _rand(ks[2], (B, S, H, P), jnp.float32) * 0.5
    logi = jax.random.normal(ks[3], (B, S, H), jnp.float32)
    logf = -jax.nn.softplus(-jax.random.normal(ks[4], (B, S, H)) * 2.0)
    out = mlstm_chunk(q, k, v, logi, logf, chunk=chunk)
    ref = mlstm_sequential(q, k, v, logi, logf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)


def test_mlstm_chunk_size_invariance():
    B, S, H, P = 1, 256, 2, 32
    ks = jax.random.split(jax.random.key(11), 5)
    q = _rand(ks[0], (B, S, H, P), jnp.float32)
    k = _rand(ks[1], (B, S, H, P), jnp.float32) / (P ** 0.5)
    v = _rand(ks[2], (B, S, H, P), jnp.float32)
    logi = jax.random.normal(ks[3], (B, S, H))
    logf = -jax.nn.softplus(-jax.random.normal(ks[4], (B, S, H)))
    outs = [mlstm_chunk(q, k, v, logi, logf, chunk=c) for c in (32, 64, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=2e-4)   # f32 reassociation across chunk sizes


def test_mlstm_kernel_matches_model_path():
    """Kernel numerics == the model's jnp chunked scan (models/xlstm.py)."""
    from repro.configs import get_smoke_config
    from repro.models import xlstm
    from repro.runconfig import RunConfig
    cfg = get_smoke_config("xlstm-1.3b")
    rc = RunConfig(mlstm_chunk=16)
    p = xlstm.mlstm_init(jax.random.key(0), cfg, jnp.float32)
    u = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model), jnp.float32)
    ref_out = xlstm.mlstm_apply(p, u, cfg, rc)          # jnp chunked path
    q, k, v, logi, logf, z = xlstm._mlstm_qkvg(p, u, cfg)
    h = mlstm_chunk(q, k, v, logi, logf, chunk=16)
    di, nh, P = xlstm.mlstm_dims(cfg)
    from repro.models.common import dense_apply, norm_apply
    hh = h.reshape(2, 64, di)
    hh = norm_apply(p["out_norm"],
                    hh.astype(u.dtype)
                    * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype),
                    kind="rmsnorm", eps=cfg.norm_eps)
    out = dense_apply(p["down"], hh)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=5e-4)


GRAM_CASES = [(40, 17, 5), (130, 200, 16), (8, 8, 2), (300, 1, 24),
              (128, 128, 8)]


@pytest.mark.parametrize("case", GRAM_CASES,
                         ids=lambda c: f"n{c[0]}m{c[1]}d{c[2]}")
def test_gp_gram_matches_reference(case):
    n, m, d = case
    ka, kb, kl = jax.random.split(jax.random.key(n + m), 3)
    xa = jax.random.uniform(ka, (n, d))
    xb = jax.random.uniform(kb, (m, d))
    ls = jax.random.uniform(kl, (d,), minval=0.1, maxval=1.0)
    np.testing.assert_allclose(
        np.asarray(matern52_gram(xa, ls, 1.7)),
        np.asarray(matern52_gram_ref(xa, ls, 1.7)), atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(matern52_cross(xa, xb, ls, 0.9)),
        np.asarray(matern52_cross_ref(xa, xb, ls, 0.9)), atol=2e-4)


def test_gp_gram_psd():
    """Property: Gram + jitter is positive definite (Cholesky succeeds)."""
    x = jax.random.uniform(jax.random.key(5), (64, 6))
    g = matern52_gram(x, jnp.full((6,), 0.3), 1.0)
    chol = np.linalg.cholesky(np.asarray(g) + 1e-5 * np.eye(64))
    assert np.all(np.isfinite(chol))


# ---------------------------------------------------------------------------
# autotune knobs: rectangular tiles, knob spaces, the dogfood evaluator
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block_n,block_m", [(64, 256), (256, 64), (32, 32)])
def test_gp_gram_rectangular_tiles_match_reference(block_n, block_m):
    """Tiling is a pure scheduling knob: non-square tiles at shapes off
    every block ladder (n=136, m=77) reproduce the reference bit-for-bit
    within f32 tolerance."""
    ka, kb, kl = jax.random.split(jax.random.key(9), 3)
    xa = jax.random.uniform(ka, (136, 9))
    xb = jax.random.uniform(kb, (77, 9))
    ls = jax.random.uniform(kl, (9,), minval=0.1, maxval=1.0)
    np.testing.assert_allclose(
        np.asarray(matern52_gram(xa, ls, 1.3, block=block_n,
                                 block_m=block_m)),
        np.asarray(matern52_gram_ref(xa, ls, 1.3)), atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(matern52_cross(xa, xb, ls, 0.8, block=block_n,
                                  block_m=block_m)),
        np.asarray(matern52_cross_ref(xa, xb, ls, 0.8)), atol=2e-4)


class TestKernelSpaces:
    def test_tunable_registry(self):
        from repro.kernels.autotune import kernel_space, tunable_kernels
        assert tunable_kernels() == ("flash_attention", "gp_gram",
                                     "mlstm_chunk")
        for k in tunable_kernels():
            sp = kernel_space(k)
            dflt = sp.project(sp.default_config())
            assert sp.validate(dflt) == []
        with pytest.raises(KeyError):
            kernel_space("nope")

    def test_pow2_snap_and_product_constraint(self):
        """Projection first snaps every knob to its pow2 ladder, then the
        ProductLeq halves the larger factor until the tile budget holds —
        and returns the ladder's own int objects, not floats."""
        from repro.kernels.autotune import kernel_space
        sp = kernel_space("gp_gram")
        p = sp.project({"block_n": 500, "block_m": 500,
                        "num_warps": 3, "pipeline": 2})
        assert p["block_n"] * p["block_m"] <= 256 * 256
        assert all(isinstance(p[k], int) and not isinstance(p[k], bool)
                   for k in ("block_n", "block_m", "num_warps"))
        assert p["num_warps"] in (2, 4)          # nearest pow2 of 3
        assert sp.validate(p) == []

    def test_pow2_knob_helper(self):
        from repro.core.space import pow2_knob
        k = pow2_knob("b", 128, 16, 512)
        assert k.choices == (16, 32, 64, 128, 256, 512)
        assert k.clip(200) == 256
        assert k.clip(24) in (16, 32)            # nearest, tie -> smaller
        with pytest.raises(AssertionError):
            pow2_knob("b", 100, 16, 512)         # default off the ladder


class TestKernelEvaluator:
    def test_times_valid_config(self):
        from repro.kernels.autotune import KernelEvaluator
        ev = KernelEvaluator("gp_gram", shape={"n": 24, "d": 3},
                            repeats=1, warmup=1)
        ms = ev(ev.spec.default_config())
        assert ms > 0.0

    def test_invalid_config_fails_through_service(self):
        """A config off the space raises in the evaluator; the service
        layer converts it into a *failed* EvalResult — the contract that
        lets the async controller price it as infeasible instead of
        dying."""
        from repro.core.service import EvalRequest, as_service
        from repro.kernels.autotune import KernelEvaluator
        ev = KernelEvaluator("gp_gram", shape={"n": 24, "d": 3},
                            repeats=1, warmup=1)
        bad = dict(ev.spec.default_config())
        bad["block_n"] = 48                      # off the pow2 ladder
        with as_service(ev) as svc:
            ticket = svc.submit([EvalRequest(config=bad)])[0]
            res = svc.gather([ticket])[0]
        assert not res.ok
        assert "invalid config" in res.error

    def test_screen_fidelity_reduces_repeats(self):
        from repro.core.service import EvalRequest
        from repro.kernels.autotune import KernelEvaluator
        calls = []
        ev = KernelEvaluator("gp_gram", shape={"n": 24, "d": 3},
                            repeats=4, warmup=1, screen_repeats=1)
        build = ev._build

        def counting_build(cfg):
            run = build(cfg)
            def wrapped():
                calls.append(1)
                return run()
            return wrapped

        ev._build = counting_build
        cfg = ev.spec.default_config()
        ev(cfg, request=EvalRequest(config=cfg, fidelity="screen"))
        screen_calls = len(calls)
        calls.clear()
        ev(cfg, request=EvalRequest(config=cfg))
        assert screen_calls < len(calls)

"""ML core (§3.3 Lasso, §3.4 GP-BO): correctness + noise-robustness."""

import numpy as np
import pytest

from repro.core import bo, gp, optimizers as opt, ranking
from repro.core.lasso import (lasso_fit, lasso_path, path_importance,
                              ridge_fit)
from repro.core.space import Knob, Space


# ---------------------------------------------------------------------------
# Lasso
# ---------------------------------------------------------------------------

def _sparse_problem(n=200, d=30, k=4, noise=0.05, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d))
    beta = np.zeros(d)
    beta[:k] = np.array([3.0, -2.0, 1.5, 1.0])[:k]
    y = x @ beta + rng.normal(0, noise, n)
    return x, y, beta


class TestLasso:
    def test_recovers_support(self):
        x, y, beta = _sparse_problem()
        coef = lasso_fit(x, y, lam=0.05)
        picked = set(np.where(np.abs(coef) > 1e-3)[0])
        assert set(range(4)) <= picked
        assert len(picked) <= 10

    def test_l1_zeroes_ridge_does_not(self):
        """The paper's argument: L1 selects, L2 only shrinks."""
        x, y, _ = _sparse_problem()
        lcoef = lasso_fit(x, y, lam=0.1)
        rcoef = ridge_fit(x, y, lam=0.1)
        assert np.sum(np.abs(lcoef) < 1e-4) > 10
        assert np.sum(np.abs(rcoef) < 1e-4) == 0

    def test_path_monotone_support(self):
        x, y, _ = _sparse_problem()
        lams, betas = lasso_path(x, y, n_lambdas=20)
        nnz = (np.abs(betas) > 1e-6).sum(axis=1)
        assert nnz[0] <= 1 and nnz[-1] >= 4        # grows along the path

    def test_path_importance_ranks_true_features_first(self):
        x, y, _ = _sparse_problem()
        lams, betas = lasso_path(x, y)
        imp = path_importance(lams, betas)
        assert set(np.argsort(-imp)[:4]) == {0, 1, 2, 3}

    # property test (was hypothesis @given): fixed draw of 10 seeds
    @pytest.mark.parametrize(
        "seed", np.random.default_rng(42).integers(0, 10_000, 10).tolist())
    def test_lambda_max_gives_zero(self, seed):
        """Property: at λ ≥ λ_max the solution is exactly 0."""
        x, y, _ = _sparse_problem(n=60, d=10, seed=seed)
        from repro.core.lasso import lambda_max, standardize
        lmax = lambda_max(standardize(x, y))
        coef = lasso_fit(x, y, lam=lmax * 1.01)
        assert np.allclose(coef, 0, atol=1e-6)


# ---------------------------------------------------------------------------
# GP
# ---------------------------------------------------------------------------

class TestGP:
    def test_interpolates_clean_data(self):
        rng = np.random.default_rng(0)
        x = rng.random((40, 2)).astype(np.float32)
        y = np.sin(3 * x[:, 0]) + x[:, 1]
        st_ = gp.fit(x, y, steps=150)
        mu, sd = gp.predict(st_, x[:10])
        assert float(np.sqrt(np.mean((np.asarray(mu) - y[:10]) ** 2))) < 0.05

    def test_denoises(self):
        """The §3.4 claim: GP approximates through noise-corrupted data."""
        rng = np.random.default_rng(1)
        x = rng.random((80, 2)).astype(np.float32)
        f = np.sin(3 * x[:, 0]) + x[:, 1]
        y = f + rng.normal(0, 0.1, 80)
        st_ = gp.fit(x, y, steps=200)
        mu, _ = gp.predict(st_, x)
        rmse = float(np.sqrt(np.mean((np.asarray(mu) - f) ** 2)))
        assert rmse < 0.06                 # well below the 0.1 noise floor

    def test_padding_invariance(self):
        rng = np.random.default_rng(2)
        x = rng.random((37, 3)).astype(np.float32)   # odd n -> pads to 48
        y = x.sum(axis=1)
        mu_p, _ = gp.predict(gp.fit(x, y, steps=100, pad=True), x[:5])
        mu_n, _ = gp.predict(gp.fit(x, y, steps=100, pad=False), x[:5])
        assert np.allclose(np.asarray(mu_p), np.asarray(mu_n), atol=2e-2)

    def test_uncertainty_grows_off_data(self):
        rng = np.random.default_rng(3)
        x = (rng.random((30, 2)) * 0.4).astype(np.float32)   # corner cluster
        y = x.sum(axis=1)
        st_ = gp.fit(x, y, steps=100)
        _, sd_near = gp.predict(st_, x[:5])
        _, sd_far = gp.predict(st_, np.full((5, 2), 0.95, np.float32))
        assert float(np.mean(sd_far)) > 2 * float(np.mean(sd_near))


# ---------------------------------------------------------------------------
# BO + baselines
# ---------------------------------------------------------------------------

def _space2d():
    return Space((Knob("x", "float", 0.5, lo=0.0, hi=1.0),
                  Knob("y", "float", 0.5, lo=0.0, hi=1.0)))


class TestBO:
    def test_converges_noisy_quadratic(self):
        rng = np.random.default_rng(0)
        f = lambda c: (c["x"] - 0.7) ** 2 + (c["y"] - 0.2) ** 2 \
            + rng.normal(0, 0.005)
        best, _, trace, _ = bo.minimize(
            f, _space2d(), bo.BOConfig(n_init=6, n_iter=20,
                                       n_candidates=256, fit_steps=60))
        assert abs(best["x"] - 0.7) < 0.15 and abs(best["y"] - 0.2) < 0.15

    def test_best_values_monotone(self):
        f = lambda c: (c["x"] - 0.3) ** 2
        _, _, trace, _ = bo.minimize(
            f, _space2d(), bo.BOConfig(n_init=4, n_iter=8,
                                       n_candidates=128, fit_steps=40))
        bv = trace.best_values
        assert all(b2 <= b1 + 1e-12 for b1, b2 in zip(bv, bv[1:]))

    def test_dynamic_boundary_escapes_static_box(self):
        """Paper Fig. 4: optimum OUTSIDE the initial box is reachable only
        with dynamic boundaries."""
        sp = Space((Knob("x", "float", 4.0, lo=1.0, hi=8.0, log_scale=True,
                         dynamic_bound=True),))
        f = lambda c: (c["x"] - 20.0) ** 2          # optimum at 20 > hi=8
        cfg = bo.BOConfig(n_init=4, n_iter=16, n_candidates=128,
                          fit_steps=40, boundary_factor=3.0)
        best_d, vd, tr, sp_final = bo.minimize(f, sp, cfg)
        assert sp_final.knob("x").hi > 8.0          # boundary grew
        assert tr.boundary_events                   # events recorded
        cfg_static = bo.BOConfig(n_init=4, n_iter=16, n_candidates=128,
                                 fit_steps=40, dynamic_boundary=False)
        best_s, vs, _, _ = bo.minimize(f, sp, cfg_static)
        assert best_d["x"] > best_s["x"]            # got closer to 20
        assert vd < vs

    def test_baseline_optimizers_run(self):
        f = lambda c: (c["x"] - 0.3) ** 2 + 0.5 * abs(c["y"] - 0.6)
        for fn in (opt.random_search,):
            best, v, tr = fn(f, _space2d(), budget=16)
            assert len(tr.values) == 16
        best, v, tr = opt.simulated_annealing(f, _space2d(), budget=16)
        assert len(tr.values) == 16
        best, v, tr = opt.genetic_algorithm(f, _space2d(), budget=16)
        assert len(tr.values) >= 16


# ---------------------------------------------------------------------------
# ranking pipeline (§3.3 end-to-end on a synthetic ground truth)
# ---------------------------------------------------------------------------

def test_ranking_finds_influential_knobs():
    knobs = tuple(
        [Knob(f"real{i}", "float", 0.5, lo=0.0, hi=1.0) for i in range(3)]
        + [Knob(f"inert{i}", "float", 0.5, lo=0.0, hi=1.0, inert=True)
           for i in range(20)]
        + [Knob("cat", "categorical", "a", choices=("a", "b", "c"))]
    )
    sp = Space(knobs)
    rng = np.random.default_rng(0)

    def f(c):
        # monotone effects: Lasso is linear — a symmetric |x-0.5| bump is
        # invisible to it by design (zero linear correlation)
        base = (3.0 * c["real0"] + 2.0 * c["real1"] ** 2
                + 1.0 * c["real2"] + (0.8 if c["cat"] == "b" else 0.0))
        return float(np.exp(base / 3) + rng.normal(0, 0.02))

    rk = ranking.rank(sp, f, n_samples=200, seed=0)
    top4 = set(rk.top(4))
    assert {"real0", "real1", "real2"} <= set(rk.top(6))
    assert "cat" in set(rk.top(8))
    rows = rk.table(4)
    assert rows[0]["importance"] >= rows[-1]["importance"]


def test_stability_selection_reduces_false_positives():
    knobs = tuple(
        [Knob("real", "float", 0.5, lo=0.0, hi=1.0)]
        + [Knob(f"inert{i}", "float", 0.5, lo=0.0, hi=1.0, inert=True)
           for i in range(40)])
    sp = Space(knobs)
    rng = np.random.default_rng(1)
    f = lambda c: 2.0 * c["real"] + rng.normal(0, 0.3)
    plain = ranking.rank(sp, f, n_samples=150, seed=2)
    rng = np.random.default_rng(1)
    stable = ranking.rank(sp, f, n_samples=150, seed=2, stability_rounds=8)
    assert stable.top(1) == ["real"]
    # stability-selected importances concentrate more mass on the signal
    def mass(rk):
        imp = rk.importance / (rk.importance.sum() + 1e-12)
        return imp[list(rk.space.names).index("real")]
    assert mass(stable) >= mass(plain)

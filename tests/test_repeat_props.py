"""Property tests for repeat aggregation and the heteroscedastic GP.

Invariants (the algebra the replication layer leans on):

* pooled mean / m2 are invariant to repeat order and to any merge/split
  of repeat groups (Chan et al.'s parallel formula);
* failed repeats never shift the pooled mean — they only widen the
  variance of the mean;
* ``RepeatStats.from_result`` is the exact inverse of the aggregation
  for ``repeats >= 2``;
* the heteroscedastic GP posterior reduces to the scalar-noise posterior
  when every row variance is equal, and the ``obs_var=None`` path is
  bit-identical to the pre-replication build.

Each property runs twice when `hypothesis` is installed (CI installs it;
the container baseline does not): once as a hypothesis ``@given`` search
and once as a fixed numpy-parametrized draw that always executes — the
suite never silently loses coverage to a missing optional dependency.
"""

import numpy as np
import pytest

from repro.core import gp
from repro.core.replication import RepeatStats, aggregate_repeats
from repro.core.service import EvalRequest, EvalResult, EvalTicket

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # container baseline: numpy-only
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed (CI-only dep)")


# ---------------------------------------------------------------------------
# the invariants, written once, driven from both harnesses
# ---------------------------------------------------------------------------

def check_order_invariance(vals, perm):
    a = RepeatStats.from_values(vals)
    b = RepeatStats.from_values([vals[i] for i in perm])
    assert a.count == b.count
    assert np.isclose(a.mean, b.mean, rtol=1e-12, atol=1e-12)
    assert np.isclose(a.m2, b.m2, rtol=1e-9, atol=1e-12)
    assert np.isclose(a.mean_var, b.mean_var, rtol=1e-9, atol=1e-15)


def check_merge_split_invariance(vals, cut):
    whole = RepeatStats.from_values(vals)
    left = RepeatStats.from_values(vals[:cut])
    right = RepeatStats.from_values(vals[cut:])
    merged = left.merge(right)
    assert merged.count == whole.count
    assert np.isclose(merged.mean, whole.mean, rtol=1e-12, atol=1e-12)
    assert np.isclose(merged.m2, whole.m2, rtol=1e-9, atol=1e-12)
    # merge is symmetric
    flipped = right.merge(left)
    assert np.isclose(flipped.mean, merged.mean, rtol=1e-12, atol=1e-12)
    assert np.isclose(flipped.m2, merged.m2, rtol=1e-9, atol=1e-12)


def check_failures_never_shift_mean(vals, n_failures):
    clean = RepeatStats.from_values(vals)
    dirty = RepeatStats.from_values(vals, failures=n_failures)
    assert dirty.mean == clean.mean
    assert dirty.obs_var == clean.obs_var
    if clean.count >= 2 and clean.obs_var > 0:
        # widening is exactly (k + f)/k, monotone in f
        assert dirty.mean_var == pytest.approx(
            clean.mean_var * (clean.count + n_failures) / clean.count)
        assert dirty.mean_var >= clean.mean_var


def check_result_roundtrip(vals, n_failures):
    t = EvalTicket(0, EvalRequest({"x": 0.5}))
    reps = [EvalResult(EvalTicket(i + 1, t.request), v, wall_s=1.0)
            for i, v in enumerate(vals)]
    reps += [EvalResult(EvalTicket(99 + i, t.request), float("nan"),
                        "failed", False, None, "boom", 1.0,
                        RuntimeError("boom")) for i in range(n_failures)]
    agg = aggregate_repeats(t, reps)
    back = RepeatStats.from_result(agg)
    direct = RepeatStats.from_values(vals, failures=n_failures)
    assert back.count == direct.count
    assert back.failures == direct.failures
    assert np.isclose(back.mean, direct.mean, rtol=1e-12, atol=1e-12)
    assert np.isclose(back.mean_var, direct.mean_var,
                      rtol=1e-9, atol=1e-15)


def check_hetero_reduces_to_scalar(seed, v):
    rng = np.random.default_rng(seed)
    x = rng.random((12, 3)).astype(np.float32)
    y = np.sin(3 * x.sum(1)) + 0.05 * rng.standard_normal(12)
    params = gp.init_params(3)
    hetero = gp.condition(params, x, y, pad=False,
                          obs_var=np.full(12, v, np.float64))
    # equal row variances == a larger global noise scalar.  obs_var is
    # raw-units and internally rescaled by 1/y_std²; fold the same term
    # into log_noise_var for the scalar build.
    import jax.numpy as jnp
    y_std = float(np.asarray(y, np.float32).std())
    if y_std < 1e-12:
        y_std = 1.0
    bumped = params._replace(log_noise_var=jnp.log(
        jnp.exp(params.log_noise_var)
        + jnp.float32(v / (y_std * y_std))))
    scalar = gp.condition(bumped, x, y, pad=False)
    xq = rng.random((6, 3)).astype(np.float32)
    mh, sh = gp.predict(hetero, xq)
    ms, ss = gp.predict(scalar, xq)
    np.testing.assert_allclose(np.asarray(mh), np.asarray(ms),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sh), np.asarray(ss),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# always-run fallback: fixed numpy draws (was hypothesis @given)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_order_invariance_fixed(seed):
    rng = np.random.default_rng(seed)
    vals = list(rng.lognormal(0, 1, size=rng.integers(1, 12)))
    check_order_invariance(vals, list(rng.permutation(len(vals))))


@pytest.mark.parametrize("seed", range(8))
def test_merge_split_invariance_fixed(seed):
    rng = np.random.default_rng(seed)
    vals = list(rng.lognormal(0, 1, size=rng.integers(2, 12)))
    check_merge_split_invariance(vals, int(rng.integers(0, len(vals) + 1)))


@pytest.mark.parametrize("seed", range(8))
def test_failures_never_shift_mean_fixed(seed):
    rng = np.random.default_rng(seed)
    vals = list(rng.lognormal(0, 1, size=rng.integers(1, 10)))
    check_failures_never_shift_mean(vals, int(rng.integers(0, 5)))


@pytest.mark.parametrize("seed", range(6))
def test_result_roundtrip_fixed(seed):
    rng = np.random.default_rng(seed)
    vals = list(rng.lognormal(0, 1, size=rng.integers(1, 8)))
    check_result_roundtrip(vals, int(rng.integers(0, 3)))


@pytest.mark.parametrize("seed,v", [(0, 0.01), (1, 0.5), (2, 2.0)])
def test_hetero_reduces_to_scalar_fixed(seed, v):
    check_hetero_reduces_to_scalar(seed, v)


def test_obs_var_none_bit_identical():
    # the pre-replication build must be untouched byte for byte
    rng = np.random.default_rng(0)
    x = rng.random((9, 2)).astype(np.float32)
    y = (x ** 2).sum(1)
    p = gp.init_params(2)
    a = gp.fit(x, y, steps=0, params=p, pad=True)
    b = gp.fit(x, y, steps=0, params=p, pad=True, obs_var=None)
    assert bool(np.all(np.asarray(a.chol) == np.asarray(b.chol)))
    assert bool(np.all(np.asarray(a.alpha) == np.asarray(b.alpha)))


def test_empty_and_singleton_stats():
    empty = RepeatStats()
    assert empty.count == 0 and empty.obs_var == 0.0 and empty.mean_var == 0.0
    one = RepeatStats.from_values([3.0])
    assert one.mean == 3.0 and one.obs_var == 0.0 and one.mean_var == 0.0
    # merging with empty is the identity (plus failure accounting)
    merged = empty.merge(one)
    assert merged.mean == 3.0 and merged.count == 1


# ---------------------------------------------------------------------------
# hypothesis-driven variants (CI installs hypothesis; skipped locally)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    finite_vals = st.lists(
        st.floats(min_value=1e-6, max_value=1e6,
                  allow_nan=False, allow_infinity=False),
        min_size=1, max_size=16)

    @needs_hypothesis
    @settings(max_examples=50, deadline=None)
    @given(vals=finite_vals, data=st.data())
    def test_order_invariance_hyp(vals, data):
        perm = data.draw(st.permutations(range(len(vals))))
        check_order_invariance(vals, list(perm))

    @needs_hypothesis
    @settings(max_examples=50, deadline=None)
    @given(vals=finite_vals, data=st.data())
    def test_merge_split_invariance_hyp(vals, data):
        cut = data.draw(st.integers(min_value=0, max_value=len(vals)))
        check_merge_split_invariance(vals, cut)

    @needs_hypothesis
    @settings(max_examples=50, deadline=None)
    @given(vals=finite_vals,
           n_failures=st.integers(min_value=0, max_value=6))
    def test_failures_never_shift_mean_hyp(vals, n_failures):
        check_failures_never_shift_mean(vals, n_failures)

    @needs_hypothesis
    @settings(max_examples=30, deadline=None)
    @given(vals=finite_vals,
           n_failures=st.integers(min_value=0, max_value=3))
    def test_result_roundtrip_hyp(vals, n_failures):
        check_result_roundtrip(vals, n_failures)

    @needs_hypothesis
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1),
           v=st.floats(min_value=1e-3, max_value=5.0))
    def test_hetero_reduces_to_scalar_hyp(seed, v):
        check_hetero_reduces_to_scalar(seed, v)

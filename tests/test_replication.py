"""Seed-determinism + replication suite (the paper's averaging dilemma).

Pins the replication contract end to end: a seeded (config, fidelity,
seed) probe is bit-reproducible across every service path (immediate,
worker pool, adapter, router — regardless of completion order), a
ReplicatingService aggregate is invariant to which inner service ran the
repeats, and a replayed ``run_async`` on a fresh controller reproduces
its trace bit for bit under a fixed controller seed.

Every test runs under a 120 s watchdog (POSIX SIGALRM) like the async
service suite: a deadlocked gather/poll fails fast instead of hanging CI.
"""

import hashlib
import json
import signal

import pytest

from repro.configs import get_config
from repro.core.controller import Controller, EvalDB, EvalRecord
from repro.core.costmodel import SINGLE_POD
from repro.core.evaluators import AnalyticEvaluator
from repro.core.knobs import clean_space
from repro.core.replication import (AdaptiveRacer, RepeatStats,
                                    ReplicatingService, ReplicationPolicy,
                                    aggregate_repeats)
from repro.core.service import (CallableServiceAdapter, EvalRequest,
                                EvalResult, EvalTicket, FidelityRouter,
                                ImmediateEvaluationService,
                                WorkerPoolEvaluationService, fold_seed)
from repro.core.strategy import BOConfig, make_strategy
from repro.models.config import SHAPES_BY_NAME

WATCHDOG_S = 120


@pytest.fixture(autouse=True)
def _watchdog():
    if not hasattr(signal, "SIGALRM"):
        yield
        return

    def _fire(signum, frame):
        raise TimeoutError(f"replication test exceeded {WATCHDOG_S}s "
                           "(deadlocked gather/poll?)")

    old = signal.signal(signal.SIGALRM, _fire)
    signal.alarm(WATCHDOG_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


class SeededFn:
    """Request-aware backend: value is a pure function of (config, seed),
    so bit-identity across services is checkable without jax."""

    wants_request = True

    def __init__(self):
        self.calls = 0

    def __call__(self, cfg, request=None):
        self.calls += 1
        seed = request.seed if request is not None else None
        h = hashlib.blake2s(
            f"{sorted(cfg.items())}|{seed}".encode()).digest()[:8]
        noise = 1.0 + (int.from_bytes(h, "little") % 10007) / 1e5
        return (cfg["x"] - 0.3) ** 2 * noise + 0.1


def _cfgs(n):
    return [{"x": 0.1 + 0.07 * i} for i in range(n)]


def _analytic(sigma=0.15):
    cfg = get_config("yi-6b")
    cell = SHAPES_BY_NAME["train_4k"]
    space, _, _ = clean_space(cfg, cell, SINGLE_POD)
    return AnalyticEvaluator(cfg, cell, noise_sigma=sigma), space


# ---------------------------------------------------------------------------
# seed propagation through every service path (satellite regression)
# ---------------------------------------------------------------------------

class TestSeedPropagation:
    def test_callable_adapter_forwards_seed(self):
        # regression: the adapter used to drop EvalRequest.seed on the
        # way to the backend
        svc = CallableServiceAdapter(SeededFn())
        c = {"x": 0.4}
        (a,) = svc.gather(svc.submit([EvalRequest(c, seed=11)]))
        (b,) = svc.gather(svc.submit([EvalRequest(c, seed=11)]))
        (d,) = svc.gather(svc.submit([EvalRequest(c, seed=12)]))
        assert a.value == b.value
        assert a.value != d.value

    def test_fidelity_router_forwards_seed(self):
        fn = SeededFn()
        router = FidelityRouter(
            {"screen": ImmediateEvaluationService({"screen": fn})})
        c = {"x": 0.4}
        (via_router,) = router.gather(router.submit(
            [EvalRequest(c, fidelity="screen", seed=11)]))
        direct_svc = ImmediateEvaluationService({"screen": SeededFn()})
        (direct,) = direct_svc.gather(direct_svc.submit(
            [EvalRequest(c, fidelity="screen", seed=11)]))
        assert via_router.value == direct.value
        router.close()

    def test_analytic_seeded_draw_position_independent(self):
        ev, space = _analytic()
        c = space.default_config()
        # seeded row value must not depend on batch position or on how
        # many unseeded calls came before it
        (v1,), _ = ev.evaluate_batch_detailed([c], seeds=[77])
        ev(c)                                   # burn unseeded calls
        ev(c)
        vals, _ = ev.evaluate_batch_detailed([c, c, c],
                                             seeds=[None, 77, None])
        assert float(vals[1]) == float(v1)
        # __call__ with the same seed is the same measurement
        assert ev(c, seed=77) == float(v1)
        # unseeded rows still draw fresh noise
        assert float(vals[0]) != float(vals[2])

    def test_seeded_and_unseeded_streams_disjoint(self):
        ev, space = _analytic()
        c = space.default_config()
        seeded = {ev(c, seed=s) for s in range(8)}
        fresh = {ev(c) for _ in range(8)}
        assert len(seeded) == 8 and len(fresh) == 8
        assert not (seeded & fresh)


# ---------------------------------------------------------------------------
# acceptance criterion: seed-replay bit-identity across built-in services
# ---------------------------------------------------------------------------

class TestServiceBitIdentity:
    def test_immediate_vs_pool_bit_identical(self):
        ev1, space = _analytic()
        c = space.default_config()
        reqs = [EvalRequest(c, seed=fold_seed(99, i)) for i in range(6)]
        imm = ImmediateEvaluationService(ev1)
        res_imm = imm.gather(imm.submit(reqs))
        ev2, _ = _analytic()
        with WorkerPoolEvaluationService(ev2, max_workers=4) as pool:
            # streamed out of order by 4 workers — gather restores ticket
            # order, and the seeds pin every draw
            res_pool = pool.gather(pool.submit(reqs))
        assert [r.value for r in res_imm] == [r.value for r in res_pool]

    def test_distinct_seeds_distinct_draws(self):
        ev, space = _analytic()
        c = space.default_config()
        svc = ImmediateEvaluationService(ev)
        res = svc.gather(svc.submit(
            [EvalRequest(c, seed=s) for s in range(10)]))
        assert len({r.value for r in res}) == 10


# ---------------------------------------------------------------------------
# the ReplicatingService wrapper
# ---------------------------------------------------------------------------

class TestReplicatingService:
    def test_fans_out_and_aggregates(self):
        ev, space = _analytic()
        c = space.default_config()
        svc = ReplicatingService(ImmediateEvaluationService(ev),
                                 n_repeats=4, seed=3)
        (r,) = svc.gather(svc.submit([EvalRequest(c, seed=55)]))
        assert r.ok and r.repeats == 4 and r.failures == 0
        assert svc.measurements == 4 and ev.calls == 4
        # the aggregate IS the pooled stats of the four seeded draws
        ev2, _ = _analytic()
        vals = [ev2(c, seed=fold_seed(55, i)) for i in range(4)]
        st = RepeatStats.from_values(vals)
        assert r.value == pytest.approx(st.mean, rel=0, abs=1e-15)
        assert r.variance == pytest.approx(st.mean_var, rel=0, abs=1e-18)

    def test_aggregate_invariant_to_inner_service(self):
        ev1, space = _analytic()
        c = space.default_config()
        reqs = [EvalRequest(c, seed=s) for s in (1, 2, 3)]
        s_imm = ReplicatingService(ImmediateEvaluationService(ev1),
                                   n_repeats=5, seed=0)
        res_imm = s_imm.gather(s_imm.submit(reqs))
        ev2, _ = _analytic()
        pool = WorkerPoolEvaluationService(ev2, max_workers=4)
        s_pool = ReplicatingService(pool, n_repeats=5, seed=0)
        res_pool = s_pool.gather(s_pool.submit(reqs))
        pool.close()
        # aggregation happens in slot (seed) order, not completion order
        assert [r.value for r in res_imm] == [r.value for r in res_pool]
        assert [r.variance for r in res_imm] == \
            [r.variance for r in res_pool]

    def test_request_n_repeats_override(self):
        ev, space = _analytic()
        c = space.default_config()
        svc = ReplicatingService(ImmediateEvaluationService(ev),
                                 n_repeats=3, seed=0)
        res = svc.gather(svc.submit([EvalRequest(c, seed=1),
                                     EvalRequest(c, seed=2, n_repeats=7)]))
        assert res[0].repeats == 3 and res[1].repeats == 7
        assert svc.measurements == 10

    def test_unseeded_requests_replay_on_fresh_wrapper(self):
        # without a request seed, the wrapper derives one from its own
        # seed and the ticket uid — a fresh stack replays bit for bit
        def run():
            ev, space = _analytic()
            svc = ReplicatingService(ImmediateEvaluationService(ev),
                                     n_repeats=3, seed=12)
            return svc.gather(svc.submit(
                [EvalRequest(space.default_config())]))[0]
        a, b = run(), run()
        assert a.value == b.value and a.variance == b.variance

    def test_poll_streams_aggregates(self):
        ev, space = _analytic()
        svc = ReplicatingService(ImmediateEvaluationService(ev),
                                 n_repeats=2, seed=0)
        tickets = svc.submit([EvalRequest(space.default_config(), seed=s)
                              for s in range(3)])
        res = svc.poll()
        assert len(res) == 3 and all(r.repeats == 2 for r in res)
        assert svc.drain() == []


# ---------------------------------------------------------------------------
# aggregation semantics (unit level; property tests in test_repeat_props)
# ---------------------------------------------------------------------------

def _res(uid, value, ok=True):
    t = EvalTicket(uid, EvalRequest({"x": 0.5}))
    if ok:
        return EvalResult(t, value, wall_s=1.0)
    return EvalResult(t, float("nan"), "failed", False, None, "boom",
                      1.0, RuntimeError("boom"))


class TestAggregation:
    def test_failed_repeat_widens_variance_not_mean(self):
        t = EvalTicket(0, EvalRequest({"x": 0.5}))
        clean = aggregate_repeats(t, [_res(1, 1.0), _res(2, 2.0),
                                      _res(3, 3.0)])
        dirty = aggregate_repeats(t, [_res(1, 1.0), _res(2, 2.0),
                                      _res(3, 3.0), _res(4, 0.0, ok=False)])
        assert dirty.value == clean.value            # mean untouched
        assert dirty.variance > clean.variance       # trust shrinks
        assert dirty.repeats == 3 and dirty.failures == 1
        assert dirty.variance == pytest.approx(clean.variance * 4 / 3)
        assert dirty.wall_s == pytest.approx(4.0)    # failed runs cost too

    def test_all_failed_aggregates_to_failed(self):
        t = EvalTicket(0, EvalRequest({"x": 0.5}))
        r = aggregate_repeats(t, [_res(1, 0, ok=False),
                                  _res(2, 0, ok=False)])
        assert not r.ok and r.repeats == 0 and r.failures == 2
        assert r.error == "boom" or "boom" in r.error

    def test_single_repeat_has_no_variance_estimate(self):
        t = EvalTicket(0, EvalRequest({"x": 0.5}))
        r = aggregate_repeats(t, [_res(1, 2.5)])
        assert r.value == 2.5 and r.variance == 0.0 and r.repeats == 1

    def test_stats_roundtrip_through_result(self):
        t = EvalTicket(0, EvalRequest({"x": 0.5}))
        r = aggregate_repeats(t, [_res(1, 1.0), _res(2, 2.0), _res(3, 4.0),
                                  _res(4, 0.0, ok=False)])
        st = RepeatStats.from_result(r)
        assert st.count == 3 and st.failures == 1
        assert st.mean == r.value
        assert st.mean_var == pytest.approx(r.variance)


# ---------------------------------------------------------------------------
# replayed run_async traces (fresh controller + fresh service each run)
# ---------------------------------------------------------------------------

def _bo(space, budget=10, seed=0):
    return make_strategy("bo", space, budget=budget, seed=seed,
                         cfg=BOConfig(n_init=6, n_iter=budget - 6,
                                      fit_steps=25))


class TestRunAsyncReplay:
    def test_replay_identical_immediate(self):
        def run():
            ev, space = _analytic()
            ctrl = Controller(ev, EvalDB(), tag="t", seed=7)
            return ctrl.run_async(_bo(space)).values
        assert run() == run()

    def test_replay_identical_worker_pool(self):
        # barrier cadence (max_in_flight=min_ask=1): with overlap, how
        # completions group into tell waves depends on thread timing, so
        # replay bit-identity over a pool is only guaranteed when each
        # ask waits out its probe (the tuning service's deterministic
        # sessions rely on exactly this cadence)
        def run():
            ev, space = _analytic()
            svc = WorkerPoolEvaluationService(ev, max_workers=1)
            ctrl = Controller(svc, EvalDB(), tag="t", seed=7)
            try:
                return ctrl.run_async(_bo(space), max_in_flight=1,
                                      min_ask=1).values
            finally:
                svc.close()
        assert run() == run()

    def test_replay_identical_fixed_k_replication(self):
        def run():
            ev, space = _analytic()
            ctrl = Controller(ev, EvalDB(), tag="t", seed=7,
                              replication=ReplicationPolicy(n_repeats=3))
            tr = ctrl.run_async(_bo(space))
            return tr.values, tr.variances, ev.calls
        a, b = run(), run()
        assert a == b
        assert a[2] == 30                       # 10 probes × 3 repeats
        assert all(v > 0 for v in a[1])         # variance channel filled

    def test_replay_identical_adaptive(self):
        def run():
            ev, space = _analytic()
            pol = ReplicationPolicy(n_repeats=2, adaptive=True,
                                    max_repeats=6, z=1.0)
            ctrl = Controller(ev, EvalDB(), tag="t", seed=7,
                              replication=pol)
            tr = ctrl.run_async(_bo(space))
            return tr.values, ev.calls, \
                [r.repeats for r in ctrl.db.records]
        a, b = run(), run()
        assert a == b
        assert len(a[0]) == 10
        assert a[1] >= 20                       # at least 2 repeats each

    def test_unseeded_controller_trace_unchanged(self):
        # the pre-replication path: no controller seed, no policy — the
        # request stream carries seed=None and traces match run() exactly
        ev1, space = _analytic(sigma=0.025)
        sync = Controller(ev1, EvalDB(), tag="t").run(_bo(space))
        ev2, _ = _analytic(sigma=0.025)
        over = Controller(ev2, EvalDB(), tag="t").run_async(_bo(space))
        assert sync.values == over.values


# ---------------------------------------------------------------------------
# EvalDB round-trip for the replication fields
# ---------------------------------------------------------------------------

class TestEvalDB:
    def test_repeats_variance_roundtrip(self, tmp_path):
        p = tmp_path / "evals.jsonl"
        db = EvalDB(str(p))
        db.append(EvalRecord({"x": 0.5}, 1.25, 0.1, "bo",
                             repeats=4, variance=0.02))
        db2 = EvalDB(str(p))
        (r,) = db2.records
        assert r.repeats == 4 and r.variance == 0.02

    def test_legacy_lines_load_with_defaults(self, tmp_path):
        p = tmp_path / "evals.jsonl"
        p.write_text(json.dumps({"config": {"x": 0.5}, "value": 1.0,
                                 "wall_s": 0.1, "tag": "bo"}) + "\n")
        (r,) = EvalDB(str(p)).records
        assert r.repeats == 1 and r.variance == 0.0

    def test_single_measurement_line_stays_legacy_shaped(self, tmp_path):
        # repeats=1 / variance=0 writes no new keys: existing tooling
        # sees byte-stable lines for non-replicated runs
        p = tmp_path / "evals.jsonl"
        db = EvalDB(str(p))
        db.append(EvalRecord({"x": 0.5}, 1.0, 0.1, "bo"))
        d = json.loads(p.read_text())
        assert "repeats" not in d and "variance" not in d


# ---------------------------------------------------------------------------
# the adaptive racer in isolation
# ---------------------------------------------------------------------------

class TestAdaptiveRacer:
    def test_settled_probe_released_immediately(self):
        ev, space = _analytic()
        svc = ReplicatingService(ImmediateEvaluationService(ev),
                                 n_repeats=2, seed=0)
        racer = AdaptiveRacer(ReplicationPolicy(adaptive=True,
                                                max_repeats=6, z=1.0), svc)
        racer.incumbent = -1e9          # CI can't straddle: far incumbent
        c = space.default_config()
        (t,) = svc.submit([EvalRequest(c, seed=5)])
        (r,) = svc.gather([t])
        out = racer.offer(t.uid, r, c, c)
        assert out is not None and out[0].value == r.value
        assert racer.busy == 0

    def test_straddling_probe_re_measured(self):
        ev, space = _analytic()
        svc = ReplicatingService(ImmediateEvaluationService(ev),
                                 n_repeats=2, seed=0)
        racer = AdaptiveRacer(ReplicationPolicy(adaptive=True,
                                                max_repeats=8, increment=2,
                                                z=3.0), svc)
        c = space.default_config()
        (t,) = svc.submit([EvalRequest(c, seed=5)])
        (r,) = svc.gather([t])
        racer.incumbent = r.value       # dead straddle: must re-measure
        held = racer.offer(t.uid, r, c, c)
        assert held is None and racer.busy == 1
        # the follow-up is a real submission through the service
        follow = svc.drain()
        assert len(follow) == 1 and follow[0].repeats == 2
        out = racer.absorb(follow[0])
        # merged stats: either settled (released) or racing again — but
        # measured count must grow and never exceed max_repeats
        if out is not None:
            assert out[0].repeats == 4
        else:
            assert racer.busy == 1


# ---------------------------------------------------------------------------
# retried repeats interleaving with failures (resilience under replication)
# ---------------------------------------------------------------------------

class FlakySeededFn(SeededFn):
    """SeededFn whose listed seeds fail transiently on their first call
    only: a retry of the same sub-repeat seed then succeeds with the
    same value a never-failed run would have produced."""

    def __init__(self, flaky_seeds=(), permanent_seeds=()):
        super().__init__()
        self.flaky = set(flaky_seeds)
        self.permanent = set(permanent_seeds)
        self.seen = set()

    def __call__(self, cfg, request=None):
        seed = request.seed if request is not None else None
        if seed in self.permanent:
            self.calls += 1
            raise ValueError("config infeasible at this seed")
        if seed in self.flaky and seed not in self.seen:
            self.seen.add(seed)
            self.calls += 1
            raise TimeoutError("benchmark timed out (transient)")
        return super().__call__(cfg, request)


class TestRetriedRepeats:
    def _repeat_seeds(self, req_seed, k):
        return [fold_seed(req_seed, i) for i in range(k)]

    def test_retried_repeat_matches_fault_free_aggregate(self):
        from repro.core.resilience import ResilientService, RetryPolicy
        req = EvalRequest({"x": 0.4}, seed=33, n_repeats=4)
        sub = self._repeat_seeds(33, 4)

        clean = ReplicatingService(CallableServiceAdapter(SeededFn()),
                                   n_repeats=4)
        (want,) = clean.gather(clean.submit([req]))

        flaky_fn = FlakySeededFn(flaky_seeds=sub[1:3])
        svc = ReplicatingService(
            ResilientService(CallableServiceAdapter(flaky_fn),
                             RetryPolicy(max_attempts=3, backoff_s=0.0)),
            n_repeats=4)
        (got,) = svc.gather(svc.submit([req]))
        # Chan-merge invariants hold through retries: same pooled mean,
        # same variance-of-mean, same repeat/failure counts
        assert got.ok and want.ok
        assert got.value == want.value
        assert got.variance == want.variance
        assert (got.repeats, got.failures) == (want.repeats, want.failures)

    def test_exhausted_transient_repeat_counts_as_failure(self):
        from repro.core.resilience import ResilientService, RetryPolicy
        req = EvalRequest({"x": 0.4}, seed=7, n_repeats=3)
        sub = self._repeat_seeds(7, 3)
        # one sub-repeat seed is permanently broken: retries burn out and
        # the aggregate must count exactly one failed repeat
        fn = FlakySeededFn(permanent_seeds=sub[1:2])
        svc = ReplicatingService(
            ResilientService(CallableServiceAdapter(fn),
                             RetryPolicy(max_attempts=2, backoff_s=0.0)),
            n_repeats=3)
        (r,) = svc.gather(svc.submit([req]))
        assert r.ok and r.repeats == 2 and r.failures == 1

        # the failure-widened variance matches a run where that repeat
        # failed without any resilience layer in the path
        plain = ReplicatingService(
            CallableServiceAdapter(
                FlakySeededFn(permanent_seeds=sub[1:2])), n_repeats=3)
        (base,) = plain.gather(plain.submit([req]))
        assert r.value == base.value and r.variance == base.variance

    def test_interleaved_failures_and_retries_stats_order_invariant(self):
        # RepeatStats is a pure fold: pushing the same per-repeat
        # outcomes in any interleaving (retried successes landing after
        # later repeats' failures) produces identical pooled stats
        from dataclasses import replace as _replace
        vals = [1.0, 3.0, 2.0]
        outcomes = [(v, True) for v in vals] + [(0.0, False)] * 2
        import itertools
        stats = []
        for perm in itertools.permutations(outcomes):
            s = RepeatStats()
            for v, ok in perm:
                if ok:
                    s = s.push(v)
                else:
                    s = _replace(s, failures=s.failures + 1)
            stats.append((s.mean, s.mean_var, s.count, s.failures))
        assert len(set(stats)) == 1
        mean, mean_var, count, failures = stats[0]
        assert mean == pytest.approx(2.0)
        assert (count, failures) == (3, 2)

    def test_chaos_replicated_run_bit_identical(self):
        # the whole stack: replication over resilience over seeded chaos
        # reproduces the fault-free replicated trace bit for bit
        from repro.core.faults import FaultInjectingService, FaultPlan
        from repro.core.resilience import RetryPolicy
        from repro.core.space import Knob, Space

        space = Space((Knob("x", "float", 0.5, lo=0.0, hi=1.0),))

        def run(plan):
            base = CallableServiceAdapter(SeededFn())
            svc = base if plan is None else FaultInjectingService(base,
                                                                  plan)
            ctrl = Controller(
                svc, EvalDB(), tag="bo", seed=5,
                replication=ReplicationPolicy(n_repeats=3, seed=5),
                resilience=RetryPolicy(max_attempts=8, backoff_s=0.0))
            strat = make_strategy("random", space, budget=12, seed=5)
            trace = ctrl.run_async(strat, batch_size=4)
            return (trace.values,
                    [(r.repeats, r.variance) for r in ctrl.db.records])

        assert run(None) == run(FaultPlan(transient_rate=0.25, seed=3))

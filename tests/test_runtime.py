"""Runtime substrate: train loop equivalences, checkpoint, data, elastic,
serving engine, cost model, evaluators."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.costmodel import (MULTI_POD, SINGLE_POD, estimate,
                                  mxu_block_efficiency, V5E)
from repro.core.evaluators import AnalyticEvaluator
from repro.core import knobs as km
from repro.models.config import SHAPES_BY_NAME
from repro.models.model import Model
from repro.runconfig import RunConfig
from repro.serve.engine import Engine
from repro.serve.kvcache import CachePlan
from repro.train import elastic
from repro.train.checkpoint import CheckpointManager
from repro.train.data import batch_at
from repro.train.train_loop import init_state, make_train_step


# ---------------------------------------------------------------------------
# train loop
# ---------------------------------------------------------------------------

class TestTrainLoop:
    def test_microbatch_equivalence(self):
        """Grad accumulation must match single-shot (same trajectory)."""
        cfg = get_smoke_config("yi-6b")
        m = Model(cfg)
        lr = lambda s: 1e-3
        results = {}
        for mb in (0, 2):
            rc = RunConfig(microbatch=mb)
            state = init_state(m, jax.random.key(0), rc)
            step = jax.jit(make_train_step(m, rc, lr_schedule=lr))
            for i in range(3):
                b = batch_at(0, i, global_batch=8, seq_len=32,
                             vocab_size=cfg.vocab_size)
                state, mets = step(state, b)
            results[mb] = float(mets["loss"])
        assert abs(results[0] - results[2]) < 0.05

    def test_unrolled_matches_scan(self):
        cfg = get_smoke_config("qwen1.5-4b")
        m = Model(cfg)
        lr = lambda s: 1e-3
        out = {}
        for unroll in (False, True):
            rc = RunConfig(microbatch=2, grad_accum_unroll=unroll)
            state = init_state(m, jax.random.key(0), rc)
            step = jax.jit(make_train_step(m, rc, lr_schedule=lr))
            b = batch_at(0, 0, global_batch=4, seq_len=16,
                         vocab_size=cfg.vocab_size)
            state, mets = step(state, b)
            out[unroll] = float(mets["loss"])
        assert abs(out[False] - out[True]) < 1e-3

    def test_loss_decreases(self):
        cfg = get_smoke_config("yi-6b")
        m = Model(cfg)
        rc = RunConfig()
        state = init_state(m, jax.random.key(0), rc)
        step = jax.jit(make_train_step(m, rc, lr_schedule=lambda s: 3e-3))
        losses = []
        for i in range(20):
            b = batch_at(0, i, global_batch=8, seq_len=64,
                         vocab_size=cfg.vocab_size)
            state, mets = step(state, b)
            losses.append(float(mets["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def test_roundtrip_bf16(self):
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d)
            tree = {"a": jnp.arange(6.0).reshape(2, 3),
                    "b": {"c": jnp.full((4,), 1.5, jnp.bfloat16)}}
            cm.save(3, tree)
            restored, step = cm.restore(tree)
            assert step == 3
            np.testing.assert_array_equal(np.asarray(restored["a"]),
                                          np.asarray(tree["a"]))
            assert restored["b"]["c"].dtype == jnp.bfloat16

    def test_retention_and_latest(self):
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d, keep_last=2)
            t = {"x": jnp.zeros(2)}
            for s in (1, 2, 3, 4):
                cm.save(s, t)
            assert cm.steps() == [3, 4]
            assert cm.latest_step() == 4

    def test_async_save(self):
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d)
            cm.save(1, {"x": jnp.ones(8)}, blocking=False)
            cm.wait()
            assert cm.latest_step() == 1

    def test_shape_mismatch_rejected(self):
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d)
            cm.save(1, {"x": jnp.ones((2, 2))})
            with pytest.raises(ValueError):
                cm.restore({"x": jnp.ones((3, 3))})

    def test_resume_reproduces_trajectory(self):
        """Train 6 = train 3 + restore + train 3 (fault tolerance)."""
        cfg = get_smoke_config("qwen1.5-4b")
        m = Model(cfg)
        rc = RunConfig()
        step = jax.jit(make_train_step(m, rc, lr_schedule=lambda s: 1e-3))

        def run(state, lo, hi):
            for i in range(lo, hi):
                b = batch_at(0, i, global_batch=4, seq_len=16,
                             vocab_size=cfg.vocab_size)
                state, mets = step(state, b)
            return state, float(mets["loss"])

        s0 = init_state(m, jax.random.key(0), rc)
        _, loss_straight = run(s0, 0, 6)
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d)
            s1, _ = run(init_state(m, jax.random.key(0), rc), 0, 3)
            cm.save(3, s1)
            s2, step_r = cm.restore(init_state(m, jax.random.key(0), rc))
            _, loss_resumed = run(s2, step_r, 6)
        assert abs(loss_straight - loss_resumed) < 1e-3


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

class TestData:
    def test_stateless_determinism(self):
        a = batch_at(0, 17, global_batch=4, seq_len=32, vocab_size=100)
        b = batch_at(0, 17, global_batch=4, seq_len=32, vocab_size=100)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))

    def test_labels_are_shifted_tokens(self):
        b = batch_at(1, 0, global_batch=2, seq_len=16, vocab_size=50)
        np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                      np.asarray(b["labels"][:, :-1]))

    def test_steps_differ(self):
        a = batch_at(0, 1, global_batch=2, seq_len=16, vocab_size=100)
        b = batch_at(0, 2, global_batch=2, seq_len=16, vocab_size=100)
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))

    # property test (was hypothesis @given): fixed draw of 10 (seed, step)s
    @pytest.mark.parametrize(
        "seed,step",
        np.random.default_rng(11).integers(0, 1000, (10, 2)).tolist())
    def test_host_shards_partition(self, seed, step):
        """Property: per-host shards are disjoint slices of the global."""
        full = batch_at(seed, step, global_batch=4, seq_len=8,
                        vocab_size=64, host_index=0, host_count=1)
        parts = [batch_at(seed, step, global_batch=4, seq_len=8,
                          vocab_size=64, host_index=h, host_count=2)
                 for h in (0, 1)]
        assert parts[0]["tokens"].shape == (2, 8)
        # different hosts draw different data
        assert not np.array_equal(np.asarray(parts[0]["tokens"]),
                                  np.asarray(parts[1]["tokens"]))


# ---------------------------------------------------------------------------
# elastic runtime
# ---------------------------------------------------------------------------

class TestElastic:
    def test_watchdog_flags_persistent_straggler(self):
        w = elastic.StepWatchdog(patience=2)
        for t in range(12):
            for h in range(4):
                w.observe(h, 1.0 + (3.0 if (h == 2 and t > 7) else 0.0))
            health = w.classify()
        assert health[2] == elastic.STRAGGLER
        assert health[0] == elastic.HEALTHY

    def test_watchdog_ignores_transient(self):
        w = elastic.StepWatchdog(patience=3)
        for t in range(10):
            for h in range(4):
                w.observe(h, 4.0 if (h == 1 and t == 5) else 1.0)
            health = w.classify()
        assert health[1] == elastic.HEALTHY

    def test_recarve_keeps_model_axis(self):
        c = elastic.Carve(2, 16, 16)
        new = elastic.recarve(c.chips - 16, c)
        assert new.model == 16
        assert new.chips <= c.chips - 16

    def test_reshard_plan_covers_all_new_shards(self):
        plan = elastic.plan_reshard(elastic.Carve(1, 8, 4),
                                    elastic.Carve(1, 6, 4))
        targets = {j for _, j in plan.param_moves}
        assert targets == set(range(6))

    def test_policy_actions(self):
        pol = elastic.ElasticPolicy(elastic.Carve(1, 16, 16),
                                    chips_per_host=8)
        assert pol.decide({0: "healthy"}, None)[0] == "continue"
        act = pol.decide({0: "healthy", 1: "dead"}, 500)
        assert act[0] == "restore" and act[1] == 500
        act = pol.decide({0: "healthy", 1: "straggler"}, None)
        assert act[0] == "evict"


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

class TestServe:
    def test_continuous_batching_isolation(self):
        """A request's output must not depend on its neighbours."""
        cfg = get_smoke_config("yi-6b")
        m = Model(cfg)
        params = m.init(jax.random.key(0))
        rc = RunConfig()
        prompt = np.arange(1, 8) % cfg.vocab_size
        eng1 = Engine(m, params, rc, slots=4, s_max=64)
        eng1.submit(prompt, 5)
        solo = eng1.run()[0].out_tokens
        eng2 = Engine(m, params, rc, slots=4, s_max=64)
        for n in (3, 7, 2, 9):
            eng2.submit(np.arange(1, 1 + n) % cfg.vocab_size, 5)
        batched = [r for r in eng2.run() if len(r.prompt) == 7][0].out_tokens
        assert solo == batched

    def test_slot_recycling(self):
        cfg = get_smoke_config("qwen1.5-4b")
        m = Model(cfg)
        params = m.init(jax.random.key(0))
        eng = Engine(m, params, RunConfig(), slots=2, s_max=48)
        for i in range(6):
            eng.submit(np.arange(1, 4), 3)
        done = eng.run()
        assert len(done) == 6
        assert all(len(r.out_tokens) == 3 for r in done)

    def test_kv_budget_enforced(self):
        cfg = get_smoke_config("yi-6b")
        m = Model(cfg)
        params = m.init(jax.random.key(0))
        with pytest.raises(ValueError):
            Engine(m, params, RunConfig(), slots=512, s_max=1 << 20,
                   hbm_bytes=1e6)

    def test_cache_plan_arithmetic(self):
        cfg = get_config("yi-6b")
        plan = CachePlan.build(cfg, RunConfig(), hbm_bytes=16e9, kv_frac=0.3)
        assert plan.fits(plan.max_batch(32768), 32768)
        assert not plan.fits(plan.max_batch(32768) + 1, 32768)
        int8 = CachePlan.build(cfg, RunConfig(kv_cache_dtype="int8"),
                               hbm_bytes=16e9, kv_frac=0.3)
        assert int8.max_batch(32768) >= 2 * plan.max_batch(32768) * 0.9


# ---------------------------------------------------------------------------
# cost model + evaluators (the test cluster)
# ---------------------------------------------------------------------------

class TestCostModel:
    def test_multi_peak_block_response(self):
        """Fig. 2b shape: the block response is non-monotone (multi-peak)."""
        effs = [mxu_block_efficiency(b, 512, 4096, 128, V5E)
                for b in range(128, 2049, 128)]
        d = np.sign(np.diff(effs))
        assert (d > 0).any() and (d < 0).any()

    def test_inert_knobs_have_no_effect(self):
        cfg = get_config("yi-6b")
        cell = SHAPES_BY_NAME["train_4k"]
        space, _, _ = km.clean_space(cfg, cell, SINGLE_POD)
        base = space.default_config()
        t0 = estimate(cfg, cell, SINGLE_POD, base).step_s
        for k in space.knobs:
            if k.inert and k.kind in ("int", "float"):
                mod = dict(base)
                mod[k.name] = k.hi
                assert estimate(cfg, cell, SINGLE_POD, mod).step_s == t0, \
                    k.name

    def test_microbatch_saturation(self):
        cfg = get_config("yi-6b")
        cell = SHAPES_BY_NAME["train_4k"]
        base = {"microbatch": 1}
        big = {"microbatch": 16}
        assert estimate(cfg, cell, SINGLE_POD, big).step_s \
            < estimate(cfg, cell, SINGLE_POD, base).step_s

    def test_oom_penalized(self):
        cfg = get_config("grok-1-314b")
        cell = SHAPES_BY_NAME["train_4k"]
        bad = {"fsdp_shard_params": False, "remat_policy": "none",
               "microbatch": 16}
        bd = estimate(cfg, cell, SINGLE_POD, bad)
        assert not bd.feasible

    def test_noise_distribution(self):
        cfg = get_config("yi-6b")
        cell = SHAPES_BY_NAME["train_4k"]
        ev = AnalyticEvaluator(cfg, cell, SINGLE_POD, noise_sigma=0.025)
        base = {}
        vals = np.array([ev(base) for _ in range(60)])
        true = ev.true_step(base)
        rel = vals / true - 1
        assert 0.01 < np.std(rel) < 0.05      # ~2.5 % multiplicative noise
        assert abs(np.mean(rel)) < 0.02

    def test_multipod_scales(self):
        cfg = get_config("yi-6b")
        cell = SHAPES_BY_NAME["train_4k"]
        t1 = estimate(cfg, cell, SINGLE_POD, {"microbatch": 16}).compute_s
        t2 = estimate(cfg, cell, MULTI_POD, {"microbatch": 16}).compute_s
        assert t2 < t1                         # 512 chips beat 256
